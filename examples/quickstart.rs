//! Quickstart: generate a synthetic ogbn-mag-like HetG, meta-partition
//! it, and train an R-GCN for a few epochs with the RAF engine.
//!
//!     make artifacts && cargo run --release --offline --example quickstart

use heta::config::Config;
use heta::coordinator::{Engine, Session, SystemKind};
use heta::partition::meta::meta_partition;

fn main() -> anyhow::Result<()> {
    let cfg = Config::load("configs/mag-tiny.json")?;
    let g = cfg.build_graph();
    println!(
        "graph: {} nodes / {} types, {} edges / {} relations",
        g.num_nodes(),
        g.schema.node_types.len(),
        g.num_edges(),
        g.schema.relations.len()
    );

    // Meta-partitioning (paper §5): sub-metatrees -> partitions.
    let (mp, tree) = meta_partition(&g, cfg.train.num_partitions, cfg.model.layers, None);
    println!(
        "meta-partitioning: {} sub-metatrees, {} partitions, done in {}",
        tree.sub_metatrees().len(),
        mp.num_parts,
        heta::util::fmt_secs(mp.elapsed_s)
    );
    for p in 0..mp.num_parts {
        let rels: Vec<String> = mp.rels_per_part[p]
            .iter()
            .map(|&r| g.schema.rel_triple(r))
            .collect();
        println!("  partition {p}: {}", rels.join(", "));
    }

    // Train with the RAF engine (Algorithm 1).
    let mut sess = Session::new(&cfg, &format!("artifacts/{}", cfg.name))?;
    let mut engine = Engine::build(&mut sess, SystemKind::Heta)?;
    for ep in 0..4 {
        let r = engine.run_epoch(&mut sess, ep)?;
        println!(
            "epoch {ep}: loss {:.4} acc {:.3} | simulated epoch time {} | net {}",
            r.loss_mean,
            r.accuracy,
            heta::util::fmt_secs(r.epoch_time_s),
            heta::util::fmt_bytes(r.comm.bytes[0])
        );
    }
    Ok(())
}
