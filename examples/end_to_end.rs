//! End-to-end validation driver (EXPERIMENTS.md §End-to-end): trains an
//! R-GCN on the synthetic ogbn-mag dataset for several hundred steps
//! under BOTH engines, logging the loss curve, and asserts (a) the
//! curves match step-for-step (Prop. 1 / Fig. 16) and (b) training
//! converges (loss drops substantially, accuracy climbs well above
//! chance).
//!
//!     make artifacts && cargo run --release --offline --example end_to_end
//!     # optional: --config mag-bench --epochs 60

use heta::config::Config;
use heta::coordinator::{Engine, Session, SystemKind};
use heta::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let name = args.get_or("config", "mag-bench");
    let epochs = args.get_usize("epochs", 40);
    let cfg = Config::load(&format!("configs/{name}.json"))?;
    let dir = format!("artifacts/{name}");

    let mut raf_sess = Session::new(&cfg, &dir)?;
    let mut raf = Engine::build(&mut raf_sess, SystemKind::Heta)?;
    let mut van_sess = Session::new(&cfg, &dir)?;
    let mut van = Engine::build(&mut van_sess, SystemKind::DglMetis)?;

    println!("step  raf_loss  vanilla_loss  raf_acc  vanilla_acc");
    let mut steps = 0usize;
    let (mut first_loss, mut last_loss, mut last_acc) = (f64::NAN, f64::NAN, 0.0);
    let mut max_diff = 0.0f64;
    for ep in 0..epochs {
        let r = raf.run_epoch(&mut raf_sess, ep)?;
        let v = van.run_epoch(&mut van_sess, ep)?;
        steps += r.batches;
        if first_loss.is_nan() {
            first_loss = r.loss_mean;
        }
        last_loss = r.loss_mean;
        last_acc = r.accuracy;
        max_diff = max_diff.max((r.loss_mean - v.loss_mean).abs());
        println!(
            "{:>4}  {:>8.4}  {:>12.4}  {:>7.3}  {:>11.3}",
            steps, r.loss_mean, v.loss_mean, r.accuracy, v.accuracy
        );
    }

    println!("\ntrained {steps} steps");
    println!("loss: {first_loss:.4} -> {last_loss:.4} (acc {last_acc:.3})");
    println!("max RAF-vs-vanilla loss divergence: {max_diff:.2e}");
    anyhow::ensure!(
        last_loss < first_loss * 0.7,
        "training did not converge"
    );
    anyhow::ensure!(
        max_diff < 0.05 * first_loss,
        "engines diverged (Prop. 1 violated)"
    );
    println!("end-to-end validation OK: engines equivalent and training converges");
    Ok(())
}
