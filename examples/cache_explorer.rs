//! Cache explorer: reproduce the §6 mechanism interactively — profile
//! miss-penalty ratios across node types (Fig. 7), build caches under
//! the three policies (Fig. 11 arms), train one epoch each and report
//! hit rates + simulated epoch time.
//!
//!     cargo run --release --offline --example cache_explorer -- --config donor-bench

use heta::cache::{miss_penalty_ratio, Policy};
use heta::config::Config;
use heta::coordinator::{Engine, Session, SystemKind};
use heta::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let name = args.get_or("config", "donor-bench");
    let mut cfg = Config::load(&format!("configs/{name}.json"))?;
    let g = cfg.build_graph();

    println!("miss-penalty ratios (ns per feature byte), {}:", g.schema.name);
    for t in &g.schema.node_types {
        let r = miss_penalty_ratio(&cfg.cost, t.feat_dim, t.learnable);
        println!(
            "  {:<10} dim {:<5} {}  o_a = {:>8.1} ns/B",
            t.name,
            t.feat_dim,
            if t.learnable { "learnable" } else { "read-only" },
            r * 1e9
        );
    }

    for policy in [Policy::None, Policy::HotnessOnly, Policy::HotnessMissPenalty] {
        cfg.train.cache_policy = policy;
        let mut sess = Session::new(&cfg, &format!("artifacts/{name}"))?;
        let mut engine = Engine::build(&mut sess, SystemKind::Heta)?;
        let r = engine.run_epoch(&mut sess, 0)?;
        let label = match policy {
            Policy::None => "no-cache",
            Policy::HotnessOnly => "hotness-only",
            Policy::HotnessMissPenalty => "hotness+miss-penalty (Heta)",
        };
        println!(
            "\npolicy {label}: simulated epoch {} (fetch {})",
            heta::util::fmt_secs(r.epoch_time_s),
            heta::util::fmt_secs(r.stages.get(heta::metrics::Stage::Fetch))
        );
        if let Engine::Raf(raf) = &engine {
            for (p, rates) in raf.hit_rates().iter().enumerate() {
                let shown: Vec<String> = rates
                    .iter()
                    .enumerate()
                    .filter(|(_, &r)| r > 0.0)
                    .map(|(ty, r)| format!("{}={:.0}%", g.schema.node_types[ty].name, r * 100.0))
                    .collect();
                if !shown.is_empty() {
                    println!("  partition {p} hit rates: {}", shown.join(" "));
                }
            }
        }
    }
    Ok(())
}
