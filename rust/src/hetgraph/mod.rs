//! Heterogeneous graph storage: schema (node types, relations, metagraph)
//! and per-relation CSR adjacency.
//!
//! A HetG `G = (V, E, A, R)` (paper §2.1) is stored as a collection of
//! *mono-relation subgraphs*: one CSR per relation `r = (src_ty, name,
//! dst_ty)`, indexed by **destination** node, because HGNN aggregation for
//! a node `v` pulls from in-neighbors `N_r(v)` (the `u` of edges
//! `(u, v)` of relation `r`). Node ids are local per node type
//! (`0 .. count(ty)`), matching how features and partitions are stored.

use crate::util::json::Json;

/// Index of a node type in the schema (a "vertex" of the metagraph).
pub type TypeId = usize;
/// Index of a relation in the schema (a "link" of the metagraph).
pub type RelId = usize;
/// Node id local to its node type.
pub type NodeId = u32;

/// A node type: name, cardinality and feature profile.
#[derive(Debug, Clone)]
pub struct NodeType {
    pub name: String,
    pub count: usize,
    /// Feature dimension. For featureless types this is the dimension of
    /// the *learnable* embedding assigned to them (paper §1/§2.3).
    pub feat_dim: usize,
    /// True if this type has no raw features and uses learnable
    /// embeddings updated during training.
    pub learnable: bool,
}

/// A relation `(src_ty, name, dst_ty)`; `reverse_of` links a reverse
/// relation to its forward counterpart when the schema declares one.
#[derive(Debug, Clone)]
pub struct Relation {
    pub name: String,
    pub src: TypeId,
    pub dst: TypeId,
    pub reverse_of: Option<RelId>,
}

/// Graph schema = metagraph `M = (A, R)` plus task metadata.
#[derive(Debug, Clone)]
pub struct Schema {
    pub name: String,
    pub node_types: Vec<NodeType>,
    pub relations: Vec<Relation>,
    /// The target (training) node type carrying labels.
    pub target: TypeId,
    pub num_classes: usize,
}

impl Schema {
    pub fn type_id(&self, name: &str) -> Option<TypeId> {
        self.node_types.iter().position(|t| t.name == name)
    }

    pub fn rel_id(&self, name: &str) -> Option<RelId> {
        self.relations.iter().position(|r| r.name == name)
    }

    /// Relations whose destination is `ty` — the links followed by the
    /// metatree BFS (paper §5, Step 1).
    pub fn in_relations(&self, ty: TypeId) -> Vec<RelId> {
        (0..self.relations.len())
            .filter(|&r| self.relations[r].dst == ty)
            .collect()
    }

    /// Human-readable relation triple, e.g. `author-writes->paper`.
    pub fn rel_triple(&self, r: RelId) -> String {
        let rel = &self.relations[r];
        format!(
            "{}-{}->{}",
            self.node_types[rel.src].name, rel.name, self.node_types[rel.dst].name
        )
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::str(self.name.clone())),
            (
                "node_types",
                Json::Arr(
                    self.node_types
                        .iter()
                        .map(|t| {
                            Json::from_pairs(vec![
                                ("name", Json::str(t.name.clone())),
                                ("count", Json::num(t.count as f64)),
                                ("feat_dim", Json::num(t.feat_dim as f64)),
                                ("learnable", Json::Bool(t.learnable)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "relations",
                Json::Arr(
                    self.relations
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("name", Json::str(r.name.clone())),
                                ("src", Json::num(r.src as f64)),
                                ("dst", Json::num(r.dst as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("target", Json::num(self.target as f64)),
            ("num_classes", Json::num(self.num_classes as f64)),
        ])
    }
}

/// CSR adjacency of one mono-relation subgraph, indexed by destination
/// node: in-neighbors of dst `v` are `indices[offsets[v] .. offsets[v+1]]`.
#[derive(Debug, Clone)]
pub struct RelCsr {
    pub rel: RelId,
    pub offsets: Vec<u64>,
    pub indices: Vec<NodeId>,
}

impl RelCsr {
    /// Build from an edge list of `(src, dst)` pairs using counting sort —
    /// O(E). `num_dst` is the cardinality of the destination type.
    pub fn from_edges(rel: RelId, num_dst: usize, edges: &[(NodeId, NodeId)]) -> RelCsr {
        let mut counts = vec![0u64; num_dst + 1];
        for &(_, d) in edges {
            counts[d as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let offsets = counts.clone();
        let mut indices = vec![0 as NodeId; edges.len()];
        let mut cursor = counts;
        for &(s, d) in edges {
            indices[cursor[d as usize] as usize] = s;
            cursor[d as usize] += 1;
        }
        RelCsr {
            rel,
            offsets,
            indices,
        }
    }

    #[inline]
    pub fn neighbors(&self, dst: NodeId) -> &[NodeId] {
        let lo = self.offsets[dst as usize] as usize;
        let hi = self.offsets[dst as usize + 1] as usize;
        &self.indices[lo..hi]
    }

    #[inline]
    pub fn degree(&self, dst: NodeId) -> usize {
        (self.offsets[dst as usize + 1] - self.offsets[dst as usize]) as usize
    }

    pub fn num_edges(&self) -> usize {
        self.indices.len()
    }

    /// Bytes consumed by this CSR (for Table 2 peak-memory accounting).
    pub fn mem_bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.indices.len() * 4) as u64
    }
}

/// A heterogeneous graph: schema + one CSR per relation + labels for the
/// target type. Features live in [`crate::kvstore`] so that partitioned /
/// cached storage is explicit.
#[derive(Debug, Clone)]
pub struct HetGraph {
    pub schema: Schema,
    pub rels: Vec<RelCsr>,
    /// Class label per target-type node.
    pub labels: Vec<u16>,
    /// Train-split mask over target nodes (the paper's "training nodes").
    pub train_mask: Vec<bool>,
}

impl HetGraph {
    pub fn num_nodes(&self) -> usize {
        self.schema.node_types.iter().map(|t| t.count).sum()
    }

    pub fn num_edges(&self) -> usize {
        self.rels.iter().map(|r| r.num_edges()).sum()
    }

    pub fn train_nodes(&self) -> Vec<NodeId> {
        self.train_mask
            .iter()
            .enumerate()
            .filter(|(_, &m)| m)
            .map(|(i, _)| i as NodeId)
            .collect()
    }

    pub fn csr(&self, rel: RelId) -> &RelCsr {
        &self.rels[rel]
    }

    /// Total topology bytes (Table 2 memory accounting).
    pub fn mem_bytes(&self) -> u64 {
        self.rels.iter().map(|r| r.mem_bytes()).sum::<u64>()
            + self.labels.len() as u64 * 2
            + self.train_mask.len() as u64
    }

    /// Storage footprint including features at the given bytes/element
    /// (paper Table 1 "Storage (GB)" uses fp16 features ⇒ 2 bytes).
    pub fn storage_bytes(&self, bytes_per_feat: u64) -> u64 {
        let feat: u64 = self
            .schema
            .node_types
            .iter()
            .map(|t| (t.count * t.feat_dim) as u64 * bytes_per_feat)
            .sum();
        self.mem_bytes() + feat
    }
}

/// Metatree: the HGNN computation-dependency tree over the metagraph
/// (paper §5 Step 1). Vertices are tree positions; the same node type may
/// appear at several positions (metagraph cycles).
#[derive(Debug, Clone)]
pub struct MetaTree {
    pub vertices: Vec<MetaTreeVertex>,
    /// Tree edges: (parent vertex, child vertex, relation).
    pub edges: Vec<MetaTreeEdge>,
}

#[derive(Debug, Clone)]
pub struct MetaTreeVertex {
    pub ty: TypeId,
    pub depth: usize,
    pub parent: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct MetaTreeEdge {
    pub parent: usize,
    pub child: usize,
    pub rel: RelId,
}

impl MetaTree {
    /// k-depth BFS from the target type, following in-relations — exactly
    /// Algorithm 2 line 4. Deterministic: children expand in relation-id
    /// order, giving a canonical vertex numbering shared with the AOT plan.
    pub fn build(schema: &Schema, depth: usize) -> MetaTree {
        let mut t = MetaTree {
            vertices: vec![MetaTreeVertex {
                ty: schema.target,
                depth: 0,
                parent: None,
            }],
            edges: Vec::new(),
        };
        let mut frontier = vec![0usize];
        for d in 0..depth {
            let mut next = Vec::new();
            for &v in &frontier {
                let ty = t.vertices[v].ty;
                for r in schema.in_relations(ty) {
                    let child = t.vertices.len();
                    t.vertices.push(MetaTreeVertex {
                        ty: schema.relations[r].src,
                        depth: d + 1,
                        parent: Some(v),
                    });
                    t.edges.push(MetaTreeEdge {
                        parent: v,
                        child,
                        rel: r,
                    });
                    next.push(child);
                }
            }
            frontier = next;
        }
        t
    }

    /// Build from user-provided metapaths (Algorithm 2 line 2): each
    /// metapath is a sequence of relation ids walked from the root.
    pub fn from_metapaths(schema: &Schema, metapaths: &[Vec<RelId>]) -> MetaTree {
        let mut t = MetaTree {
            vertices: vec![MetaTreeVertex {
                ty: schema.target,
                depth: 0,
                parent: None,
            }],
            edges: Vec::new(),
        };
        for path in metapaths {
            let mut at = 0usize;
            for (d, &r) in path.iter().enumerate() {
                assert_eq!(
                    schema.relations[r].dst, t.vertices[at].ty,
                    "metapath relation {} does not end at current type",
                    schema.rel_triple(r)
                );
                // Reuse an existing child edge with the same relation,
                // otherwise extend the tree.
                let existing = t
                    .edges
                    .iter()
                    .find(|e| e.parent == at && e.rel == r)
                    .map(|e| e.child);
                at = match existing {
                    Some(c) => c,
                    None => {
                        let child = t.vertices.len();
                        t.vertices.push(MetaTreeVertex {
                            ty: schema.relations[r].src,
                            depth: d + 1,
                            parent: Some(at),
                        });
                        t.edges.push(MetaTreeEdge {
                            parent: at,
                            child,
                            rel: r,
                        });
                        child
                    }
                };
            }
        }
        t
    }

    /// Children edges of a vertex, in canonical order.
    pub fn children_of(&self, v: usize) -> Vec<&MetaTreeEdge> {
        self.edges.iter().filter(|e| e.parent == v).collect()
    }

    /// Root-child subtree ids: for each child edge of the root, the set of
    /// tree-edge indices contained in that sub-metatree (root + child +
    /// descendants) — paper §5 Step 2.
    pub fn sub_metatrees(&self) -> Vec<Vec<usize>> {
        let root_children: Vec<usize> = self
            .edges
            .iter()
            .enumerate()
            .filter(|(_, e)| e.parent == 0)
            .map(|(i, _)| i)
            .collect();
        root_children
            .iter()
            .map(|&ei| {
                let mut contained = vec![ei];
                let mut stack = vec![self.edges[ei].child];
                while let Some(v) = stack.pop() {
                    for (j, e) in self.edges.iter().enumerate() {
                        if e.parent == v {
                            contained.push(j);
                            stack.push(e.child);
                        }
                    }
                }
                contained.sort();
                contained
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny ogbn-mag-like schema used across module tests.
    pub fn mag_schema() -> Schema {
        Schema {
            name: "magtest".into(),
            node_types: vec![
                NodeType { name: "paper".into(), count: 100, feat_dim: 16, learnable: false },
                NodeType { name: "author".into(), count: 80, feat_dim: 8, learnable: true },
                NodeType { name: "inst".into(), count: 10, feat_dim: 8, learnable: true },
                NodeType { name: "field".into(), count: 20, feat_dim: 8, learnable: true },
            ],
            relations: vec![
                Relation { name: "writes".into(), src: 1, dst: 0, reverse_of: None },
                Relation { name: "cites".into(), src: 0, dst: 0, reverse_of: None },
                Relation { name: "topic_rev".into(), src: 3, dst: 0, reverse_of: None },
                Relation { name: "writes_rev".into(), src: 0, dst: 1, reverse_of: Some(0) },
                Relation { name: "affil_rev".into(), src: 2, dst: 1, reverse_of: None },
            ],
            target: 0,
            num_classes: 5,
        }
    }

    #[test]
    fn csr_from_edges() {
        let edges = [(3u32, 0u32), (1, 0), (2, 2), (0, 2)];
        let csr = RelCsr::from_edges(0, 3, &edges);
        assert_eq!(csr.neighbors(0), &[3, 1]);
        assert_eq!(csr.neighbors(1), &[] as &[u32]);
        assert_eq!(csr.neighbors(2), &[2, 0]);
        assert_eq!(csr.degree(0), 2);
        assert_eq!(csr.num_edges(), 4);
    }

    #[test]
    fn in_relations_follow_dst() {
        let s = mag_schema();
        assert_eq!(s.in_relations(0), vec![0, 1, 2]); // writes, cites, topic_rev
        assert_eq!(s.in_relations(1), vec![3, 4]);
    }

    #[test]
    fn metatree_matches_paper_fig6() {
        // 2-depth BFS from "paper": root has 3 children (A, P, F);
        // the A child has 2 children (P via writes_rev... no: in-relations
        // of author are writes_rev(P->A) and affil_rev(I->A)).
        let s = mag_schema();
        let t = MetaTree::build(&s, 2);
        let root_children = t.children_of(0);
        assert_eq!(root_children.len(), 3);
        // Sub-metatrees: one per root child (paper: S1, S2, S3).
        let subs = t.sub_metatrees();
        assert_eq!(subs.len(), 3);
        // The author subtree contains the depth-2 edges under author.
        let author_sub = &subs[0]; // child via rel 0 = writes (author)
        assert!(author_sub.len() == 3); // writes + writes_rev + affil_rev
        // Every edge belongs to exactly one sub-metatree.
        let mut all: Vec<usize> = subs.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..t.edges.len()).collect::<Vec<_>>());
    }

    #[test]
    fn metatree_depth1() {
        let s = mag_schema();
        let t = MetaTree::build(&s, 1);
        assert_eq!(t.vertices.len(), 4);
        assert_eq!(t.edges.len(), 3);
        assert!(t.vertices[1..].iter().all(|v| v.depth == 1));
    }

    #[test]
    fn metapath_tree_shares_prefixes() {
        let s = mag_schema();
        // P<-writes-A<-affil_rev-I and P<-writes-A<-writes_rev-P share the
        // first hop.
        let t = MetaTree::from_metapaths(&s, &[vec![0, 4], vec![0, 3], vec![1]]);
        assert_eq!(t.children_of(0).len(), 2); // writes-child and cites-child
        let subs = t.sub_metatrees();
        assert_eq!(subs.len(), 2);
        assert_eq!(subs[0].len(), 3);
        assert_eq!(subs[1].len(), 1);
    }

    #[test]
    fn schema_json_roundtrip_fields() {
        let s = mag_schema();
        let j = s.to_json();
        assert_eq!(j.get("target").as_usize(), Some(0));
        assert_eq!(j.get("node_types").as_arr().unwrap().len(), 4);
    }
}

#[cfg(test)]
pub use tests::mag_schema;
