//! Per-worker event timeline of one training epoch (simulated clock).
//!
//! Both execution runtimes record, per batch, the modeled duration of
//! every stage on every worker plus the leader-side phases. From one
//! timeline two epoch times are derived:
//!
//! * [`EpochTimeline::sequential_time`] — the classic accounting the
//!   seed engines reported: per batch, the slowest worker's
//!   sample+fetch+copy+forward, then the leader phases, then the
//!   slowest worker's backward, all summed (no overlap).
//! * [`EpochTimeline::pipelined_time`] — the double-buffered cluster
//!   schedule: each worker prefetches batch `i+1`'s sampling and
//!   read-only cache fetches while the leader runs batch `i`'s
//!   gather → leader-step → scatter, so prefetch work is hidden
//!   whenever it fits inside the leader phase. This is the
//!   critical-path (max-over-workers, overlap-aware) epoch time.
//!
//! The schedule is a deterministic function of the recorded durations —
//! thread interleavings of the real runtime never affect it.

/// Wall-clock stage spans per worker, in seconds relative to the
/// epoch's wall-clock origin (PR 3; backward lanes since PR 4). Unlike
/// the modeled spans below — which *price* a schedule — these record
/// when each worker's marshal+execute stages actually ran on this
/// machine, so they are the direct evidence that per-worker execution
/// contexts overlap (and that the `train.shared_session` escape hatch
/// serializes them). With a staleness window open
/// (`train.staleness >= 1`), the backward lane is the evidence that
/// batch `i`'s backward genuinely overlapped a later batch's forward.
#[derive(Debug, Clone, Default)]
pub struct WallClock {
    /// `forward[w]` = `(start_s, end_s)` intervals of worker `w`'s
    /// forward executions, one per batch, in batch order.
    pub forward: Vec<Vec<(f64, f64)>>,
    /// `backward[w]` = intervals of worker `w`'s backward executions,
    /// one per batch, in batch order. Empty for engines whose backward
    /// is fused into the forward artifact (vanilla).
    pub backward: Vec<Vec<(f64, f64)>>,
}

/// Half-open interval overlap: a span ending exactly when another
/// starts does not overlap.
fn spans_overlap(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 < b.1 && b.0 < a.1
}

impl WallClock {
    pub fn new(workers: usize) -> WallClock {
        WallClock {
            forward: vec![Vec::new(); workers],
            backward: vec![Vec::new(); workers],
        }
    }

    /// Record one forward-execution interval for `worker`.
    pub fn record_forward(&mut self, worker: usize, span: (f64, f64)) {
        if self.forward.len() <= worker {
            self.forward.resize(worker + 1, Vec::new());
        }
        self.forward[worker].push(span);
    }

    /// Record one backward-execution interval for `worker`.
    pub fn record_backward(&mut self, worker: usize, span: (f64, f64)) {
        if self.backward.len() <= worker {
            self.backward.resize(worker + 1, Vec::new());
        }
        self.backward[worker].push(span);
    }

    /// Peak number of workers whose forward executions were in flight
    /// at the same wall-clock instant (half-open intervals: a span
    /// ending exactly when another starts does not overlap). ≥ 2 means
    /// per-worker contexts genuinely ran concurrently; 1 means every
    /// execution serialized (the shared-session behavior); 0 means no
    /// spans were recorded.
    pub fn max_concurrent_forward(&self) -> usize {
        let mut events: Vec<(f64, i32)> = Vec::new();
        for spans in &self.forward {
            for &(s, e) in spans {
                events.push((s, 1));
                events.push((e, -1));
            }
        }
        // Sort by time; at ties, close intervals before opening new ones
        // (half-open semantics).
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut cur = 0i32;
        let mut peak = 0i32;
        for (_, d) in events {
            cur += d;
            peak = peak.max(cur);
        }
        peak.max(0) as usize
    }

    /// The backward-vs-forward overlap sweep: number of (backward of
    /// batch `i`, forward of batch `j > i`) span pairs that genuinely
    /// overlapped in wall clock, across any pair of workers. Zero under
    /// the synchronous protocol (`train.staleness = 0`: every backward
    /// of batch `i` completes before any batch `i+1` forward is
    /// released); ≥ 1 is the evidence the staleness window let a later
    /// forward run under an in-flight backward.
    pub fn backward_overlapping_later_forward(&self) -> usize {
        let mut pairs = 0;
        for bw in &self.backward {
            for (i, &b) in bw.iter().enumerate() {
                for fw in &self.forward {
                    pairs += fw.iter().skip(i + 1).filter(|&&f| spans_overlap(b, f)).count();
                }
            }
        }
        pairs
    }

    /// Forward spans of *different batches* in flight together (any
    /// pair of workers). Impossible at `train.staleness <= 1` — the
    /// leader releases batch `i+1` only after every batch-`i` forward
    /// landed — and the overlap evidence for deeper windows, where the
    /// engine's fused forward is the only execution stage (vanilla).
    pub fn cross_batch_forward_overlap(&self) -> usize {
        let mut pairs = 0;
        for f1 in &self.forward {
            for (i, &a) in f1.iter().enumerate() {
                for f2 in &self.forward {
                    // j > i counts each unordered cross-batch pair once.
                    pairs += f2.iter().skip(i + 1).filter(|&&b| spans_overlap(a, b)).count();
                }
            }
        }
        pairs
    }

    /// Fold another epoch's spans in (per worker, appended). The
    /// appended spans are shifted past this clock's latest end so
    /// intervals from different epochs — which share a per-epoch
    /// timebase — can never spuriously count as concurrent.
    pub fn merge(&mut self, other: &WallClock) {
        let offset = self
            .forward
            .iter()
            .chain(self.backward.iter())
            .flatten()
            .map(|&(_, e)| e)
            .fold(0.0f64, f64::max);
        if self.forward.len() < other.forward.len() {
            self.forward.resize(other.forward.len(), Vec::new());
        }
        if self.backward.len() < other.backward.len() {
            self.backward.resize(other.backward.len(), Vec::new());
        }
        for (mine, theirs) in self.forward.iter_mut().zip(&other.forward) {
            mine.extend(theirs.iter().map(|&(s, e)| (s + offset, e + offset)));
        }
        for (mine, theirs) in self.backward.iter_mut().zip(&other.backward) {
            mine.extend(theirs.iter().map(|&(s, e)| (s + offset, e + offset)));
        }
    }
}

/// Leader-phase structure of the bounded-staleness schedule
/// ([`EpochTimeline::async_pipelined_time`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AsyncShape {
    /// Gather partials → leader step → scatter gradients → worker
    /// backwards → update; backwards gate on the scatter.
    Raf,
    /// Fused worker step → all-reduce → update; the update waits for
    /// the marshal completion of every released batch (store barrier).
    Vanilla,
}

/// Modeled per-worker durations for one batch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WorkerSpan {
    /// Neighbor sampling (prefetchable).
    pub sample_s: f64,
    /// Cache fetch of read-only feature rows (prefetchable).
    pub fetch_ro_s: f64,
    /// Cache fetch of learnable rows (must follow the previous update).
    pub fetch_lr_s: f64,
    /// Input marshalling / H2D copy.
    pub copy_s: f64,
    /// Worker forward artifact execution.
    pub fwd_s: f64,
    /// Worker backward artifact execution + gradient extraction.
    pub bwd_s: f64,
}

impl WorkerSpan {
    /// Work that the pipeline may run ahead for the next batch.
    pub fn prefetchable_s(&self) -> f64 {
        self.sample_s + self.fetch_ro_s
    }

    /// Work bound to the batch's execution slot.
    pub fn exec_fwd_s(&self) -> f64 {
        self.fetch_lr_s + self.copy_s + self.fwd_s
    }

    pub fn total_s(&self) -> f64 {
        self.prefetchable_s() + self.exec_fwd_s() + self.bwd_s
    }
}

/// Modeled leader-side durations for one batch (between the workers'
/// forward and backward phases, plus the post-backward update).
#[derive(Debug, Clone, Copy, Default)]
pub struct LeaderSpan {
    /// Gather of worker partials at the leader (RAF) or the dense
    /// gradient all-reduce (vanilla).
    pub gather_s: f64,
    /// Leader artifact execution (cross-relation agg + head + loss).
    pub leader_s: f64,
    /// Scatter of gradients back to the workers.
    pub scatter_s: f64,
    /// Weight / learnable-feature updates closing the batch.
    pub update_s: f64,
    /// Replica gradient synchronization.
    pub sync_s: f64,
}

impl LeaderSpan {
    /// The window overlapping the workers' prefetch of batch `i+1`.
    pub fn mid_s(&self) -> f64 {
        self.gather_s + self.leader_s + self.scatter_s
    }

    pub fn total_s(&self) -> f64 {
        self.mid_s() + self.update_s + self.sync_s
    }
}

/// One batch: per-worker spans plus the leader phase.
#[derive(Debug, Clone, Default)]
pub struct BatchSpans {
    pub workers: Vec<WorkerSpan>,
    pub leader: LeaderSpan,
}

/// The event timeline of a whole epoch.
#[derive(Debug, Clone, Default)]
pub struct EpochTimeline {
    pub workers: usize,
    pub batches: Vec<BatchSpans>,
}

fn max_over(xs: impl Iterator<Item = f64>) -> f64 {
    xs.fold(0.0, f64::max)
}

impl EpochTimeline {
    pub fn new(workers: usize) -> EpochTimeline {
        EpochTimeline {
            workers,
            batches: Vec::new(),
        }
    }

    /// Record one batch. `workers` must have one span per worker;
    /// short rows are padded with zero spans (defensive, not expected).
    pub fn push_batch(&mut self, mut workers: Vec<WorkerSpan>, leader: LeaderSpan) {
        workers.resize(self.workers, WorkerSpan::default());
        self.batches.push(BatchSpans { workers, leader });
    }

    /// No-overlap accounting: per batch, slowest worker forward phase
    /// (including its prefetchable work), leader phases, slowest
    /// backward, summed over batches.
    pub fn sequential_time(&self) -> f64 {
        let mut t = 0.0;
        for b in &self.batches {
            t += max_over(b.workers.iter().map(|w| w.prefetchable_s() + w.exec_fwd_s()));
            t += b.leader.mid_s();
            t += max_over(b.workers.iter().map(|w| w.bwd_s));
            t += b.leader.update_s + b.leader.sync_s;
        }
        t
    }

    /// Double-buffered schedule: worker `w` prefetches batch `i+1`
    /// (sampling + read-only fetch) immediately after shipping its
    /// batch-`i` partials, concurrently with the leader's
    /// gather → leader-step → scatter. Forward execution of batch `i`
    /// still waits for batch `i-1`'s update (weights/learnable rows
    /// must be current — the equivalence contract), so the speedup
    /// comes exactly from hiding prefetch work inside the leader phase.
    pub fn pipelined_time(&self) -> f64 {
        let n = self.batches.len();
        if n == 0 {
            return 0.0;
        }
        // pf_done[w]: when w's prefetch for the *current* batch is done.
        let mut pf_done: Vec<f64> = self.batches[0]
            .workers
            .iter()
            .map(|w| w.prefetchable_s())
            .collect();
        let mut ready = 0.0f64; // params for the current batch are current
        for (i, b) in self.batches.iter().enumerate() {
            let fwd_done: Vec<f64> = b
                .workers
                .iter()
                .zip(&pf_done)
                .map(|(w, &pf)| pf.max(ready) + w.exec_fwd_s())
                .collect();
            let scatter_done = max_over(fwd_done.iter().copied()) + b.leader.mid_s();
            // Prefetch of batch i+1 starts right after each worker's send.
            if i + 1 < n {
                for (w, (&fd, span)) in fwd_done
                    .iter()
                    .zip(&self.batches[i + 1].workers)
                    .enumerate()
                {
                    pf_done[w] = fd + span.prefetchable_s();
                }
            }
            let bwd_done = b.workers.iter().enumerate().map(|(w, span)| {
                let free = if i + 1 < n { pf_done[w] } else { fwd_done[w] };
                free.max(scatter_done) + span.bwd_s
            });
            ready = max_over(bwd_done) + b.leader.update_s + b.leader.sync_s;
        }
        ready
    }

    /// The bounded-staleness (async 1F1B) schedule with `staleness = k
    /// >= 1` in-flight batches (PR 4): the leader broadcasts batch
    /// `i+k`'s release right after gathering batch `i`'s results, so
    /// workers marshal+execute batch `i+k`'s forward — against a
    /// snapshot missing at most `k` updates — while batch `i` is still
    /// in its leader/backward/update phases. Workers process releases
    /// and gradient scatters in the leader's deterministic send order
    /// (forward of `i+k`, then backward of `i` — the 1F1B
    /// interleaving), so the schedule is, like [`Self::pipelined_time`],
    /// a pure function of the recorded durations.
    ///
    /// `shape` selects the leader-phase structure: [`AsyncShape::Raf`]
    /// gates each backward on the leader's gather → step → scatter,
    /// while [`AsyncShape::Vanilla`] has no separate backward and
    /// instead delays each update behind the *marshal* completion of
    /// every released batch — the store barrier that keeps feature-row
    /// reads deterministic under the window.
    pub fn async_pipelined_time(&self, staleness: usize, shape: AsyncShape) -> f64 {
        let k = staleness.max(1);
        let n = self.batches.len();
        if n == 0 {
            return 0.0;
        }
        let nw = self.workers;
        // Every worker sees the same arrival order of leader messages:
        // the k primed releases, then per leader batch i the release of
        // i+k followed (RAF) by batch i's gradient scatter.
        #[derive(Clone, Copy)]
        enum Task {
            Fwd(usize),
            Bwd(usize),
        }
        let raf = matches!(shape, AsyncShape::Raf);
        let mut tasks: Vec<Task> = Vec::new();
        let mut fwd_idx = vec![0usize; n];
        let mut bwd_idx = vec![0usize; n];
        for j in 0..k.min(n) {
            fwd_idx[j] = tasks.len();
            tasks.push(Task::Fwd(j));
        }
        for i in 0..n {
            if i + k < n {
                fwd_idx[i + k] = tasks.len();
                tasks.push(Task::Fwd(i + k));
            }
            if raf {
                bwd_idx[i] = tasks.len();
                tasks.push(Task::Bwd(i));
            }
        }

        let mut ready_t = vec![0.0f64; n]; // batches 0..k primed at 0
        let mut grads_t = vec![0.0f64; n];
        let mut wfree = vec![0.0f64; nw];
        let mut mdone = vec![vec![0.0f64; nw]; n]; // marshal (sample+fetch+copy) done
        let mut fdone = vec![vec![0.0f64; nw]; n];
        let mut bdone = vec![vec![0.0f64; nw]; n];
        let mut cursor = vec![0usize; nw];
        // Advance every worker through its task list up to and
        // including `target` (gates for that prefix are already known).
        let advance = |target: usize,
                       wfree: &mut [f64],
                       cursor: &mut [usize],
                       mdone: &mut [Vec<f64>],
                       fdone: &mut [Vec<f64>],
                       bdone: &mut [Vec<f64>],
                       ready_t: &[f64],
                       grads_t: &[f64]| {
            for w in 0..nw {
                while cursor[w] <= target {
                    match tasks[cursor[w]] {
                        Task::Fwd(j) => {
                            let s = &self.batches[j].workers[w];
                            let start = wfree[w].max(ready_t[j]);
                            mdone[j][w] =
                                start + s.sample_s + s.fetch_ro_s + s.fetch_lr_s + s.copy_s;
                            fdone[j][w] = mdone[j][w] + s.fwd_s;
                            wfree[w] = fdone[j][w];
                        }
                        Task::Bwd(i) => {
                            let s = &self.batches[i].workers[w];
                            bdone[i][w] = wfree[w].max(grads_t[i]) + s.bwd_s;
                            wfree[w] = bdone[i][w];
                        }
                    }
                    cursor[w] += 1;
                }
            }
        };

        let mut lfree = 0.0f64;
        for i in 0..n {
            let b = &self.batches[i];
            advance(
                fwd_idx[i], &mut wfree, &mut cursor, &mut mdone, &mut fdone, &mut bdone,
                &ready_t, &grads_t,
            );
            let gstart = fdone[i].iter().copied().fold(lfree, f64::max);
            lfree = gstart + b.leader.gather_s;
            if i + k < n {
                ready_t[i + k] = lfree;
            }
            if raf {
                lfree += b.leader.leader_s + b.leader.scatter_s;
                grads_t[i] = lfree;
                advance(
                    bwd_idx[i], &mut wfree, &mut cursor, &mut mdone, &mut fdone, &mut bdone,
                    &ready_t, &grads_t,
                );
                let ustart = bdone[i].iter().copied().fold(lfree, f64::max);
                lfree = ustart + b.leader.update_s + b.leader.sync_s;
            } else {
                // Store barrier: the update may not write feature rows
                // until every released batch finished marshalling.
                let last = (i + k).min(n - 1);
                advance(
                    fwd_idx[last], &mut wfree, &mut cursor, &mut mdone, &mut fdone,
                    &mut bdone, &ready_t, &grads_t,
                );
                let mut ustart = lfree;
                for md in mdone.iter().take(last + 1) {
                    ustart = md.iter().copied().fold(ustart, f64::max);
                }
                lfree = ustart + b.leader.update_s + b.leader.sync_s;
            }
        }
        lfree
    }

    /// Seconds the pipeline hides relative to sequential execution.
    pub fn overlap_saving_s(&self) -> f64 {
        (self.sequential_time() - self.pipelined_time()).max(0.0)
    }

    /// Total busy seconds per worker (sum of that worker's spans).
    pub fn worker_busy_s(&self) -> Vec<f64> {
        let mut busy = vec![0.0f64; self.workers];
        for b in &self.batches {
            for (w, span) in b.workers.iter().enumerate() {
                busy[w] += span.total_s();
            }
        }
        busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn span(sample: f64, fwd: f64, bwd: f64) -> WorkerSpan {
        WorkerSpan {
            sample_s: sample,
            fetch_ro_s: sample * 0.1,
            fetch_lr_s: sample * 0.05,
            copy_s: 0.01,
            fwd_s: fwd,
            bwd_s: bwd,
        }
    }

    fn leader(mid: f64, upd: f64) -> LeaderSpan {
        LeaderSpan {
            gather_s: mid * 0.2,
            leader_s: mid * 0.6,
            scatter_s: mid * 0.2,
            update_s: upd,
            sync_s: 0.0,
        }
    }

    fn tl(batches: usize, workers: usize, seed: u64) -> EpochTimeline {
        let mut rng = Rng::new(seed);
        let mut t = EpochTimeline::new(workers);
        for _ in 0..batches {
            let spans: Vec<WorkerSpan> = (0..workers)
                .map(|_| span(rng.f64() * 0.2, rng.f64() * 0.1, rng.f64() * 0.1))
                .collect();
            t.push_batch(spans, leader(rng.f64() * 0.3, rng.f64() * 0.02));
        }
        t
    }

    #[test]
    fn pipelined_never_exceeds_sequential() {
        for seed in 0..50 {
            let t = tl(1 + (seed as usize % 7), 1 + (seed as usize % 4), seed);
            let seq = t.sequential_time();
            let pipe = t.pipelined_time();
            assert!(
                pipe <= seq + 1e-12,
                "pipelined {pipe} > sequential {seq} (seed {seed})"
            );
        }
    }

    #[test]
    fn overlap_hides_prefetch_inside_leader_phase() {
        // Two identical batches: prefetch (0.1s) fits inside the leader
        // phase (0.3s), so the pipeline saves exactly one prefetch per
        // overlapped batch boundary.
        let mut t = EpochTimeline::new(2);
        let w = WorkerSpan {
            sample_s: 0.1,
            fwd_s: 0.2,
            bwd_s: 0.1,
            ..Default::default()
        };
        let l = LeaderSpan {
            leader_s: 0.3,
            update_s: 0.05,
            ..Default::default()
        };
        t.push_batch(vec![w, w], l);
        t.push_batch(vec![w, w], l);
        let seq = t.sequential_time();
        let pipe = t.pipelined_time();
        assert!((seq - 2.0 * (0.1 + 0.2 + 0.3 + 0.1 + 0.05)).abs() < 1e-12);
        // Batch 1's 0.1s sample is fully hidden under batch 0's leader phase.
        assert!((seq - pipe - 0.1).abs() < 1e-12, "seq {seq} pipe {pipe}");
        assert!(pipe < seq);
    }

    #[test]
    fn single_batch_has_no_overlap() {
        let t = tl(1, 3, 9);
        assert!((t.sequential_time() - t.pipelined_time()).abs() < 1e-12);
    }

    #[test]
    fn long_prefetch_degrades_gracefully() {
        // Prefetch longer than the leader phase: pipeline stalls on it
        // but still beats sequential (partial hiding).
        let mut t = EpochTimeline::new(1);
        let w = WorkerSpan {
            sample_s: 0.5,
            fwd_s: 0.1,
            bwd_s: 0.1,
            ..Default::default()
        };
        let l = LeaderSpan {
            leader_s: 0.2,
            ..Default::default()
        };
        t.push_batch(vec![w], l);
        t.push_batch(vec![w], l);
        let seq = t.sequential_time();
        let pipe = t.pipelined_time();
        // Only 0.2s of the 0.5s prefetch hides per boundary.
        assert!((seq - pipe - 0.2).abs() < 1e-12, "seq {seq} pipe {pipe}");
    }

    #[test]
    fn worker_busy_accounts_all_spans() {
        let t = tl(4, 3, 11);
        let busy = t.worker_busy_s();
        assert_eq!(busy.len(), 3);
        assert!(busy.iter().all(|&b| b > 0.0));
    }

    /// 1 worker, `n` identical batches: fwd 1s, bwd 1s, leader step 1s,
    /// nothing else. Synchronous cost is 3s per batch; the k=1 window
    /// hides the leader step of each non-final batch under the next
    /// forward (and vice versa).
    fn raf_unit_tl(n: usize) -> EpochTimeline {
        let mut t = EpochTimeline::new(1);
        for _ in 0..n {
            t.push_batch(
                vec![WorkerSpan { fwd_s: 1.0, bwd_s: 1.0, ..Default::default() }],
                LeaderSpan { leader_s: 1.0, ..Default::default() },
            );
        }
        t
    }

    #[test]
    fn async_raf_hides_leader_phase_under_next_forward() {
        let t = raf_unit_tl(3);
        assert!((t.sequential_time() - 9.0).abs() < 1e-12);
        assert!((t.pipelined_time() - 9.0).abs() < 1e-12, "no prefetchable work");
        // Hand-simulated: fwd(i+1) released at gather(i), so each of the
        // two overlapped boundaries saves exactly the 1s leader step.
        let a1 = t.async_pipelined_time(1, AsyncShape::Raf);
        assert!((a1 - 7.0).abs() < 1e-12, "async k=1 expected 7s, got {a1}");
        assert!(a1 < t.pipelined_time());
    }

    #[test]
    fn async_vanilla_hides_update_behind_next_step() {
        // 1 worker, 3 batches: fused step 1s, all-reduce (gather) 1s,
        // update 1s. The k=1 window overlaps each non-final update with
        // the next step's execution (the marshal barrier costs nothing
        // here: marshal time is zero).
        let mut t = EpochTimeline::new(1);
        for _ in 0..3 {
            t.push_batch(
                vec![WorkerSpan { fwd_s: 1.0, ..Default::default() }],
                LeaderSpan { gather_s: 1.0, update_s: 1.0, ..Default::default() },
            );
        }
        assert!((t.sequential_time() - 9.0).abs() < 1e-12);
        let a1 = t.async_pipelined_time(1, AsyncShape::Vanilla);
        assert!((a1 - 7.0).abs() < 1e-12, "async k=1 expected 7s, got {a1}");
    }

    #[test]
    fn async_marshal_barrier_delays_update() {
        // Same vanilla shape but each step spends 0.5s marshalling
        // (copy): update(i) must wait for batch i+1's marshal to finish
        // (the store barrier), so only part of the update window hides.
        let mut t = EpochTimeline::new(1);
        for _ in 0..2 {
            t.push_batch(
                vec![WorkerSpan { copy_s: 0.5, fwd_s: 1.0, ..Default::default() }],
                LeaderSpan { gather_s: 1.0, update_s: 1.0, ..Default::default() },
            );
        }
        // By hand: f0 [0, 1.5] (marshal done 0.5); gather(0) [1.5, 2.5];
        // release(1) at 2.5; f1 marshal [2.5, 3.0], exec done 4.0;
        // update(0) waits marshal(1) = 3.0 -> done 4.0; gather(1)
        // [4.5? no: max(lfree 4.0, fdone1 4.0) = 4.0 -> 5.0]; update(1)
        // -> 6.0.
        let a1 = t.async_pipelined_time(1, AsyncShape::Vanilla);
        assert!((a1 - 6.0).abs() < 1e-12, "expected 6s, got {a1}");
        assert!(a1 < t.sequential_time());
    }

    #[test]
    fn async_never_exceeds_sequential_on_random_timelines() {
        for seed in 0..40 {
            let t = tl(1 + (seed as usize % 6), 1 + (seed as usize % 3), 100 + seed);
            let seq = t.sequential_time();
            for k in 1..=3 {
                for shape in [AsyncShape::Raf, AsyncShape::Vanilla] {
                    let a = t.async_pipelined_time(k, shape);
                    assert!(
                        a <= seq + 1e-9,
                        "async k={k} {shape:?} {a} > sequential {seq} (seed {seed})"
                    );
                }
            }
        }
    }

    #[test]
    fn async_window_larger_than_epoch_is_safe() {
        let t = raf_unit_tl(2);
        let a = t.async_pipelined_time(10, AsyncShape::Raf);
        assert!(a > 0.0 && a <= t.sequential_time() + 1e-12);
        assert_eq!(EpochTimeline::new(2).async_pipelined_time(1, AsyncShape::Raf), 0.0);
    }

    #[test]
    fn wall_clock_counts_concurrent_forwards() {
        let mut w = WallClock::new(3);
        assert_eq!(w.max_concurrent_forward(), 0);
        // Serialized: back-to-back half-open intervals never overlap.
        w.record_forward(0, (0.0, 1.0));
        w.record_forward(1, (1.0, 2.0));
        w.record_forward(2, (2.0, 3.0));
        assert_eq!(w.max_concurrent_forward(), 1);
        // Overlap: worker 1's next span starts inside worker 0's.
        w.record_forward(0, (10.0, 12.0));
        w.record_forward(1, (11.0, 13.0));
        assert_eq!(w.max_concurrent_forward(), 2);
        w.record_forward(2, (11.5, 11.6));
        assert_eq!(w.max_concurrent_forward(), 3);
    }

    #[test]
    fn wall_clock_merge_never_crosses_epochs() {
        let mut a = WallClock::new(2);
        a.record_forward(0, (0.0, 1.0));
        let mut b = WallClock::new(2);
        b.record_forward(1, (0.2, 0.8)); // would overlap a's span naively
        a.merge(&b);
        assert_eq!(a.max_concurrent_forward(), 1, "epochs must not overlap");
        assert_eq!(a.forward[1], vec![(1.2, 1.8)]);
    }
}
