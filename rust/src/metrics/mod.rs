//! Training-stage metrics matching the paper's breakdowns (Figs. 4 & 10):
//! sampling, feature fetching, data copy, forward, backward, gradient
//! sync, and (learnable-)feature/model update. Each engine accumulates
//! per-stage simulated seconds; reports render the same rows the paper
//! plots.
//!
//! [`timeline`] adds the per-worker event timeline both runtimes fill,
//! from which [`EpochReport::critical_path_s`] (max-over-workers,
//! overlap-aware) is derived alongside the classic summed epoch time.

pub mod timeline;

/// The training stages of Fig. 3 / Fig. 10.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Sample,
    Fetch,
    Copy,
    Forward,
    Backward,
    GradSync,
    Update,
}

pub const STAGES: [Stage; 7] = [
    Stage::Sample,
    Stage::Fetch,
    Stage::Copy,
    Stage::Forward,
    Stage::Backward,
    Stage::GradSync,
    Stage::Update,
];

impl Stage {
    pub fn index(self) -> usize {
        match self {
            Stage::Sample => 0,
            Stage::Fetch => 1,
            Stage::Copy => 2,
            Stage::Forward => 3,
            Stage::Backward => 4,
            Stage::GradSync => 5,
            Stage::Update => 6,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Stage::Sample => "sample",
            Stage::Fetch => "fetch",
            Stage::Copy => "copy",
            Stage::Forward => "forward",
            Stage::Backward => "backward",
            Stage::GradSync => "grad_sync",
            Stage::Update => "update",
        }
    }
}

/// Per-stage accumulated time (seconds, simulated clock).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageTimes {
    pub secs: [f64; 7],
}

impl StageTimes {
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage.index()] += secs;
    }

    pub fn get(&self, stage: Stage) -> f64 {
        self.secs[stage.index()]
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    pub fn merge(&mut self, other: &StageTimes) {
        for i in 0..7 {
            self.secs[i] += other.secs[i];
        }
    }

    /// Percentage breakdown (sums to ~100).
    pub fn percentages(&self) -> Vec<(Stage, f64)> {
        let total = self.total().max(1e-30);
        STAGES
            .iter()
            .map(|&s| (s, self.get(s) / total * 100.0))
            .collect()
    }

    pub fn report_rows(&self) -> Vec<Vec<String>> {
        self.percentages()
            .iter()
            .map(|(s, pct)| {
                vec![
                    s.name().to_string(),
                    crate::util::fmt_secs(self.get(*s)),
                    format!("{pct:.1}%"),
                ]
            })
            .collect()
    }
}

/// Result of one training epoch under either engine.
#[derive(Debug, Clone, Default)]
pub struct EpochReport {
    /// Classic no-overlap accounting (per batch: slowest worker forward,
    /// leader phases, slowest worker backward — summed).
    pub epoch_time_s: f64,
    /// Overlap-aware critical path from the per-worker event timeline:
    /// max-over-workers with the double-buffered prefetch schedule. For
    /// the sequential runtime this equals `epoch_time_s`; the pipelined
    /// cluster runtime reports the (lower) pipelined schedule.
    pub critical_path_s: f64,
    /// Busy seconds per worker (sum of that worker's stage spans).
    pub worker_busy_s: Vec<f64>,
    /// Per-worker stage breakdown (worker-side stages only: sample /
    /// fetch / copy / forward / backward), so forward-stage overlap is
    /// inspectable per worker without reading the timeline. Leader-side
    /// phases (gather, leader step, updates, sync) appear only in the
    /// global `stages`.
    pub worker_stages: Vec<StageTimes>,
    /// Wall-clock forward-execution spans per worker, recorded by both
    /// runtimes. ≥ 2 concurrent spans is the per-worker-context overlap
    /// evidence (cluster runtime, default config); the sequential
    /// runtime and the `shared_session` escape hatch serialize at 1.
    pub wall: timeline::WallClock,
    pub stages: StageTimes,
    pub comm: crate::comm::Ledger,
    /// Feature rows/bytes fetched from the KV store during input builds
    /// (fetch-stage builds only; backward rebuilds are excluded). With
    /// `train.dedup_fetch` on these count **unique** rows per batch —
    /// the A/B lever the dedup-gather bench asserts on.
    pub fetch: crate::kvstore::FetchStats,
    /// Bytes the harness transport actually moved this epoch (the
    /// leader node's frames: real codec bytes next to the modeled
    /// [`Wire::wire_bytes`](crate::cluster::mailbox::Wire) of the same
    /// messages). All-zero for in-process transports, which move no
    /// bytes; the modeled system's volumes stay in `comm` either way.
    pub wire: crate::net::WireTraffic,
    pub loss_mean: f64,
    pub accuracy: f64,
    pub batches: usize,
    /// Per-batch training losses in batch order (the leader's loss for
    /// RAF, the worker mean for vanilla). This is what the equivalence
    /// harness diffs: when two configurations diverge, the *first
    /// diverging batch index* localizes the fault far better than an
    /// epoch-mean mismatch.
    pub batch_losses: Vec<f64>,
    /// The flight recorder's view of the epoch: every rank's trace
    /// tracks plus the merged metrics snapshot (empty unless
    /// `train.trace` armed recording). Exported as Chrome trace JSON
    /// by `--trace out.json`.
    pub obs: crate::obs::ObsReport,
}

impl EpochReport {
    /// The report of an epoch with no full batch to train on (the
    /// ragged-tail filter consumed everything): zero times, NaN loss,
    /// per-worker vectors sized for `workers`.
    pub fn empty(workers: usize) -> EpochReport {
        EpochReport {
            worker_busy_s: vec![0.0; workers],
            worker_stages: vec![StageTimes::default(); workers],
            wall: timeline::WallClock::new(workers),
            loss_mean: f64::NAN,
            accuracy: f64::NAN,
            ..Default::default()
        }
    }

    /// Fold another epoch's report into this one (totals accumulate;
    /// loss/accuracy take the latest epoch's value).
    pub fn absorb(&mut self, rep: &EpochReport) {
        self.epoch_time_s += rep.epoch_time_s;
        self.critical_path_s += rep.critical_path_s;
        if self.worker_busy_s.len() < rep.worker_busy_s.len() {
            self.worker_busy_s.resize(rep.worker_busy_s.len(), 0.0);
        }
        for (b, r) in self.worker_busy_s.iter_mut().zip(&rep.worker_busy_s) {
            *b += r;
        }
        if self.worker_stages.len() < rep.worker_stages.len() {
            self.worker_stages
                .resize_with(rep.worker_stages.len(), StageTimes::default);
        }
        for (s, r) in self.worker_stages.iter_mut().zip(&rep.worker_stages) {
            s.merge(r);
        }
        self.wall.merge(&rep.wall);
        self.stages.merge(&rep.stages);
        self.comm.merge(&rep.comm);
        self.fetch.merge(rep.fetch);
        self.wire.merge(&rep.wire);
        // Latest-epoch semantics for loss/accuracy — but an *empty*
        // epoch (ragged tail: zero batches, NaN loss) must not clobber
        // a real trajectory.
        if rep.batches > 0 {
            self.loss_mean = rep.loss_mean;
            self.accuracy = rep.accuracy;
        }
        self.batches += rep.batches;
        self.batch_losses.extend_from_slice(&rep.batch_losses);
        self.obs.merge(&rep.obs);
    }

    pub fn print(&self, label: &str) {
        println!(
            "[{label}] epoch {} (critical path {}) | loss {:.4} acc {:.3} | batches {}",
            crate::util::fmt_secs(self.epoch_time_s),
            crate::util::fmt_secs(self.critical_path_s),
            self.loss_mean,
            self.accuracy,
            self.batches
        );
        for row in self.stages.report_rows() {
            println!("    {:<10} {:>12} {:>7}", row[0], row[1], row[2]);
        }
        println!(
            "    fetch: {} rows ({}), {} remote rows ({})",
            self.fetch.rows,
            crate::util::fmt_bytes(self.fetch.bytes),
            self.fetch.remote_rows,
            crate::util::fmt_bytes(self.fetch.remote_bytes),
        );
        println!(
            "    comm: net {} | pcie {} | dram {} | p2p {}",
            crate::util::fmt_bytes(self.comm.bytes[0]),
            crate::util::fmt_bytes(self.comm.bytes[1]),
            crate::util::fmt_bytes(self.comm.bytes[2]),
            crate::util::fmt_bytes(self.comm.bytes[3]),
        );
        if self.wire.frames() > 0 {
            println!(
                "    wire: real {} out / {} in ({} frames) | modeled {} out / {} in",
                crate::util::fmt_bytes(self.wire.real_sent),
                crate::util::fmt_bytes(self.wire.real_recv),
                self.wire.frames(),
                crate::util::fmt_bytes(self.wire.modeled_sent),
                crate::util::fmt_bytes(self.wire.modeled_recv),
            );
            // Per-lane split (PR 8): mesh bytes are a subset of the
            // real totals, so the leader-star share is the difference.
            if self.wire.mesh_sent > 0 || self.wire.mesh_recv > 0 {
                println!(
                    "    wire lanes: star {} out / {} in | mesh {} out / {} in",
                    crate::util::fmt_bytes(self.wire.real_sent - self.wire.mesh_sent),
                    crate::util::fmt_bytes(self.wire.real_recv - self.wire.mesh_recv),
                    crate::util::fmt_bytes(self.wire.mesh_sent),
                    crate::util::fmt_bytes(self.wire.mesh_recv),
                );
            }
        }
        if !self.worker_busy_s.is_empty() {
            let rows: Vec<String> = self
                .worker_busy_s
                .iter()
                .enumerate()
                .map(|(w, &b)| {
                    let detail = self
                        .worker_stages
                        .get(w)
                        .map(|s| {
                            format!(
                                " (fwd {}, bwd {})",
                                crate::util::fmt_secs(s.get(Stage::Forward)),
                                crate::util::fmt_secs(s.get(Stage::Backward)),
                            )
                        })
                        .unwrap_or_default();
                    format!("w{w} {}{detail}", crate::util::fmt_secs(b))
                })
                .collect();
            println!("    workers: {}", rows.join(" | "));
        }
        let peak = self.wall.max_concurrent_forward();
        if peak > 0 {
            println!("    forward overlap: up to {peak} worker(s) concurrent");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_accumulation_and_percentages() {
        let mut st = StageTimes::default();
        st.add(Stage::Sample, 1.0);
        st.add(Stage::Fetch, 3.0);
        st.add(Stage::Sample, 1.0);
        assert_eq!(st.get(Stage::Sample), 2.0);
        assert_eq!(st.total(), 5.0);
        let pct = st.percentages();
        assert!((pct[0].1 - 40.0).abs() < 1e-9);
        assert!((pct[1].1 - 60.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_componentwise() {
        let mut a = StageTimes::default();
        a.add(Stage::Forward, 1.0);
        let mut b = StageTimes::default();
        b.add(Stage::Forward, 2.0);
        b.add(Stage::Update, 4.0);
        a.merge(&b);
        assert_eq!(a.get(Stage::Forward), 3.0);
        assert_eq!(a.get(Stage::Update), 4.0);
    }

    #[test]
    fn absorb_accumulates_totals_and_tracks_latest_loss() {
        let mut total = EpochReport::default();
        let mut a = EpochReport::default();
        a.epoch_time_s = 2.0;
        a.critical_path_s = 1.5;
        a.worker_busy_s = vec![1.0, 0.5];
        a.loss_mean = 3.0;
        a.batches = 4;
        total.absorb(&a);
        a.loss_mean = 2.0;
        total.absorb(&a);
        assert_eq!(total.epoch_time_s, 4.0);
        assert_eq!(total.critical_path_s, 3.0);
        assert_eq!(total.worker_busy_s, vec![2.0, 1.0]);
        assert_eq!(total.loss_mean, 2.0);
        assert_eq!(total.batches, 8);
    }

    #[test]
    fn absorb_merges_every_field() {
        // Satellite audit (PR 6): every field added since PR 3 —
        // `wall`, `wire`, `worker_stages`, `batch_losses`, and now
        // `obs` — must merge, not get dropped or overwritten.
        let mut a = EpochReport::empty(1);
        a.epoch_time_s = 2.0;
        a.critical_path_s = 1.5;
        a.worker_busy_s = vec![1.0];
        a.worker_stages[0].add(Stage::Forward, 0.5);
        a.wall.record_forward(0, (0.0, 1.0));
        a.stages.add(Stage::Sample, 0.25);
        a.fetch.rows = 10;
        a.fetch.bytes = 400;
        a.wire.real_sent = 100;
        a.wire.frames_sent = 3;
        a.wire.mesh_sent = 40;
        a.loss_mean = 3.0;
        a.accuracy = 0.5;
        a.batches = 2;
        a.batch_losses = vec![3.5, 2.5];
        a.obs.metrics.counters.push(("wire.lane0.tx_bytes".to_string(), 7));

        // Second epoch: wider (2 workers) and with a trace track.
        let mut b = EpochReport::empty(2);
        b.epoch_time_s = 1.0;
        b.critical_path_s = 0.5;
        b.worker_busy_s = vec![0.25, 0.75];
        b.worker_stages[1].add(Stage::Backward, 0.125);
        b.wall.record_forward(1, (2.0, 3.0));
        b.stages.add(Stage::Update, 0.0625);
        b.fetch.rows = 5;
        b.fetch.bytes = 200;
        b.wire.real_recv = 50;
        b.wire.frames_recv = 2;
        b.wire.mesh_recv = 15;
        b.loss_mean = 2.0;
        b.accuracy = 0.75;
        b.batches = 1;
        b.batch_losses = vec![2.0];
        b.obs.metrics.counters.push(("wire.lane0.tx_bytes".to_string(), 5));
        b.obs.tracks.push(crate::obs::TraceTrack {
            rank: 1,
            thread: "worker".to_string(),
            ..Default::default()
        });

        let mut total = EpochReport::default();
        total.absorb(&a);
        total.absorb(&b);
        assert_eq!(total.epoch_time_s, 3.0);
        assert_eq!(total.critical_path_s, 2.0);
        assert_eq!(total.worker_busy_s, vec![1.25, 0.75], "absorb must widen worker vectors");
        assert_eq!(total.worker_stages.len(), 2);
        assert_eq!(total.worker_stages[0].get(Stage::Forward), 0.5);
        assert_eq!(total.worker_stages[1].get(Stage::Backward), 0.125);
        assert_eq!(total.wall.forward.len(), 2, "wall clock must widen too");
        assert_eq!(total.wall.forward[0], vec![(0.0, 1.0)]);
        // WallClock::merge shifts absorbed spans past the previous
        // epoch's latest end (1.0 here), so epochs never spuriously
        // overlap: (2.0, 3.0) lands as (3.0, 4.0).
        assert_eq!(total.wall.forward[1], vec![(3.0, 4.0)]);
        assert_eq!(total.stages.get(Stage::Sample), 0.25);
        assert_eq!(total.stages.get(Stage::Update), 0.0625);
        assert_eq!((total.fetch.rows, total.fetch.bytes), (15, 600));
        assert_eq!((total.wire.real_sent, total.wire.real_recv), (100, 50));
        assert_eq!(total.wire.frames(), 5);
        assert_eq!((total.wire.mesh_sent, total.wire.mesh_recv), (40, 15), "mesh split (PR 8)");
        assert_eq!(total.loss_mean, 2.0, "latest epoch's loss");
        assert_eq!(total.accuracy, 0.75);
        assert_eq!(total.batches, 3);
        assert_eq!(total.batch_losses, vec![3.5, 2.5, 2.0]);
        assert_eq!(total.obs.metrics.counter("wire.lane0.tx_bytes"), 12);
        assert_eq!(total.obs.tracks.len(), 1);

        // An empty epoch (ragged tail: NaN loss, zero batches) must not
        // clobber the real trajectory.
        total.absorb(&EpochReport::empty(2));
        assert_eq!(total.loss_mean, 2.0, "empty epoch clobbered loss_mean");
        assert_eq!(total.accuracy, 0.75);
        assert_eq!(total.batches, 3);
    }

    #[test]
    fn stage_names_unique() {
        let names: std::collections::HashSet<_> = STAGES.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), STAGES.len());
    }
}
