//! Training-stage metrics matching the paper's breakdowns (Figs. 4 & 10):
//! sampling, feature fetching, data copy, forward, backward, gradient
//! sync, and (learnable-)feature/model update. Each engine accumulates
//! per-stage simulated seconds; reports render the same rows the paper
//! plots.

/// The training stages of Fig. 3 / Fig. 10.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Sample,
    Fetch,
    Copy,
    Forward,
    Backward,
    GradSync,
    Update,
}

pub const STAGES: [Stage; 7] = [
    Stage::Sample,
    Stage::Fetch,
    Stage::Copy,
    Stage::Forward,
    Stage::Backward,
    Stage::GradSync,
    Stage::Update,
];

impl Stage {
    pub fn index(self) -> usize {
        match self {
            Stage::Sample => 0,
            Stage::Fetch => 1,
            Stage::Copy => 2,
            Stage::Forward => 3,
            Stage::Backward => 4,
            Stage::GradSync => 5,
            Stage::Update => 6,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Stage::Sample => "sample",
            Stage::Fetch => "fetch",
            Stage::Copy => "copy",
            Stage::Forward => "forward",
            Stage::Backward => "backward",
            Stage::GradSync => "grad_sync",
            Stage::Update => "update",
        }
    }
}

/// Per-stage accumulated time (seconds, simulated clock).
#[derive(Debug, Clone, Default)]
pub struct StageTimes {
    pub secs: [f64; 7],
}

impl StageTimes {
    pub fn add(&mut self, stage: Stage, secs: f64) {
        self.secs[stage.index()] += secs;
    }

    pub fn get(&self, stage: Stage) -> f64 {
        self.secs[stage.index()]
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    pub fn merge(&mut self, other: &StageTimes) {
        for i in 0..7 {
            self.secs[i] += other.secs[i];
        }
    }

    /// Percentage breakdown (sums to ~100).
    pub fn percentages(&self) -> Vec<(Stage, f64)> {
        let total = self.total().max(1e-30);
        STAGES
            .iter()
            .map(|&s| (s, self.get(s) / total * 100.0))
            .collect()
    }

    pub fn report_rows(&self) -> Vec<Vec<String>> {
        self.percentages()
            .iter()
            .map(|(s, pct)| {
                vec![
                    s.name().to_string(),
                    crate::util::fmt_secs(self.get(*s)),
                    format!("{pct:.1}%"),
                ]
            })
            .collect()
    }
}

/// Result of one training epoch under either engine.
#[derive(Debug, Clone, Default)]
pub struct EpochReport {
    pub epoch_time_s: f64,
    pub stages: StageTimes,
    pub comm: crate::comm::Ledger,
    pub loss_mean: f64,
    pub accuracy: f64,
    pub batches: usize,
}

impl EpochReport {
    pub fn print(&self, label: &str) {
        println!(
            "[{label}] epoch {} | loss {:.4} acc {:.3} | batches {}",
            crate::util::fmt_secs(self.epoch_time_s),
            self.loss_mean,
            self.accuracy,
            self.batches
        );
        for row in self.stages.report_rows() {
            println!("    {:<10} {:>12} {:>7}", row[0], row[1], row[2]);
        }
        println!(
            "    comm: net {} | pcie {} | dram {} | p2p {}",
            crate::util::fmt_bytes(self.comm.bytes[0]),
            crate::util::fmt_bytes(self.comm.bytes[1]),
            crate::util::fmt_bytes(self.comm.bytes[2]),
            crate::util::fmt_bytes(self.comm.bytes[3]),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_accumulation_and_percentages() {
        let mut st = StageTimes::default();
        st.add(Stage::Sample, 1.0);
        st.add(Stage::Fetch, 3.0);
        st.add(Stage::Sample, 1.0);
        assert_eq!(st.get(Stage::Sample), 2.0);
        assert_eq!(st.total(), 5.0);
        let pct = st.percentages();
        assert!((pct[0].1 - 40.0).abs() < 1e-9);
        assert!((pct[1].1 - 60.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_componentwise() {
        let mut a = StageTimes::default();
        a.add(Stage::Forward, 1.0);
        let mut b = StageTimes::default();
        b.add(Stage::Forward, 2.0);
        b.add(Stage::Update, 4.0);
        a.merge(&b);
        assert_eq!(a.get(Stage::Forward), 3.0);
        assert_eq!(a.get(Stage::Update), 4.0);
    }

    #[test]
    fn stage_names_unique() {
        let names: std::collections::HashSet<_> = STAGES.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), STAGES.len());
    }
}
