//! Synthetic heterogeneous-graph generators.
//!
//! The paper evaluates on ogbn-mag, Freebase, Donor, IGB-HET and MAG240M
//! (Table 1). Those datasets (and the authors' EC2 testbed) are not
//! available here, so `datagen` builds *schema-faithful* synthetic
//! equivalents: identical node/edge-type structure, feature-dimension
//! profiles (including featureless types that get learnable embeddings),
//! target types and class counts, with Zipf-skewed in-degrees (real
//! academic/e-commerce graphs are power-law, which is what drives cache
//! hotness skew, §6). A `scale` knob shrinks node counts so experiments
//! fit the CPU testbed; all *mechanisms* (partitioning, RAF locality,
//! cache behaviour) depend only on schema + skew, which are preserved.

use crate::hetgraph::{HetGraph, NodeId, NodeType, RelCsr, Relation, Schema};
use crate::util::rng::{Rng, Zipf};

/// Dataset presets mirroring paper Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// ogbn-mag: 4 node types, 7 relations, only `paper` featured (128-d),
    /// 349 classes.
    Mag,
    /// Freebase: 8 node types, 64 relations, **no** raw features
    /// (all learnable), 8 classes.
    Freebase,
    /// Donor: 7 node types, 14 relations, all featured with dims 7..789,
    /// 2 classes.
    Donor,
    /// IGB-HET: 4 node types, 7 relations, all featured at 1024-d,
    /// 2983 classes.
    IgbHet,
    /// MAG240M: 3 node types, 5 relations, only `paper` featured (768-d),
    /// 153 classes.
    Mag240m,
}

impl Preset {
    pub fn parse(s: &str) -> Option<Preset> {
        match s {
            "mag" | "ogbn-mag" => Some(Preset::Mag),
            "freebase" => Some(Preset::Freebase),
            "donor" => Some(Preset::Donor),
            "igb-het" | "igb_het" | "igb" => Some(Preset::IgbHet),
            "mag240m" => Some(Preset::Mag240m),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Preset::Mag => "ogbn-mag",
            Preset::Freebase => "freebase",
            Preset::Donor => "donor",
            Preset::IgbHet => "igb-het",
            Preset::Mag240m => "mag240m",
        }
    }
}

fn n(x: f64, scale: f64) -> usize {
    ((x * scale) as usize).max(8)
}

/// Build the schema for a preset at a given scale. `scale` multiplies the
/// paper's node counts (Table 1); e.g. `scale = 1e-4` turns MAG240M's
/// 2.4e8 nodes into 24k.
pub fn schema(preset: Preset, scale: f64) -> Schema {
    match preset {
        Preset::Mag => Schema {
            name: "ogbn-mag".into(),
            node_types: vec![
                NodeType { name: "paper".into(),  count: n(0.74e6, scale), feat_dim: 128, learnable: false },
                NodeType { name: "author".into(), count: n(1.1e6,  scale), feat_dim: 64,  learnable: true },
                NodeType { name: "inst".into(),   count: n(8.7e3,  scale), feat_dim: 64,  learnable: true },
                NodeType { name: "field".into(),  count: n(6.0e4,  scale), feat_dim: 64,  learnable: true },
            ],
            relations: vec![
                Relation { name: "writes".into(),    src: 1, dst: 0, reverse_of: None },
                Relation { name: "cites".into(),     src: 0, dst: 0, reverse_of: None },
                Relation { name: "has_topic_rev".into(), src: 3, dst: 0, reverse_of: None },
                Relation { name: "writes_rev".into(),    src: 0, dst: 1, reverse_of: Some(0) },
                Relation { name: "affiliated".into(),    src: 2, dst: 1, reverse_of: None },
                Relation { name: "affiliated_rev".into(), src: 1, dst: 2, reverse_of: Some(4) },
                Relation { name: "has_topic".into(),     src: 0, dst: 3, reverse_of: Some(2) },
            ],
            target: 0,
            num_classes: 349,
        },
        Preset::Freebase => {
            // 8 types, 64 relations, no raw features anywhere. The 64
            // relations are generated deterministically over the 8 types
            // with the target type (0, "book") reachable.
            let type_names = ["book", "film", "music", "sports", "people", "location", "organization", "business"];
            let counts = [2.0e6, 0.5e6, 3.0e6, 1.0e6, 2.5e6, 1.5e6, 0.8e6, 0.7e6];
            let node_types: Vec<NodeType> = type_names
                .iter()
                .zip(counts.iter())
                .map(|(nm, c)| NodeType {
                    name: (*nm).into(),
                    count: n(*c, scale),
                    feat_dim: 64,
                    learnable: true,
                })
                .collect();
            let mut relations = Vec::new();
            let mut rng = Rng::new(0xF2EE_BA5E);
            // 8 relations into the target type, the rest spread around.
            for i in 0..64usize {
                let (src, dst) = if i < 8 {
                    (i % 8, 0)
                } else {
                    let s = rng.below(8);
                    let mut d = rng.below(8);
                    if d == 0 && i % 3 != 0 {
                        d = 1 + rng.below(7); // keep target in-degree types bounded
                    }
                    (s, d)
                };
                relations.push(Relation {
                    name: format!("r{i:02}_{}_{}", type_names[src], type_names[dst]),
                    src,
                    dst,
                    reverse_of: None,
                });
            }
            Schema {
                name: "freebase".into(),
                node_types,
                relations,
                target: 0,
                num_classes: 8,
            }
        }
        Preset::Donor => Schema {
            name: "donor".into(),
            node_types: vec![
                NodeType { name: "project".into(),  count: n(1.1e6, scale), feat_dim: 789, learnable: false },
                NodeType { name: "donation".into(), count: n(4.7e6, scale), feat_dim: 15,  learnable: false },
                NodeType { name: "donor".into(),    count: n(2.0e6, scale), feat_dim: 7,   learnable: false },
                NodeType { name: "resource".into(), count: n(1.5e6, scale), feat_dim: 9,   learnable: false },
                NodeType { name: "school".into(),   count: n(7.0e4, scale), feat_dim: 30,  learnable: false },
                NodeType { name: "teacher".into(),  count: n(0.4e6, scale), feat_dim: 8,   learnable: false },
                NodeType { name: "essay".into(),    count: n(1.1e6, scale), feat_dim: 512, learnable: false },
            ],
            relations: vec![
                Relation { name: "don_proj".into(),  src: 1, dst: 0, reverse_of: None },
                Relation { name: "res_proj".into(),  src: 3, dst: 0, reverse_of: None },
                Relation { name: "essay_proj".into(), src: 6, dst: 0, reverse_of: None },
                Relation { name: "school_proj".into(), src: 4, dst: 0, reverse_of: None },
                Relation { name: "teacher_proj".into(), src: 5, dst: 0, reverse_of: None },
                Relation { name: "donor_don".into(), src: 2, dst: 1, reverse_of: None },
                Relation { name: "proj_don".into(),  src: 0, dst: 1, reverse_of: Some(0) },
                Relation { name: "proj_res".into(),  src: 0, dst: 3, reverse_of: Some(1) },
                Relation { name: "proj_essay".into(), src: 0, dst: 6, reverse_of: Some(2) },
                Relation { name: "proj_school".into(), src: 0, dst: 4, reverse_of: Some(3) },
                Relation { name: "proj_teacher".into(), src: 0, dst: 5, reverse_of: Some(4) },
                Relation { name: "don_donor".into(), src: 1, dst: 2, reverse_of: Some(5) },
                Relation { name: "school_teacher".into(), src: 4, dst: 5, reverse_of: None },
                Relation { name: "teacher_school".into(), src: 5, dst: 4, reverse_of: Some(12) },
            ],
            target: 0,
            num_classes: 2,
        },
        Preset::IgbHet => Schema {
            name: "igb-het".into(),
            node_types: vec![
                NodeType { name: "paper".into(),  count: n(1.0e7, scale), feat_dim: 1024, learnable: false },
                NodeType { name: "author".into(), count: n(1.4e7, scale), feat_dim: 1024, learnable: false },
                NodeType { name: "inst".into(),   count: n(2.7e4, scale), feat_dim: 1024, learnable: false },
                NodeType { name: "fos".into(),    count: n(1.9e6, scale), feat_dim: 1024, learnable: false },
            ],
            relations: vec![
                Relation { name: "written_by".into(), src: 1, dst: 0, reverse_of: None },
                Relation { name: "cites".into(),      src: 0, dst: 0, reverse_of: None },
                Relation { name: "topic_rev".into(),  src: 3, dst: 0, reverse_of: None },
                Relation { name: "writes".into(),     src: 0, dst: 1, reverse_of: Some(0) },
                Relation { name: "affiliated".into(), src: 2, dst: 1, reverse_of: None },
                Relation { name: "affiliated_rev".into(), src: 1, dst: 2, reverse_of: Some(4) },
                Relation { name: "topic".into(),      src: 0, dst: 3, reverse_of: Some(2) },
            ],
            target: 0,
            num_classes: 2983,
        },
        Preset::Mag240m => Schema {
            name: "mag240m".into(),
            node_types: vec![
                NodeType { name: "paper".into(),  count: n(1.2e8, scale), feat_dim: 768, learnable: false },
                NodeType { name: "author".into(), count: n(1.2e8, scale), feat_dim: 64,  learnable: true },
                NodeType { name: "inst".into(),   count: n(2.6e4, scale), feat_dim: 64,  learnable: true },
            ],
            relations: vec![
                Relation { name: "writes".into(),     src: 1, dst: 0, reverse_of: None },
                Relation { name: "cites".into(),      src: 0, dst: 0, reverse_of: None },
                Relation { name: "writes_rev".into(), src: 0, dst: 1, reverse_of: Some(0) },
                Relation { name: "affiliated".into(), src: 2, dst: 1, reverse_of: None },
                Relation { name: "affiliated_rev".into(), src: 1, dst: 2, reverse_of: Some(3) },
            ],
            target: 0,
            num_classes: 153,
        },
    }
}

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct GenParams {
    pub seed: u64,
    /// Average in-degree per relation (edges = avg_degree × |dst|).
    pub avg_degree: f64,
    /// Zipf exponent for source-node popularity (power-law out-degree).
    pub zipf_alpha: f64,
    /// Fraction of target nodes in the train split.
    pub train_frac: f64,
}

impl Default for GenParams {
    fn default() -> Self {
        GenParams {
            seed: 42,
            avg_degree: 8.0,
            zipf_alpha: 1.05,
            train_frac: 0.6,
        }
    }
}

/// Generate the full synthetic HetG for a preset at a scale.
pub fn generate(preset: Preset, scale: f64, params: &GenParams) -> HetGraph {
    let schema = schema(preset, scale);
    generate_from_schema(schema, params)
}

/// Generate topology + labels for an arbitrary schema. Source endpoints
/// are Zipf-distributed (popular nodes attract most edges); destination
/// endpoints are uniform, so every dst node has a similar expected
/// in-degree while hubs emerge on the source side.
pub fn generate_from_schema(schema: Schema, params: &GenParams) -> HetGraph {
    let mut rng = Rng::new(params.seed);
    let mut rels = Vec::with_capacity(schema.relations.len());
    for (rid, rel) in schema.relations.iter().enumerate() {
        let num_src = schema.node_types[rel.src].count;
        let num_dst = schema.node_types[rel.dst].count;
        let num_edges = ((num_dst as f64) * params.avg_degree) as usize;
        let zipf = Zipf::new(num_src, params.zipf_alpha);
        let mut r = rng.fork(rid as u64);
        let mut edges = Vec::with_capacity(num_edges);
        for _ in 0..num_edges {
            let src = zipf.sample(&mut r) as NodeId;
            let dst = r.below(num_dst) as NodeId;
            edges.push((src, dst));
        }
        // Real graphs are simple: drop duplicate (src, dst) pairs so
        // per-slot neighbor sampling stays duplicate-free.
        edges.sort_unstable();
        edges.dedup();
        rels.push(RelCsr::from_edges(rid, num_dst, &edges));
    }
    let num_target = schema.node_types[schema.target].count;
    let mut lab_rng = rng.fork(0xAB);
    let labels: Vec<u16> = (0..num_target)
        .map(|_| lab_rng.below(schema.num_classes) as u16)
        .collect();
    let train_mask: Vec<bool> = (0..num_target)
        .map(|_| lab_rng.f64() < params.train_frac)
        .collect();
    HetGraph {
        schema,
        rels,
        labels,
        train_mask,
    }
}

/// Deterministic synthetic feature value for (type, node, component):
/// features are produced lazily from a hash so multi-GB feature matrices
/// never need materializing — the KV store and cache compute them on
/// first touch. Values are in [-0.5, 0.5), weakly correlated with the
/// node's label so that training can actually learn.
pub fn feature_value(seed: u64, ty: usize, node: NodeId, comp: usize, label_hint: u16) -> f32 {
    let mut h = seed ^ 0x9E3779B97F4A7C15;
    for v in [ty as u64, node as u64, comp as u64] {
        h ^= v.wrapping_mul(0xBF58476D1CE4E5B9);
        h = h.rotate_left(27).wrapping_mul(0x94D049BB133111EB);
    }
    let base = ((h >> 40) as f32 / (1u64 << 24) as f32) - 0.5;
    // Inject a small label-dependent component on matching coordinates so
    // the classification task is learnable.
    if comp % 7 == (label_hint as usize) % 7 {
        base * 0.5 + 0.35
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_paper_shape() {
        // Node-type and relation counts match Table 1.
        let checks = [
            (Preset::Mag, 4, 7),
            (Preset::Freebase, 8, 64),
            (Preset::Donor, 7, 14),
            (Preset::IgbHet, 4, 7),
            (Preset::Mag240m, 3, 5),
        ];
        for (p, nt, ne) in checks {
            let s = schema(p, 1e-4);
            assert_eq!(s.node_types.len(), nt, "{}", p.name());
            assert_eq!(s.relations.len(), ne, "{}", p.name());
        }
    }

    #[test]
    fn feature_profiles_match_paper() {
        let mag = schema(Preset::Mag, 1e-4);
        assert!(!mag.node_types[0].learnable && mag.node_types[0].feat_dim == 128);
        assert!(mag.node_types[1].learnable);
        let fb = schema(Preset::Freebase, 1e-4);
        assert!(fb.node_types.iter().all(|t| t.learnable));
        let donor = schema(Preset::Donor, 1e-4);
        assert!(donor.node_types.iter().all(|t| !t.learnable));
        let dims: Vec<usize> = donor.node_types.iter().map(|t| t.feat_dim).collect();
        assert!(dims.contains(&7) && dims.contains(&789));
        let m240 = schema(Preset::Mag240m, 1e-4);
        assert_eq!(m240.node_types[0].feat_dim, 768);
        assert!(m240.node_types[1].learnable);
    }

    #[test]
    fn generation_is_deterministic() {
        let p = GenParams::default();
        let a = generate(Preset::Mag, 1e-4, &p);
        let b = generate(Preset::Mag, 1e-4, &p);
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.rels[0].indices, b.rels[0].indices);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn degrees_are_skewed() {
        let g = generate(Preset::Mag, 3e-4, &GenParams::default());
        // Out-degree skew: source hubs exist. Count appearances of the
        // most popular source in relation 0 vs a mid-rank node.
        let cites = &g.rels[1];
        let mut out_deg = vec![0usize; g.schema.node_types[0].count];
        for &s in &cites.indices {
            out_deg[s as usize] += 1;
        }
        out_deg.sort_unstable_by(|a, b| b.cmp(a));
        assert!(out_deg[0] > 10 * out_deg[out_deg.len() / 2].max(1), "no hub: {} vs {}", out_deg[0], out_deg[out_deg.len() / 2]);
    }

    #[test]
    fn labels_and_mask_are_sane() {
        let g = generate(Preset::Donor, 1e-3, &GenParams::default());
        assert!(g.labels.iter().all(|&l| (l as usize) < g.schema.num_classes));
        let frac = g.train_nodes().len() as f64 / g.labels.len() as f64;
        assert!((frac - 0.6).abs() < 0.1, "train frac {frac}");
    }

    #[test]
    fn avg_in_degree_matches_param() {
        let params = GenParams { avg_degree: 5.0, ..Default::default() };
        let g = generate(Preset::Mag, 1e-4, &params);
        for r in &g.rels {
            let dst_count = r.offsets.len() - 1;
            let avg = r.num_edges() as f64 / dst_count as f64;
            // Duplicate-edge removal trims Zipf-hub repeats, so the
            // realized mean sits below the nominal parameter.
            assert!(avg <= 5.05 && avg > 2.0, "avg={avg}");
        }
    }

    #[test]
    fn feature_values_bounded_and_deterministic() {
        for comp in 0..32 {
            let v = feature_value(1, 0, 17, comp, 3);
            assert!(v.is_finite() && v.abs() <= 1.0);
            assert_eq!(v, feature_value(1, 0, 17, comp, 3));
        }
        assert_ne!(feature_value(1, 0, 17, 0, 3), feature_value(1, 0, 18, 0, 3));
    }

    #[test]
    fn storage_accounting_scales_with_features() {
        let g = generate(Preset::Mag, 1e-4, &GenParams::default());
        let s2 = g.storage_bytes(2);
        let s4 = g.storage_bytes(4);
        assert!(s4 > s2);
        assert!(s2 > g.mem_bytes());
    }
}
