//! PJRT runtime: load the AOT-compiled HLO-text artifacts, compile them
//! on the CPU PJRT client (`xla` crate), and execute them from the
//! training hot path. Also hosts the manifest parser (the Python-emitted
//! input/output orderings) and the parameter store (deterministic
//! name-keyed Glorot init + Adam state — both engines initialize the
//! same weights, which is what makes the Prop. 1 equivalence test
//! byte-meaningful).

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::optim::{Adam, AdamParams};
use crate::util::json::parse;
use crate::util::rng::Rng;

/// One artifact input slot (mirrors `InputSpec.to_json` in model.py).
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub kind: String,
    pub shape: Vec<usize>,
    pub name: String,
    pub edge: i64,
    pub layer: usize,
    pub dtype: String,
    pub init: String,
}

/// One artifact output slot.
#[derive(Debug, Clone)]
pub struct OutputSpec {
    pub kind: String,
    pub name: String,
    pub edge: i64,
    pub layer: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<OutputSpec>,
}

/// The manifest for one artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: String,
    pub arch: String,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} (run `make artifacts`)"))?;
        let j = parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let mut artifacts = HashMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|s| InputSpec {
                    kind: s.get("kind").as_str().unwrap_or("").to_string(),
                    shape: s
                        .get("shape")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    name: s.get("name").as_str().unwrap_or("").to_string(),
                    edge: s.get("edge").as_f64().map(|v| v as i64).unwrap_or(-1),
                    layer: s.get("layer").as_usize().unwrap_or(0),
                    dtype: s.get("dtype").as_str().unwrap_or("f32").to_string(),
                    init: s.get("init").as_str().unwrap_or("").to_string(),
                })
                .collect();
            let outputs = a
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(|s| OutputSpec {
                    kind: s.get("kind").as_str().unwrap_or("").to_string(),
                    name: s.get("name").as_str().unwrap_or("").to_string(),
                    edge: s.get("edge").as_f64().map(|v| v as i64).unwrap_or(-1),
                    layer: s.get("layer").as_usize().unwrap_or(0),
                })
                .collect();
            artifacts.insert(name.clone(), ArtifactSpec { inputs, outputs });
        }
        Ok(Manifest {
            config: j.get("config").as_str().unwrap_or("").to_string(),
            arch: j.get("arch").as_str().unwrap_or("").to_string(),
            artifacts,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }
}

/// Compiled-executable registry over one artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: String,
}

impl Runtime {
    /// Create the CPU PJRT client and load the manifest; artifacts are
    /// compiled lazily on first use (and cached).
    pub fn load(dir: &str) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime {
            client,
            manifest,
            exes: HashMap::new(),
            dir: dir.to_string(),
        })
    }

    fn compile(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = format!("{}/{}.hlo.txt", self.dir, name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on a flat list of input literals; returns the
    /// decomposed output tuple (artifacts are lowered with
    /// `return_tuple=True`).
    pub fn exec(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.compile(name)?;
        let exe = self.exes.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        result
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Read an f32 literal back into a Vec (any shape).
pub fn lit_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

/// Read a scalar f32 output.
pub fn lit_scalar(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>()
        .map_err(|e| anyhow!("literal scalar: {e:?}"))
}

/// Name-keyed parameter store with deterministic init and per-tensor
/// Adam state. Weight names are globally unique (the manifest guarantees
/// it), so the RAF and vanilla engines construct identical parameters.
pub struct ParamStore {
    pub params: HashMap<String, Vec<f32>>,
    pub shapes: HashMap<String, Vec<usize>>,
    adam: HashMap<String, Adam>,
    seed: u64,
    hp: AdamParams,
}

impl ParamStore {
    pub fn new(seed: u64, hp: AdamParams) -> ParamStore {
        ParamStore {
            params: HashMap::new(),
            shapes: HashMap::new(),
            adam: HashMap::new(),
            seed,
            hp,
        }
    }

    fn name_seed(&self, name: &str) -> u64 {
        let mut h = self.seed ^ 0xCBF2_9CE4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01B3);
        }
        h
    }

    /// Get-or-init a parameter per its manifest spec (Glorot uniform).
    pub fn ensure(&mut self, spec: &InputSpec) {
        if self.params.contains_key(&spec.name) {
            return;
        }
        let n: usize = spec.shape.iter().product();
        let (fan_in, fan_out) = match spec.shape.len() {
            2 => (spec.shape[0], spec.shape[1]),
            1 => (spec.shape[0], spec.shape[0]),
            _ => (n, n),
        };
        let a = (6.0 / (fan_in + fan_out) as f64).sqrt();
        let mut rng = Rng::new(self.name_seed(&spec.name));
        let data: Vec<f32> = (0..n).map(|_| ((rng.f64() * 2.0 - 1.0) * a) as f32).collect();
        self.adam.insert(spec.name.clone(), Adam::new(n, self.hp));
        self.shapes.insert(spec.name.clone(), spec.shape.clone());
        self.params.insert(spec.name.clone(), data);
    }

    pub fn get(&self, name: &str) -> &Vec<f32> {
        &self.params[name]
    }

    /// Apply one Adam step with the given gradient. Errors (instead of
    /// panicking) on an unknown parameter so a cluster worker/leader
    /// thread can surface the fault through its `Result` channel.
    pub fn step(&mut self, name: &str, grad: &[f32]) -> Result<()> {
        let p = self
            .params
            .get_mut(name)
            .with_context(|| format!("step on unknown parameter '{name}'"))?;
        self.adam
            .get_mut(name)
            .with_context(|| format!("missing Adam state for '{name}'"))?
            .step(p, grad);
        Ok(())
    }

    /// Total parameter elements (gradient-allreduce volume accounting).
    pub fn total_elems(&self) -> usize {
        self.params.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wspec(name: &str, shape: Vec<usize>) -> InputSpec {
        InputSpec {
            kind: "weight".into(),
            shape,
            name: name.into(),
            edge: -1,
            layer: 0,
            dtype: "f32".into(),
            init: "glorot".into(),
        }
    }

    #[test]
    fn param_store_deterministic_by_name() {
        let spec = wspec("W1_writes", vec![4, 8]);
        let mut a = ParamStore::new(7, AdamParams::default());
        let mut b = ParamStore::new(7, AdamParams::default());
        a.ensure(&spec);
        b.ensure(&spec);
        assert_eq!(a.get("W1_writes"), b.get("W1_writes"));
        let mut c = ParamStore::new(8, AdamParams::default());
        c.ensure(&spec);
        assert_ne!(a.get("W1_writes"), c.get("W1_writes"));
    }

    #[test]
    fn glorot_bounds() {
        let mut s = ParamStore::new(1, AdamParams::default());
        s.ensure(&wspec("w", vec![10, 10]));
        let a = (6.0f64 / 20.0).sqrt() as f32;
        assert!(s.get("w").iter().all(|&x| x.abs() <= a));
        assert!(s.get("w").iter().any(|&x| x != 0.0));
    }

    #[test]
    fn step_updates_parameters() {
        let mut s = ParamStore::new(1, AdamParams::default());
        s.ensure(&wspec("w", vec![4]));
        let before = s.get("w").clone();
        s.step("w", &[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_ne!(&before, s.get("w"));
        assert_eq!(s.total_elems(), 4);
        assert!(s.step("missing", &[1.0]).is_err());
    }
}
