//! PJRT runtime: load the AOT-compiled HLO-text artifacts, compile them
//! on the CPU PJRT client (`xla` crate), and execute them from the
//! training hot path. Also hosts the manifest parser (the Python-emitted
//! input/output orderings) and the parameter store (deterministic
//! name-keyed Glorot init + Adam state — both engines initialize the
//! same weights, which is what makes the Prop. 1 equivalence test
//! byte-meaningful).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::optim::{Adam, AdamParams};
use crate::util::json::parse;
use crate::util::rng::Rng;

/// One artifact input slot (mirrors `InputSpec.to_json` in model.py).
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub kind: String,
    pub shape: Vec<usize>,
    pub name: String,
    pub edge: i64,
    pub layer: usize,
    pub dtype: String,
    pub init: String,
}

/// One artifact output slot.
#[derive(Debug, Clone)]
pub struct OutputSpec {
    pub kind: String,
    pub name: String,
    pub edge: i64,
    pub layer: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<OutputSpec>,
}

/// The manifest for one artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: String,
    pub arch: String,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} (run `make artifacts`)"))?;
        let j = parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let mut artifacts = HashMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|s| InputSpec {
                    kind: s.get("kind").as_str().unwrap_or("").to_string(),
                    shape: s
                        .get("shape")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    name: s.get("name").as_str().unwrap_or("").to_string(),
                    edge: s.get("edge").as_f64().map(|v| v as i64).unwrap_or(-1),
                    layer: s.get("layer").as_usize().unwrap_or(0),
                    dtype: s.get("dtype").as_str().unwrap_or("f32").to_string(),
                    init: s.get("init").as_str().unwrap_or("").to_string(),
                })
                .collect();
            let outputs = a
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(|s| OutputSpec {
                    kind: s.get("kind").as_str().unwrap_or("").to_string(),
                    name: s.get("name").as_str().unwrap_or("").to_string(),
                    edge: s.get("edge").as_f64().map(|v| v as i64).unwrap_or(-1),
                    layer: s.get("layer").as_usize().unwrap_or(0),
                })
                .collect();
            artifacts.insert(name.clone(), ArtifactSpec { inputs, outputs });
        }
        Ok(Manifest {
            config: j.get("config").as_str().unwrap_or("").to_string(),
            arch: j.get("arch").as_str().unwrap_or("").to_string(),
            artifacts,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }
}

/// Compiled-executable registry over one artifact directory.
///
/// Since PR 3 each worker's [`crate::exec::ExecContext`] owns its *own*
/// `Runtime` — its own PJRT client and its own lazily compiled
/// executables — so artifact executions on different workers never
/// share mutable state. The parsed [`Manifest`] is `Arc`-shared across
/// all of a session's runtimes (it is immutable after load).
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Arc<Manifest>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: String,
}

impl Runtime {
    /// Create the CPU PJRT client and load the manifest; artifacts are
    /// compiled lazily on first use (and cached).
    pub fn load(dir: &str) -> Result<Runtime> {
        let manifest = Arc::new(Manifest::load(dir)?);
        Runtime::with_manifest(dir, manifest)
    }

    /// Create a runtime over an already-parsed manifest (one PJRT client
    /// per call — the per-worker-context path).
    pub fn with_manifest(dir: &str, manifest: Arc<Manifest>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            exes: HashMap::new(),
            dir: dir.to_string(),
        })
    }

    fn compile(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = format!("{}/{}.hlo.txt", self.dir, name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on a flat list of input literals; returns the
    /// decomposed output tuple (artifacts are lowered with
    /// `return_tuple=True`).
    pub fn exec(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.compile(name)?;
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' vanished between compile and exec"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        result
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Read an f32 literal back into a Vec (any shape).
pub fn lit_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

/// Read a scalar f32 output.
pub fn lit_scalar(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>()
        .map_err(|e| anyhow!("literal scalar: {e:?}"))
}

/// Name-keyed parameter store with deterministic init and per-tensor
/// Adam state. Weight names are globally unique (the manifest guarantees
/// it), so the RAF and vanilla engines construct identical parameters.
///
/// The store is **leader-owned**: workers never hold a reference to it.
/// Each batch the leader publishes a [`ParamSnapshot`] — a versioned,
/// read-only view of the current tensors — and workers marshal their
/// weight literals from that snapshot, matching real distributed
/// semantics (parameters move to workers; workers never reach into the
/// trainer's mutable state). Tensors are `Arc`-backed copy-on-write:
/// taking a snapshot is a reference bump per tensor, and a subsequent
/// [`ParamStore::step`] clones only the tensors it actually updates.
pub struct ParamStore {
    pub params: HashMap<String, Arc<Vec<f32>>>,
    pub shapes: HashMap<String, Vec<usize>>,
    adam: HashMap<String, Adam>,
    seed: u64,
    hp: AdamParams,
    /// Bumped by every [`ParamStore::step`]; stamps snapshots.
    version: u64,
}

/// A versioned read-only view of every parameter tensor, published by
/// the leader once per batch and broadcast to the workers (the cluster
/// runtime ships it through the leader→worker collective; the
/// sequential runtime reads the store directly via
/// [`crate::exec::ParamsView::Owner`]). Snapshots share tensor storage
/// with the store at capture time — later optimizer steps copy-on-write
/// and can never mutate a published snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParamSnapshot {
    pub version: u64,
    params: HashMap<String, Arc<Vec<f32>>>,
}

impl ParamSnapshot {
    /// Rebuild a snapshot from decoded tensors (the TCP transport ships
    /// snapshots with every batch release; see `net::codec`).
    pub fn from_tensors(version: u64, tensors: Vec<(String, Vec<f32>)>) -> ParamSnapshot {
        ParamSnapshot {
            version,
            params: tensors
                .into_iter()
                .map(|(name, data)| (name, Arc::new(data)))
                .collect(),
        }
    }

    /// Every tensor, sorted by name — the canonical order the wire
    /// codec encodes (HashMap iteration order must never leak into
    /// bytes two processes compare).
    pub fn tensors_sorted(&self) -> Vec<(&str, &[f32])> {
        let mut v: Vec<(&str, &[f32])> = self
            .params
            .iter()
            .map(|(n, d)| (n.as_str(), d.as_slice()))
            .collect();
        v.sort_unstable_by(|a, b| a.0.cmp(b.0));
        v
    }

    /// Read one tensor; errors on a weight the leader never initialized
    /// (an artifact/manifest mismatch, not a race).
    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.params
            .get(name)
            .map(|v| v.as_slice())
            .with_context(|| format!("parameter '{name}' missing from snapshot"))
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }
}

impl ParamStore {
    pub fn new(seed: u64, hp: AdamParams) -> ParamStore {
        ParamStore {
            params: HashMap::new(),
            shapes: HashMap::new(),
            adam: HashMap::new(),
            seed,
            hp,
            version: 0,
        }
    }

    fn name_seed(&self, name: &str) -> u64 {
        let mut h = self.seed ^ 0xCBF2_9CE4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01B3);
        }
        h
    }

    /// Get-or-init a parameter per its manifest spec (Glorot uniform).
    pub fn ensure(&mut self, spec: &InputSpec) {
        if self.params.contains_key(&spec.name) {
            return;
        }
        let n: usize = spec.shape.iter().product();
        let (fan_in, fan_out) = match spec.shape.len() {
            2 => (spec.shape[0], spec.shape[1]),
            1 => (spec.shape[0], spec.shape[0]),
            _ => (n, n),
        };
        let a = (6.0 / (fan_in + fan_out) as f64).sqrt();
        let mut rng = Rng::new(self.name_seed(&spec.name));
        let data: Vec<f32> = (0..n).map(|_| ((rng.f64() * 2.0 - 1.0) * a) as f32).collect();
        self.adam.insert(spec.name.clone(), Adam::new(n, self.hp));
        self.shapes.insert(spec.name.clone(), spec.shape.clone());
        self.params.insert(spec.name.clone(), Arc::new(data));
    }

    /// Initialize every weight the named artifacts declare. Engines call
    /// this once at build time so the marshal stage (and the snapshots
    /// workers marshal from) never needs mutable access to the store —
    /// the init set is exactly the weights the engine's artifacts would
    /// have ensured lazily. Artifact names missing from the manifest are
    /// skipped (their absence surfaces at plan-build time instead).
    pub fn ensure_artifacts<'a>(
        &mut self,
        manifest: &Manifest,
        artifacts: impl IntoIterator<Item = &'a str>,
    ) {
        for name in artifacts {
            if let Some(spec) = manifest.artifacts.get(name) {
                for inp in spec.inputs.iter().filter(|i| i.kind == "weight") {
                    self.ensure(inp);
                }
            }
        }
    }

    pub fn get(&self, name: &str) -> &[f32] {
        &self.params[name]
    }

    /// Current store version — the value the next [`Self::snapshot`]
    /// would be stamped with. Gradients are tagged with the version of
    /// the snapshot their backward marshalled from, so the accumulator
    /// can verify every per-worker gradient of a batch was produced
    /// against the same weights (the stale-gradient contract of the
    /// bounded-staleness pipeline: under `train.staleness = k`, a
    /// batch's forward snapshot may trail the store by up to `k`
    /// updates, but all of one batch's gradients must agree).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Capture a versioned read-only snapshot of every tensor (Arc
    /// bumps, no copies). The leader publishes one per batch.
    pub fn snapshot(&self) -> ParamSnapshot {
        ParamSnapshot {
            version: self.version,
            params: self.params.clone(),
        }
    }

    /// Apply one Adam step with the given gradient. Errors (instead of
    /// panicking) on an unknown parameter so a cluster worker/leader
    /// thread can surface the fault through its `Result` channel.
    /// Copy-on-write: a tensor still referenced by a published snapshot
    /// is cloned before the in-place update.
    pub fn step(&mut self, name: &str, grad: &[f32]) -> Result<()> {
        let p = self
            .params
            .get_mut(name)
            .with_context(|| format!("step on unknown parameter '{name}'"))?;
        self.adam
            .get_mut(name)
            .with_context(|| format!("missing Adam state for '{name}'"))?
            .step(Arc::make_mut(p), grad);
        self.version += 1;
        Ok(())
    }

    /// Total parameter elements (gradient-allreduce volume accounting).
    pub fn total_elems(&self) -> usize {
        self.params.values().map(|v| v.len()).sum()
    }

    /// Export the full resumable optimizer state — every tensor with its
    /// shape and Adam moments, sorted by name (canonical order for the
    /// checkpoint codec) — plus the store version. A
    /// [`restore_state`](Self::restore_state) of the result reproduces
    /// the parameter trajectory bit-for-bit from this point.
    pub fn export_state(&self) -> ParamStoreState {
        let mut names: Vec<&String> = self.params.keys().collect();
        names.sort_unstable();
        let entries = names
            .into_iter()
            .map(|name| {
                let adam = &self.adam[name];
                ParamEntry {
                    name: name.clone(),
                    shape: self.shapes.get(name).cloned().unwrap_or_default(),
                    weight: self.params[name].as_ref().clone(),
                    m: adam.m.clone(),
                    v: adam.v.clone(),
                    t: adam.t,
                }
            })
            .collect();
        ParamStoreState { version: self.version, entries }
    }

    /// Replace this store's tensors, Adam moments and version with a
    /// previously exported state (checkpoint restore). Validates every
    /// entry's internal consistency; a later [`ensure`](Self::ensure)
    /// of a restored name is a no-op, so engines built after a restore
    /// keep the checkpointed weights.
    pub fn restore_state(&mut self, st: ParamStoreState) -> Result<()> {
        for e in &st.entries {
            let n = e.weight.len();
            anyhow::ensure!(
                e.m.len() == n && e.v.len() == n,
                "checkpointed parameter '{}': Adam moments ({}, {}) do not match \
                 the tensor length {n}",
                e.name,
                e.m.len(),
                e.v.len()
            );
            let shape_elems: usize = e.shape.iter().product();
            anyhow::ensure!(
                e.shape.is_empty() || shape_elems == n,
                "checkpointed parameter '{}': shape {:?} does not hold {n} elements",
                e.name,
                e.shape
            );
        }
        self.params.clear();
        self.shapes.clear();
        self.adam.clear();
        for e in st.entries {
            let mut adam = Adam::new(e.weight.len(), self.hp);
            adam.m = e.m;
            adam.v = e.v;
            adam.t = e.t;
            self.adam.insert(e.name.clone(), adam);
            self.shapes.insert(e.name.clone(), e.shape);
            self.params.insert(e.name, Arc::new(e.weight));
        }
        self.version = st.version;
        Ok(())
    }
}

/// One parameter tensor's full resumable state (weights + Adam moments).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub weight: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: i32,
}

/// The checkpointable state of a [`ParamStore`]: entries sorted by
/// name, plus the store version (the stale-gradient contract pins
/// folds to snapshot versions, so resumed runs must count from the
/// same value). Serialized by [`crate::ckpt`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParamStoreState {
    pub version: u64,
    pub entries: Vec<ParamEntry>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wspec(name: &str, shape: Vec<usize>) -> InputSpec {
        InputSpec {
            kind: "weight".into(),
            shape,
            name: name.into(),
            edge: -1,
            layer: 0,
            dtype: "f32".into(),
            init: "glorot".into(),
        }
    }

    #[test]
    fn param_store_deterministic_by_name() {
        let spec = wspec("W1_writes", vec![4, 8]);
        let mut a = ParamStore::new(7, AdamParams::default());
        let mut b = ParamStore::new(7, AdamParams::default());
        a.ensure(&spec);
        b.ensure(&spec);
        assert_eq!(a.get("W1_writes"), b.get("W1_writes"));
        let mut c = ParamStore::new(8, AdamParams::default());
        c.ensure(&spec);
        assert_ne!(a.get("W1_writes"), c.get("W1_writes"));
    }

    #[test]
    fn glorot_bounds() {
        let mut s = ParamStore::new(1, AdamParams::default());
        s.ensure(&wspec("w", vec![10, 10]));
        let a = (6.0f64 / 20.0).sqrt() as f32;
        assert!(s.get("w").iter().all(|&x| x.abs() <= a));
        assert!(s.get("w").iter().any(|&x| x != 0.0));
    }

    #[test]
    fn step_updates_parameters() {
        let mut s = ParamStore::new(1, AdamParams::default());
        s.ensure(&wspec("w", vec![4]));
        let before = s.get("w").to_vec();
        s.step("w", &[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_ne!(before.as_slice(), s.get("w"));
        assert_eq!(s.total_elems(), 4);
        assert!(s.step("missing", &[1.0]).is_err());
    }

    #[test]
    fn snapshots_are_versioned_and_copy_on_write() {
        let mut s = ParamStore::new(1, AdamParams::default());
        s.ensure(&wspec("w", vec![4]));
        let snap = s.snapshot();
        let frozen = snap.get("w").unwrap().to_vec();
        assert!(snap.get("missing").is_err());
        // A later optimizer step must never mutate the published
        // snapshot (workers may still be marshalling from it).
        s.step("w", &[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_eq!(snap.get("w").unwrap(), frozen.as_slice());
        assert_ne!(s.get("w"), frozen.as_slice());
        let snap2 = s.snapshot();
        assert!(snap2.version > snap.version, "steps must bump the version");
        assert_eq!(snap2.len(), 1);
        assert!(!snap2.is_empty());
    }

    #[test]
    fn export_restore_round_trips_the_trajectory() {
        let mut s = ParamStore::new(11, AdamParams::default());
        s.ensure(&wspec("w", vec![4]));
        s.ensure(&wspec("b", vec![2, 2]));
        s.step("w", &[0.5, -0.5, 0.25, -0.25]).unwrap();
        let st = s.export_state();
        assert_eq!(st.entries[0].name, "b", "entries must be name-sorted");

        // A fresh store restored from the state must continue the
        // trajectory bit-for-bit.
        let mut r = ParamStore::new(999, AdamParams::default());
        r.restore_state(st.clone()).unwrap();
        assert_eq!(r.version(), s.version());
        s.step("w", &[1.0; 4]).unwrap();
        r.step("w", &[1.0; 4]).unwrap();
        assert_eq!(s.get("w"), r.get("w"), "restored Adam moments must match");
        assert_eq!(s.get("b"), r.get("b"));

        // Inconsistent moments are an error, not a panic.
        let mut bad = st;
        bad.entries[0].m.pop();
        assert!(r.restore_state(bad).is_err());
    }

    #[test]
    fn ensure_artifacts_initializes_declared_weights_only() {
        let mut artifacts = HashMap::new();
        artifacts.insert(
            "a".to_string(),
            ArtifactSpec {
                inputs: vec![
                    wspec("w_a", vec![2, 2]),
                    InputSpec { kind: "block".into(), ..wspec("not_a_weight", vec![4]) },
                ],
                outputs: vec![],
            },
        );
        artifacts.insert(
            "b".to_string(),
            ArtifactSpec { inputs: vec![wspec("w_b", vec![3])], outputs: vec![] },
        );
        let manifest = Manifest { config: String::new(), arch: String::new(), artifacts };
        let mut s = ParamStore::new(3, AdamParams::default());
        s.ensure_artifacts(&manifest, ["a", "nonexistent"]);
        assert!(s.params.contains_key("w_a"));
        assert!(!s.params.contains_key("w_b"), "artifact b was not requested");
        assert!(!s.params.contains_key("not_a_weight"));
    }
}
