//! PJRT runtime: load the AOT-compiled HLO-text artifacts, compile them
//! on the CPU PJRT client (`xla` crate), and execute them from the
//! training hot path. Also hosts the manifest parser (the Python-emitted
//! input/output orderings) and the parameter store (deterministic
//! name-keyed Glorot init + Adam state — both engines initialize the
//! same weights, which is what makes the Prop. 1 equivalence test
//! byte-meaningful).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::optim::{Adam, AdamParams};
use crate::util::json::parse;
use crate::util::rng::Rng;

/// One artifact input slot (mirrors `InputSpec.to_json` in model.py).
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub kind: String,
    pub shape: Vec<usize>,
    pub name: String,
    pub edge: i64,
    pub layer: usize,
    pub dtype: String,
    pub init: String,
}

/// One artifact output slot.
#[derive(Debug, Clone)]
pub struct OutputSpec {
    pub kind: String,
    pub name: String,
    pub edge: i64,
    pub layer: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub inputs: Vec<InputSpec>,
    pub outputs: Vec<OutputSpec>,
}

/// The manifest for one artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: String,
    pub arch: String,
    pub artifacts: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path} (run `make artifacts`)"))?;
        let j = parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let mut artifacts = HashMap::new();
        for (name, a) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let inputs = a
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|s| InputSpec {
                    kind: s.get("kind").as_str().unwrap_or("").to_string(),
                    shape: s
                        .get("shape")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    name: s.get("name").as_str().unwrap_or("").to_string(),
                    edge: s.get("edge").as_f64().map(|v| v as i64).unwrap_or(-1),
                    layer: s.get("layer").as_usize().unwrap_or(0),
                    dtype: s.get("dtype").as_str().unwrap_or("f32").to_string(),
                    init: s.get("init").as_str().unwrap_or("").to_string(),
                })
                .collect();
            let outputs = a
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(|s| OutputSpec {
                    kind: s.get("kind").as_str().unwrap_or("").to_string(),
                    name: s.get("name").as_str().unwrap_or("").to_string(),
                    edge: s.get("edge").as_f64().map(|v| v as i64).unwrap_or(-1),
                    layer: s.get("layer").as_usize().unwrap_or(0),
                })
                .collect();
            artifacts.insert(name.clone(), ArtifactSpec { inputs, outputs });
        }
        Ok(Manifest {
            config: j.get("config").as_str().unwrap_or("").to_string(),
            arch: j.get("arch").as_str().unwrap_or("").to_string(),
            artifacts,
        })
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }
}

/// Compiled-executable registry over one artifact directory.
///
/// Since PR 3 each worker's [`crate::exec::ExecContext`] owns its *own*
/// `Runtime` — its own PJRT client and its own lazily compiled
/// executables — so artifact executions on different workers never
/// share mutable state. The parsed [`Manifest`] is `Arc`-shared across
/// all of a session's runtimes (it is immutable after load).
pub struct Runtime {
    /// Created eagerly by [`Runtime::with_manifest`], or on first
    /// compile by [`Runtime::deferred`] — the role-gated TCP path
    /// builds one *deferred* runtime per foreign rank so a K-worker
    /// cluster holds K+1 PJRT clients total instead of (K+1)².
    client: Option<xla::PjRtClient>,
    pub manifest: Arc<Manifest>,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: String,
}

impl Runtime {
    /// Create the CPU PJRT client and load the manifest; artifacts are
    /// compiled lazily on first use (and cached).
    pub fn load(dir: &str) -> Result<Runtime> {
        let manifest = Arc::new(Manifest::load(dir)?);
        Runtime::with_manifest(dir, manifest)
    }

    /// Create a runtime over an already-parsed manifest (one PJRT client
    /// per call — the per-worker-context path). The client is created
    /// eagerly so a broken PJRT install fails at build time, not in the
    /// middle of epoch 0.
    pub fn with_manifest(dir: &str, manifest: Arc<Manifest>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime {
            client: Some(client),
            manifest,
            exes: HashMap::new(),
            dir: dir.to_string(),
        })
    }

    /// Create a runtime whose PJRT client is only instantiated if an
    /// artifact is ever compiled. A TCP process runs exactly one rank's
    /// hot loop, so the other ranks' contexts stay deferred and never
    /// pay for a client.
    pub fn deferred(dir: &str, manifest: Arc<Manifest>) -> Runtime {
        Runtime {
            client: None,
            manifest,
            exes: HashMap::new(),
            dir: dir.to_string(),
        }
    }

    /// Whether the PJRT client has been instantiated (tests pin the
    /// role-gating contract with this).
    pub fn client_ready(&self) -> bool {
        self.client.is_some()
    }

    fn compile(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        if self.client.is_none() {
            self.client =
                Some(xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client (deferred): {e:?}"))?);
        }
        let client = self
            .client
            .as_ref()
            .ok_or_else(|| anyhow!("PJRT client missing right after creation"))?;
        let path = format!("{}/{}.hlo.txt", self.dir, name);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact on a flat list of input literals; returns the
    /// decomposed output tuple (artifacts are lowered with
    /// `return_tuple=True`).
    pub fn exec(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.compile(name)?;
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' vanished between compile and exec"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        result
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), shape.iter().product::<usize>());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

/// Read an f32 literal back into a Vec (any shape).
pub fn lit_to_vec(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

/// Read a scalar f32 output.
pub fn lit_scalar(l: &xla::Literal) -> Result<f32> {
    l.get_first_element::<f32>()
        .map_err(|e| anyhow!("literal scalar: {e:?}"))
}

/// Name-keyed parameter store with deterministic init and per-tensor
/// Adam state. Weight names are globally unique (the manifest guarantees
/// it), so the RAF and vanilla engines construct identical parameters.
///
/// The store is **leader-owned**: workers never hold a reference to it.
/// Each batch the leader publishes a [`ParamSnapshot`] — a versioned,
/// read-only view of the current tensors — and workers marshal their
/// weight literals from that snapshot, matching real distributed
/// semantics (parameters move to workers; workers never reach into the
/// trainer's mutable state). Tensors are `Arc`-backed copy-on-write:
/// taking a snapshot is a reference bump per tensor, and a subsequent
/// [`ParamStore::step`] clones only the tensors it actually updates.
pub struct ParamStore {
    pub params: HashMap<String, Arc<Vec<f32>>>,
    pub shapes: HashMap<String, Vec<usize>>,
    adam: HashMap<String, Adam>,
    seed: u64,
    hp: AdamParams,
    /// Bumped by every [`ParamStore::step`]; stamps snapshots.
    version: u64,
    /// Per-tensor: the store version at which this tensor last changed
    /// (init, step, or restore). [`ParamStore::diff_since`] ships only
    /// tensors whose entry advanced past the chain base.
    tensor_versions: HashMap<String, u64>,
}

/// A versioned read-only view of every parameter tensor, published by
/// the leader once per batch and broadcast to the workers (the cluster
/// runtime ships it through the leader→worker collective; the
/// sequential runtime reads the store directly via
/// [`crate::exec::ParamsView::Owner`]). Snapshots share tensor storage
/// with the store at capture time — later optimizer steps copy-on-write
/// and can never mutate a published snapshot.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParamSnapshot {
    pub version: u64,
    params: HashMap<String, Arc<Vec<f32>>>,
}

impl ParamSnapshot {
    /// Rebuild a snapshot from decoded tensors (the TCP transport ships
    /// snapshots with every batch release; see `net::codec`).
    pub fn from_tensors(version: u64, tensors: Vec<(String, Vec<f32>)>) -> ParamSnapshot {
        ParamSnapshot {
            version,
            params: tensors
                .into_iter()
                .map(|(name, data)| (name, Arc::new(data)))
                .collect(),
        }
    }

    /// Every tensor, sorted by name — the canonical order the wire
    /// codec encodes (HashMap iteration order must never leak into
    /// bytes two processes compare).
    pub fn tensors_sorted(&self) -> Vec<(&str, &[f32])> {
        let mut v: Vec<(&str, &[f32])> = self
            .params
            .iter()
            .map(|(n, d)| (n.as_str(), d.as_slice()))
            .collect();
        v.sort_unstable_by(|a, b| a.0.cmp(b.0));
        v
    }

    /// Read one tensor; errors on a weight the leader never initialized
    /// (an artifact/manifest mismatch, not a race).
    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.params
            .get(name)
            .map(|v| v.as_slice())
            .with_context(|| format!("parameter '{name}' missing from snapshot"))
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    /// Overlay a version-chained [`ParamDiff`] on this snapshot,
    /// producing the snapshot the diff advances to. The chain contract
    /// is strict: the diff's `from_version` must equal this snapshot's
    /// version (per-lane FIFO delivery means a gap is a protocol break,
    /// not a reordering), every diffed tensor must already exist here
    /// with the same length, and the chain can never run backwards.
    /// All violations are `anyhow` errors naming the versions — never a
    /// panic — so a worker can NACK and surface them.
    pub fn apply_diff(&self, diff: &ParamDiff) -> Result<ParamSnapshot> {
        anyhow::ensure!(
            diff.to_version >= diff.from_version,
            "corrupt param diff: covers v{}..v{} (the chain never runs backwards)",
            diff.from_version,
            diff.to_version
        );
        anyhow::ensure!(
            diff.from_version == self.version,
            "diff chain break: base snapshot is v{}, the diff covers v{}..v{} — \
             a full resync is required",
            self.version,
            diff.from_version,
            diff.to_version
        );
        let mut params = self.params.clone();
        for (name, data) in &diff.tensors {
            let slot = params.get_mut(name).with_context(|| {
                format!(
                    "corrupt param diff (v{}..v{}): tensor '{name}' is not in the \
                     base snapshot",
                    diff.from_version, diff.to_version
                )
            })?;
            anyhow::ensure!(
                slot.len() == data.len(),
                "corrupt param diff (v{}..v{}): tensor '{name}' ships {} elements \
                 but the base holds {}",
                diff.from_version,
                diff.to_version,
                data.len(),
                slot.len()
            );
            *slot = data.clone();
        }
        Ok(ParamSnapshot { version: diff.to_version, params })
    }
}

/// A version-chained parameter delta: only the tensors whose
/// per-tensor version advanced past `from_version`, stamped with the
/// store version the overlay reconstructs (`to_version`). Broadcast on
/// the Ready lane in place of a full [`ParamSnapshot`] when
/// `train.wire_snapshots = diff`; a worker chains
/// [`ParamSnapshot::apply_diff`] over the frames it receives in FIFO
/// order. Tensors are `Arc`-backed (the in-process transport moves the
/// diff without copying) and kept name-sorted so the wire encoding is
/// canonical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParamDiff {
    pub from_version: u64,
    pub to_version: u64,
    tensors: Vec<(String, Arc<Vec<f32>>)>,
}

impl ParamDiff {
    /// Rebuild a diff from decoded tensors (the TCP codec path).
    /// Re-sorts by name so a hand-built or adversarial frame cannot
    /// smuggle a non-canonical order past the chain.
    pub fn from_tensors(
        from_version: u64,
        to_version: u64,
        tensors: Vec<(String, Vec<f32>)>,
    ) -> ParamDiff {
        let mut tensors: Vec<(String, Arc<Vec<f32>>)> = tensors
            .into_iter()
            .map(|(name, data)| (name, Arc::new(data)))
            .collect();
        tensors.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        ParamDiff { from_version, to_version, tensors }
    }

    /// Every diffed tensor in canonical (name-sorted) order.
    pub fn tensors_sorted(&self) -> Vec<(&str, &[f32])> {
        self.tensors
            .iter()
            .map(|(n, d)| (n.as_str(), d.as_slice()))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Payload volume in tensor elements (bench accounting).
    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|(_, d)| d.len()).sum()
    }
}

/// What the leader broadcasts for one snapshot release: the first
/// frame of every chain (epoch start — which also covers the
/// post-recovery restart, since recovery re-enters the epoch) is a
/// full snapshot; later frames are diffs when the chain is enabled.
pub enum SnapOrDiff {
    Full(Arc<ParamSnapshot>),
    Diff(ParamDiff),
}

/// Leader-side diff-chain state, one per epoch per down lane. Tracks
/// the last store version broadcast so the next frame carries exactly
/// the tensors that advanced since.
pub struct DiffChain {
    last_sent: Option<u64>,
    enabled: bool,
}

impl DiffChain {
    pub fn new(enabled: bool) -> DiffChain {
        DiffChain { last_sent: None, enabled }
    }

    /// Produce the next frame of the chain for the store's current
    /// tensors and advance the chain cursor.
    pub fn next(&mut self, store: &ParamStore) -> SnapOrDiff {
        let base = self.last_sent;
        self.last_sent = Some(store.version());
        match base {
            Some(base) if self.enabled => SnapOrDiff::Diff(store.diff_since(base)),
            _ => SnapOrDiff::Full(Arc::new(store.snapshot())),
        }
    }
}

/// The wording a chain break surfaces with, shared by the worker-side
/// NACK bail and the leader-side gather abort so both ends of the wire
/// name the same versions (`have = u64::MAX` is the no-snapshot-yet
/// sentinel).
pub fn need_full_msg(have: u64, want: u64) -> String {
    let held = if have == u64::MAX {
        "holds no snapshot yet".to_string()
    } else {
        format!("holds v{have}")
    };
    format!(
        "needs a full parameter resync: it {held} but the diff chain expects \
         v{want} (restart the epoch; its first frame is always full)"
    )
}

/// Worker-side diff-chain state: the last reconstructed snapshot.
/// [`SnapshotChain::apply`] extends the chain by one diff; a gap or a
/// diff-before-full is the `NeedFull` condition and surfaces as an
/// error naming the rank and versions (the worker NACKs, the leader
/// aborts the round, and the recovery restart resyncs with a full
/// snapshot).
#[derive(Default)]
pub struct SnapshotChain {
    last: Option<Arc<ParamSnapshot>>,
}

impl SnapshotChain {
    pub fn new() -> SnapshotChain {
        SnapshotChain::default()
    }

    /// The version of the last snapshot on the chain, if any.
    pub fn version(&self) -> Option<u64> {
        self.last.as_ref().map(|s| s.version)
    }

    /// A full snapshot arrived: it becomes the new chain base.
    pub fn note_full(&mut self, snap: &Arc<ParamSnapshot>) {
        self.last = Some(snap.clone());
    }

    /// Extend the chain by one diff, returning the reconstructed
    /// snapshot.
    pub fn apply(&mut self, rank: usize, diff: &ParamDiff) -> Result<Arc<ParamSnapshot>> {
        let base = self.last.as_ref().with_context(|| {
            format!(
                "worker rank {rank}: a v{}..v{} param diff arrived before any full \
                 snapshot — a full resync is required",
                diff.from_version, diff.to_version
            )
        })?;
        let snap = Arc::new(base.apply_diff(diff).with_context(|| {
            format!(
                "worker rank {rank}: applying the v{}..v{} param diff",
                diff.from_version, diff.to_version
            )
        })?);
        self.last = Some(snap.clone());
        Ok(snap)
    }
}

impl ParamStore {
    pub fn new(seed: u64, hp: AdamParams) -> ParamStore {
        ParamStore {
            params: HashMap::new(),
            shapes: HashMap::new(),
            adam: HashMap::new(),
            seed,
            hp,
            version: 0,
            tensor_versions: HashMap::new(),
        }
    }

    fn name_seed(&self, name: &str) -> u64 {
        let mut h = self.seed ^ 0xCBF2_9CE4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01B3);
        }
        h
    }

    /// Get-or-init a parameter per its manifest spec (Glorot uniform).
    pub fn ensure(&mut self, spec: &InputSpec) {
        if self.params.contains_key(&spec.name) {
            return;
        }
        let n: usize = spec.shape.iter().product();
        let (fan_in, fan_out) = match spec.shape.len() {
            2 => (spec.shape[0], spec.shape[1]),
            1 => (spec.shape[0], spec.shape[0]),
            _ => (n, n),
        };
        let a = (6.0 / (fan_in + fan_out) as f64).sqrt();
        let mut rng = Rng::new(self.name_seed(&spec.name));
        let data: Vec<f32> = (0..n).map(|_| ((rng.f64() * 2.0 - 1.0) * a) as f32).collect();
        self.adam.insert(spec.name.clone(), Adam::new(n, self.hp));
        self.shapes.insert(spec.name.clone(), spec.shape.clone());
        self.tensor_versions.insert(spec.name.clone(), self.version);
        self.params.insert(spec.name.clone(), Arc::new(data));
    }

    /// Initialize every weight the named artifacts declare. Engines call
    /// this once at build time so the marshal stage (and the snapshots
    /// workers marshal from) never needs mutable access to the store —
    /// the init set is exactly the weights the engine's artifacts would
    /// have ensured lazily. Artifact names missing from the manifest are
    /// skipped (their absence surfaces at plan-build time instead).
    pub fn ensure_artifacts<'a>(
        &mut self,
        manifest: &Manifest,
        artifacts: impl IntoIterator<Item = &'a str>,
    ) {
        for name in artifacts {
            if let Some(spec) = manifest.artifacts.get(name) {
                for inp in spec.inputs.iter().filter(|i| i.kind == "weight") {
                    self.ensure(inp);
                }
            }
        }
    }

    pub fn get(&self, name: &str) -> &[f32] {
        &self.params[name]
    }

    /// Current store version — the value the next [`Self::snapshot`]
    /// would be stamped with. Gradients are tagged with the version of
    /// the snapshot their backward marshalled from, so the accumulator
    /// can verify every per-worker gradient of a batch was produced
    /// against the same weights (the stale-gradient contract of the
    /// bounded-staleness pipeline: under `train.staleness = k`, a
    /// batch's forward snapshot may trail the store by up to `k`
    /// updates, but all of one batch's gradients must agree).
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Capture a versioned read-only snapshot of every tensor (Arc
    /// bumps, no copies). The leader publishes one per batch.
    pub fn snapshot(&self) -> ParamSnapshot {
        ParamSnapshot {
            version: self.version,
            params: self.params.clone(),
        }
    }

    /// Apply one Adam step with the given gradient. Errors (instead of
    /// panicking) on an unknown parameter so a cluster worker/leader
    /// thread can surface the fault through its `Result` channel.
    /// Copy-on-write: a tensor still referenced by a published snapshot
    /// is cloned before the in-place update.
    pub fn step(&mut self, name: &str, grad: &[f32]) -> Result<()> {
        let p = self
            .params
            .get_mut(name)
            .with_context(|| format!("step on unknown parameter '{name}'"))?;
        self.adam
            .get_mut(name)
            .with_context(|| format!("missing Adam state for '{name}'"))?
            .step(Arc::make_mut(p), grad);
        self.version += 1;
        self.tensor_versions.insert(name.to_string(), self.version);
        Ok(())
    }

    /// Capture a version-chained delta: every tensor whose per-tensor
    /// version advanced past `base`, stamped `base..current`. A tensor
    /// with no version record is shipped (safe over-inclusion — the
    /// overlay is idempotent for unchanged data).
    pub fn diff_since(&self, base: u64) -> ParamDiff {
        let mut tensors: Vec<(String, Arc<Vec<f32>>)> = self
            .params
            .iter()
            .filter(|(name, _)| {
                self.tensor_versions
                    .get(name.as_str())
                    .copied()
                    .unwrap_or(u64::MAX)
                    > base
            })
            .map(|(n, d)| (n.clone(), d.clone()))
            .collect();
        tensors.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        ParamDiff { from_version: base, to_version: self.version, tensors }
    }

    /// Total parameter elements (gradient-allreduce volume accounting).
    pub fn total_elems(&self) -> usize {
        self.params.values().map(|v| v.len()).sum()
    }

    /// Export the full resumable optimizer state — every tensor with its
    /// shape and Adam moments, sorted by name (canonical order for the
    /// checkpoint codec) — plus the store version. A
    /// [`restore_state`](Self::restore_state) of the result reproduces
    /// the parameter trajectory bit-for-bit from this point.
    pub fn export_state(&self) -> ParamStoreState {
        let mut names: Vec<&String> = self.params.keys().collect();
        names.sort_unstable();
        let entries = names
            .into_iter()
            .map(|name| {
                let adam = &self.adam[name];
                ParamEntry {
                    name: name.clone(),
                    shape: self.shapes.get(name).cloned().unwrap_or_default(),
                    weight: self.params[name].as_ref().clone(),
                    m: adam.m.clone(),
                    v: adam.v.clone(),
                    t: adam.t,
                }
            })
            .collect();
        ParamStoreState { version: self.version, entries }
    }

    /// Replace this store's tensors, Adam moments and version with a
    /// previously exported state (checkpoint restore). Validates every
    /// entry's internal consistency; a later [`ensure`](Self::ensure)
    /// of a restored name is a no-op, so engines built after a restore
    /// keep the checkpointed weights.
    pub fn restore_state(&mut self, st: ParamStoreState) -> Result<()> {
        for e in &st.entries {
            let n = e.weight.len();
            anyhow::ensure!(
                e.m.len() == n && e.v.len() == n,
                "checkpointed parameter '{}': Adam moments ({}, {}) do not match \
                 the tensor length {n}",
                e.name,
                e.m.len(),
                e.v.len()
            );
            let shape_elems: usize = e.shape.iter().product();
            anyhow::ensure!(
                e.shape.is_empty() || shape_elems == n,
                "checkpointed parameter '{}': shape {:?} does not hold {n} elements",
                e.name,
                e.shape
            );
        }
        self.params.clear();
        self.shapes.clear();
        self.adam.clear();
        self.tensor_versions.clear();
        for e in st.entries {
            let mut adam = Adam::new(e.weight.len(), self.hp);
            adam.m = e.m;
            adam.v = e.v;
            adam.t = e.t;
            self.adam.insert(e.name.clone(), adam);
            self.shapes.insert(e.name.clone(), e.shape);
            self.tensor_versions.insert(e.name.clone(), st.version);
            self.params.insert(e.name, Arc::new(e.weight));
        }
        self.version = st.version;
        Ok(())
    }
}

/// One parameter tensor's full resumable state (weights + Adam moments).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub weight: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: i32,
}

/// The checkpointable state of a [`ParamStore`]: entries sorted by
/// name, plus the store version (the stale-gradient contract pins
/// folds to snapshot versions, so resumed runs must count from the
/// same value). Serialized by [`crate::ckpt`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParamStoreState {
    pub version: u64,
    pub entries: Vec<ParamEntry>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wspec(name: &str, shape: Vec<usize>) -> InputSpec {
        InputSpec {
            kind: "weight".into(),
            shape,
            name: name.into(),
            edge: -1,
            layer: 0,
            dtype: "f32".into(),
            init: "glorot".into(),
        }
    }

    #[test]
    fn param_store_deterministic_by_name() {
        let spec = wspec("W1_writes", vec![4, 8]);
        let mut a = ParamStore::new(7, AdamParams::default());
        let mut b = ParamStore::new(7, AdamParams::default());
        a.ensure(&spec);
        b.ensure(&spec);
        assert_eq!(a.get("W1_writes"), b.get("W1_writes"));
        let mut c = ParamStore::new(8, AdamParams::default());
        c.ensure(&spec);
        assert_ne!(a.get("W1_writes"), c.get("W1_writes"));
    }

    #[test]
    fn glorot_bounds() {
        let mut s = ParamStore::new(1, AdamParams::default());
        s.ensure(&wspec("w", vec![10, 10]));
        let a = (6.0f64 / 20.0).sqrt() as f32;
        assert!(s.get("w").iter().all(|&x| x.abs() <= a));
        assert!(s.get("w").iter().any(|&x| x != 0.0));
    }

    #[test]
    fn step_updates_parameters() {
        let mut s = ParamStore::new(1, AdamParams::default());
        s.ensure(&wspec("w", vec![4]));
        let before = s.get("w").to_vec();
        s.step("w", &[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_ne!(before.as_slice(), s.get("w"));
        assert_eq!(s.total_elems(), 4);
        assert!(s.step("missing", &[1.0]).is_err());
    }

    #[test]
    fn snapshots_are_versioned_and_copy_on_write() {
        let mut s = ParamStore::new(1, AdamParams::default());
        s.ensure(&wspec("w", vec![4]));
        let snap = s.snapshot();
        let frozen = snap.get("w").unwrap().to_vec();
        assert!(snap.get("missing").is_err());
        // A later optimizer step must never mutate the published
        // snapshot (workers may still be marshalling from it).
        s.step("w", &[1.0, 1.0, 1.0, 1.0]).unwrap();
        assert_eq!(snap.get("w").unwrap(), frozen.as_slice());
        assert_ne!(s.get("w"), frozen.as_slice());
        let snap2 = s.snapshot();
        assert!(snap2.version > snap.version, "steps must bump the version");
        assert_eq!(snap2.len(), 1);
        assert!(!snap2.is_empty());
    }

    #[test]
    fn export_restore_round_trips_the_trajectory() {
        let mut s = ParamStore::new(11, AdamParams::default());
        s.ensure(&wspec("w", vec![4]));
        s.ensure(&wspec("b", vec![2, 2]));
        s.step("w", &[0.5, -0.5, 0.25, -0.25]).unwrap();
        let st = s.export_state();
        assert_eq!(st.entries[0].name, "b", "entries must be name-sorted");

        // A fresh store restored from the state must continue the
        // trajectory bit-for-bit.
        let mut r = ParamStore::new(999, AdamParams::default());
        r.restore_state(st.clone()).unwrap();
        assert_eq!(r.version(), s.version());
        s.step("w", &[1.0; 4]).unwrap();
        r.step("w", &[1.0; 4]).unwrap();
        assert_eq!(s.get("w"), r.get("w"), "restored Adam moments must match");
        assert_eq!(s.get("b"), r.get("b"));

        // Inconsistent moments are an error, not a panic.
        let mut bad = st;
        bad.entries[0].m.pop();
        assert!(r.restore_state(bad).is_err());
    }

    #[test]
    fn diff_since_ships_only_advanced_tensors() {
        let mut s = ParamStore::new(5, AdamParams::default());
        s.ensure(&wspec("a", vec![4]));
        s.ensure(&wspec("b", vec![4]));
        let base = s.version();
        assert!(s.diff_since(base).is_empty(), "no steps yet: empty diff");
        s.step("a", &[1.0; 4]).unwrap();
        let diff = s.diff_since(base);
        assert_eq!(diff.len(), 1, "only 'a' advanced");
        assert_eq!(diff.tensors_sorted()[0].0, "a");
        assert_eq!(diff.from_version, base);
        assert_eq!(diff.to_version, s.version());
        assert_eq!(diff.total_elems(), 4);
        // diff_since(0) after init ships everything (init tags tensors).
        assert_eq!(s.diff_since(0).len(), 1, "init happened at v0, only the step advanced past it");
    }

    #[test]
    fn apply_diff_reconstructs_bit_exactly_and_rejects_gaps() {
        let mut s = ParamStore::new(5, AdamParams::default());
        s.ensure(&wspec("a", vec![4]));
        s.ensure(&wspec("b", vec![2]));
        let snap0 = Arc::new(s.snapshot());
        s.step("a", &[0.5, -0.5, 0.25, -0.25]).unwrap();
        let diff = s.diff_since(snap0.version);
        let snap1 = snap0.apply_diff(&diff).unwrap();
        assert_eq!(snap1, s.snapshot(), "overlay must reconstruct bit-exactly");

        // A second step: the old diff no longer chains onto snap1.
        s.step("b", &[1.0, 1.0]).unwrap();
        let err = snap1.apply_diff(&diff).unwrap_err().to_string();
        assert!(err.contains("chain break"), "got: {err}");
        assert!(err.contains(&format!("v{}", snap1.version)), "names versions: {err}");

        // Unknown tensors and wrong lengths are corrupt, not panics.
        let bogus = ParamDiff::from_tensors(snap1.version, snap1.version + 1, vec![
            ("nope".to_string(), vec![1.0]),
        ]);
        assert!(snap1.apply_diff(&bogus).unwrap_err().to_string().contains("corrupt"));
        let resized = ParamDiff::from_tensors(snap1.version, snap1.version + 1, vec![
            ("a".to_string(), vec![1.0]),
        ]);
        assert!(snap1.apply_diff(&resized).unwrap_err().to_string().contains("corrupt"));
        // Backwards chains are rejected before any overlay work.
        let backwards = ParamDiff::from_tensors(5, 4, vec![]);
        assert!(snap1.apply_diff(&backwards).is_err());
    }

    #[test]
    fn diff_chain_full_then_diffs_and_worker_chain_tracks() {
        let mut s = ParamStore::new(9, AdamParams::default());
        s.ensure(&wspec("w", vec![4]));
        let mut leader = DiffChain::new(true);
        let mut worker = SnapshotChain::new();
        assert!(worker.version().is_none());

        // First frame of the epoch is always full.
        match leader.next(&s) {
            SnapOrDiff::Full(snap) => worker.note_full(&snap),
            SnapOrDiff::Diff(_) => panic!("chain must open with a full snapshot"),
        }
        for i in 0..4 {
            s.step("w", &[i as f32 + 1.0; 4]).unwrap();
            match leader.next(&s) {
                SnapOrDiff::Diff(diff) => {
                    let snap = worker.apply(0, &diff).unwrap();
                    assert_eq!(*snap, s.snapshot(), "step {i}: reconstruction diverged");
                }
                SnapOrDiff::Full(_) => panic!("later frames must be diffs"),
            }
        }
        assert_eq!(worker.version(), Some(s.version()));

        // A diff arriving before any full snapshot names the rank.
        let mut cold = SnapshotChain::new();
        let err = cold.apply(3, &s.diff_since(0)).unwrap_err().to_string();
        assert!(err.contains("rank 3") && err.contains("full"), "got: {err}");

        // Disabled chains ship full snapshots forever.
        let mut full_only = DiffChain::new(false);
        for _ in 0..2 {
            assert!(matches!(full_only.next(&s), SnapOrDiff::Full(_)));
        }
    }

    #[test]
    fn ensure_artifacts_initializes_declared_weights_only() {
        let mut artifacts = HashMap::new();
        artifacts.insert(
            "a".to_string(),
            ArtifactSpec {
                inputs: vec![
                    wspec("w_a", vec![2, 2]),
                    InputSpec { kind: "block".into(), ..wspec("not_a_weight", vec![4]) },
                ],
                outputs: vec![],
            },
        );
        artifacts.insert(
            "b".to_string(),
            ArtifactSpec { inputs: vec![wspec("w_b", vec![3])], outputs: vec![] },
        );
        let manifest = Manifest { config: String::new(), arch: String::new(), artifacts };
        let mut s = ParamStore::new(3, AdamParams::default());
        s.ensure_artifacts(&manifest, ["a", "nonexistent"]);
        assert!(s.params.contains_key("w_a"));
        assert!(!s.params.contains_key("w_b"), "artifact b was not requested");
        assert!(!s.params.contains_key("not_a_weight"));
    }
}
