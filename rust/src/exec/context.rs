//! Per-worker execution contexts and the epoch-scoped shared world.

use std::sync::{Arc, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::cache::FeatureCache;
use crate::config::Config;
use crate::hetgraph::{HetGraph, MetaTree};
use crate::kvstore::FeatureStore;
use crate::runtime::{Manifest, ParamSnapshot, ParamStore, Runtime};

/// Everything one worker **owns** for artifact execution: its own PJRT
/// client with its own compiled executables and its partition's feature
/// cache. Cluster worker threads hold an exclusive `&mut ExecContext`
/// for the whole epoch; the sequential runtime iterates the same
/// contexts one at a time. The type is `Send` by construction — moving
/// a context to a worker thread needs no lock, which is the
/// compile-level guarantee `tests/test_exec_contexts.rs` pins.
///
/// Marshalling scratch is *not* part of the context since PR 4: a
/// [`BatchArena`](super::BatchArena) is scoped to one batch's
/// forward→backward lifetime, because the staleness window lets a
/// worker open batch `i+1`'s forward before batch `i`'s backward ran —
/// two batches' staged rows are then alive at once. Schedulers own the
/// arenas (one per in-flight batch, recycled through a pool) and pass
/// them into the stage functions.
pub struct ExecContext {
    /// Worker / partition id this context belongs to.
    pub worker: usize,
    /// GPU index of this worker on its machine (for the cache's
    /// non-replicative split accounting).
    pub gpu: usize,
    /// This worker's own artifact registry: one PJRT client, executables
    /// compiled lazily on first use.
    pub rt: Runtime,
    /// The partition's feature cache (`None` for cache-less baselines).
    pub cache: Option<FeatureCache>,
}

impl ExecContext {
    /// Build the context for `worker`, creating its own PJRT client over
    /// the shared parsed manifest.
    pub fn new(
        worker: usize,
        gpu: usize,
        artifacts_dir: &str,
        manifest: Arc<Manifest>,
        cache: Option<FeatureCache>,
    ) -> Result<ExecContext> {
        let rt = Runtime::with_manifest(artifacts_dir, manifest)
            .with_context(|| format!("execution context for worker {worker}"))?;
        Ok(ExecContext {
            worker,
            gpu,
            rt,
            cache,
        })
    }

    /// Build a context whose PJRT client is deferred until an artifact
    /// is actually compiled. The role-gated TCP path uses this for
    /// every rank the current process does **not** run: the context
    /// still carries the partition's cache (the leader's fork ledgers
    /// read foreign caches) but never instantiates a client, so a
    /// K-worker cluster holds K+1 PJRT clients total instead of
    /// (K+1)².
    pub fn deferred(
        worker: usize,
        gpu: usize,
        artifacts_dir: &str,
        manifest: Arc<Manifest>,
        cache: Option<FeatureCache>,
    ) -> ExecContext {
        ExecContext {
            worker,
            gpu,
            rt: Runtime::deferred(artifacts_dir, manifest),
            cache,
        }
    }
}

/// The `train.shared_session = true` escape hatch: a serialization
/// token acquired around every marshal+execute stage, reproducing the
/// pre-PR-3 behavior where all artifact executions serialized on one
/// shared session. Used only for A/B timing (`benches/exec_overlap.rs`);
/// per-worker contexts (the default) never construct one. Lives in the
/// exec layer on purpose — the cluster runtime itself is lock-free.
#[derive(Default)]
pub struct ExecGate {
    token: Mutex<()>,
}

impl ExecGate {
    pub fn new() -> ExecGate {
        ExecGate::default()
    }

    /// Hold the returned guard for the duration of one serialized
    /// marshal+execute stage. Poisoning is impossible to observe
    /// meaningfully here (the token guards no data), so a poisoned
    /// token is re-entered rather than treated as an error.
    pub fn acquire(&self) -> MutexGuard<'_, ()> {
        self.token.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// The state every worker shares **read-only** during one epoch, plus
/// the epoch's wall-clock origin for overlap spans. The feature store
/// sits behind a reader-writer lock: marshal stages take concurrent
/// read guards, and the leader's update stage (the only writer) runs in
/// a protocol phase where no worker is marshalling.
pub struct EpochWorld<'a> {
    pub cfg: &'a Config,
    pub g: &'a HetGraph,
    pub tree: &'a MetaTree,
    pub store: &'a RwLock<FeatureStore>,
    /// `Some` iff `train.shared_session` — the serialized escape hatch.
    pub gate: Option<&'a ExecGate>,
    /// Wall-clock origin; forward-execution spans are recorded relative
    /// to it so the timeline can show per-context overlap.
    pub epoch_t0: Instant,
}

impl<'a> EpochWorld<'a> {
    /// Read access to the feature KV store (concurrent across workers).
    pub fn store(&self) -> RwLockReadGuard<'a, FeatureStore> {
        self.store.read().unwrap_or_else(|e| e.into_inner())
    }

    /// Write access for the leader's update stage.
    pub fn store_mut(&self) -> RwLockWriteGuard<'a, FeatureStore> {
        self.store.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Acquire the serialization token if the shared-session escape
    /// hatch is active; a no-op (`None`) under per-worker contexts.
    pub fn serialize(&self) -> Option<MutexGuard<'a, ()>> {
        self.gate.map(|g| g.acquire())
    }

    /// Seconds since the epoch's wall-clock origin.
    pub fn now(&self) -> f64 {
        self.epoch_t0.elapsed().as_secs_f64()
    }
}

/// How a marshal stage reads parameters: the sequential runtime and the
/// leader read the store they own; cluster workers read the batch's
/// broadcast snapshot. Both views yield byte-identical tensors — the
/// snapshot is a copy-on-write capture of the same store.
#[derive(Clone, Copy)]
pub enum ParamsView<'a> {
    Owner(&'a ParamStore),
    Snapshot(&'a ParamSnapshot),
}

impl<'a> ParamsView<'a> {
    pub fn get(&self, name: &str) -> Result<&'a [f32]> {
        match self {
            ParamsView::Owner(store) => {
                store
                    .params
                    .get(name)
                    .map(|v| v.as_slice())
                    .with_context(|| format!("parameter '{name}' not initialized (ensure_artifacts)"))
            }
            ParamsView::Snapshot(snap) => snap.get(name),
        }
    }

    /// Version of the weights this view reads — the snapshot's stamp,
    /// or the store's live version for the owner. Gradients produced
    /// from a view are tagged with it so the accumulator can enforce
    /// the one-snapshot-per-batch contract (see
    /// [`crate::exec::plan::GradAccumulator`]).
    pub fn version(&self) -> u64 {
        match self {
            ParamsView::Owner(store) => store.version(),
            ParamsView::Snapshot(snap) => snap.version,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_context_is_send() {
        // The whole point of per-worker contexts: moving one to a worker
        // thread requires no lock. Compile-time assertion.
        fn assert_send<T: Send>() {}
        assert_send::<ExecContext>();
        assert_send::<super::super::marshal::BatchArena>();
    }

    #[test]
    fn gate_serializes_and_recovers_from_poison() {
        let gate = ExecGate::new();
        {
            let _g = gate.acquire();
        }
        let _g2 = gate.acquire();
    }

    #[test]
    fn params_view_reads_owner_and_snapshot_identically() {
        use crate::optim::AdamParams;
        use crate::runtime::InputSpec;
        let mut store = ParamStore::new(5, AdamParams::default());
        store.ensure(&InputSpec {
            kind: "weight".into(),
            shape: vec![2, 3],
            name: "w".into(),
            edge: -1,
            layer: 0,
            dtype: "f32".into(),
            init: "glorot".into(),
        });
        let snap = store.snapshot();
        let owner = ParamsView::Owner(&store);
        let view = ParamsView::Snapshot(&snap);
        assert_eq!(owner.get("w").unwrap(), view.get("w").unwrap());
        assert_eq!(owner.version(), view.version(), "fresh snapshot shares the store version");
        assert!(owner.get("nope").is_err());
        assert!(view.get("nope").is_err());
    }
}
