//! The marshal stage: turn a [`TreeSample`] plus an artifact's manifest
//! input specs into the flat literal list a PJRT executable consumes.
//! Moved here from `coordinator/common.rs` when the exec layer landed —
//! the stage is written once and driven by every engine.
//!
//! The hot path is the **deduplicated-frontier gather**: when the caller
//! supplies a batch [`Frontier`], each node type's distinct rows are
//! fetched once per batch into a [`BatchArena`] staging buffer
//! ([`FeatureStore::gather_unique`]), the cache model is consulted once
//! per unique id with misses charged as one batched staging transfer
//! ([`FeatureCache::access_unique`]), and every padded block literal is
//! produced by an in-memory scatter. Without a frontier
//! (`train.dedup_fetch = false`) the seed's per-slot gather and
//! per-occurrence cache accounting are reproduced exactly, which is the
//! A/B baseline. Gathered bytes are identical either way — only where
//! the copies and charges happen moves — so losses are byte-identical
//! across both settings and both runtimes.
//!
//! Unlike the pre-exec-layer version, marshalling is **read-only over
//! shared state**: weights come from a [`ParamsView`] (leader store or
//! broadcast snapshot — both initialized up front via
//! [`ParamStore::ensure_artifacts`](crate::runtime::ParamStore::ensure_artifacts)),
//! and the feature store is borrowed behind a read guard. All mutation
//! lands in the caller-owned [`BatchArena`] and cache ledgers.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::cache::FeatureCache;
use crate::comm::CostModel;
use crate::hetgraph::{HetGraph, MetaTree, NodeId};
use crate::kvstore::{scatter_rows, FeatureStore};
use crate::runtime::{lit_f32, lit_i32, ArtifactSpec};
use crate::sampling::{Frontier, TreeSample, PAD};

use super::context::ParamsView;

/// Extra per-batch inputs supplied by the engine (leader partial sums,
/// backward gradients), keyed by (kind, layer).
pub type ExtraInputs = HashMap<(String, usize), Vec<f32>>;

/// Child vertex and source type of a metatree edge.
pub fn edge_child(g: &HetGraph, tree: &MetaTree, edge: usize) -> (usize, usize) {
    let e = &tree.edges[edge];
    (e.child, g.schema.relations[e.rel].src)
}

/// Aggregate fetch accounting of one input build.
///
/// With a dedup frontier, `stats` counts **unique** rows only (each
/// distinct id fetched once per batch); without one it counts padded
/// slots, matching the seed accounting.
#[derive(Debug, Clone, Default)]
pub struct GatherAccounting {
    pub stats: crate::kvstore::FetchStats,
    /// Modeled cache/miss time (Fetch stage), all node types.
    pub cache_time_s: f64,
    /// The read-only share of `cache_time_s`. Read-only rows are
    /// immutable during training, so the cluster pipeline may prefetch
    /// them for batch `i+1` while batch `i` executes; learnable rows
    /// (the remainder) must wait for batch `i`'s update.
    pub cache_time_ro_s: f64,
}

/// Reusable per-worker marshalling scratch, recycled across batches so
/// the input-build hot loop performs no steady-state allocation. Owned
/// by a worker's [`ExecContext`](super::ExecContext).
///
/// `staging[ty]` holds the batch frontier's distinct rows of type `ty`,
/// gathered once per batch on first use and then scattered into every
/// padded block literal that references the type — including the
/// backward pass's rebuild of the same batch (feature rows cannot change
/// between a batch's forward and backward, so restaging would be pure
/// waste). `block` / `mask` / `labels` are literal scratch: literals
/// copy out of them, so one buffer serves every input of every batch.
#[derive(Debug, Default)]
pub struct BatchArena {
    staging: Vec<Vec<f32>>,
    staged: Vec<bool>,
    block: Vec<f32>,
    mask: Vec<f32>,
    labels: Vec<i32>,
}

impl BatchArena {
    pub fn new() -> BatchArena {
        BatchArena::default()
    }

    /// Invalidate the per-batch staging (learnable rows may have been
    /// updated since the previous batch); buffer capacity survives.
    /// Call once per (worker, batch) before the batch's first
    /// `build_inputs`; later builds of the *same* batch (the backward
    /// pass) then reuse the staged rows.
    pub fn begin_batch(&mut self, num_types: usize) {
        self.staged.clear();
        self.staged.resize(num_types, false);
        if self.staging.len() < num_types {
            self.staging.resize_with(num_types, Vec::new);
        }
    }

    /// Grow-and-slice helper for the literal scratch buffers.
    fn block_slice(&mut self, n: usize) -> &mut [f32] {
        if self.block.len() < n {
            self.block.resize(n, 0.0);
        }
        &mut self.block[..n]
    }
}

/// The read-only world one marshal call runs against: cost model, graph
/// topology, the feature store (borrowed behind the caller's read
/// guard), and the parameter view. Mutable state (cache ledgers, arena)
/// is passed separately — it is per-worker-owned.
pub struct MarshalEnv<'a> {
    pub cost: &'a CostModel,
    pub g: &'a HetGraph,
    pub tree: &'a MetaTree,
    pub store: &'a FeatureStore,
    pub params: ParamsView<'a>,
}

/// Fetch `ty`'s distinct frontier rows into the arena staging buffer —
/// once per batch — merging unique-row fetch stats and the batched
/// cache accounting on first staging only.
#[allow(clippy::too_many_arguments)]
fn stage_type(
    store: &FeatureStore,
    cost: &CostModel,
    fr: &Frontier,
    ty: usize,
    is_remote: &dyn Fn(usize, NodeId) -> bool,
    cache: &mut Option<&mut FeatureCache>,
    gpu: usize,
    arena: &mut BatchArena,
    acc: &mut GatherAccounting,
) -> Result<()> {
    // `begin_batch` owns the per-batch invalidation; a missing call must
    // fail fast (index panic / this assert), never silently scatter the
    // previous batch's staged rows.
    debug_assert!(
        arena.staged.len() > ty && arena.staging.len() > ty,
        "stage_type before BatchArena::begin_batch"
    );
    if arena.staged[ty] {
        return Ok(());
    }
    let uniq = fr.rows(ty);
    let dim = store.dim(ty);
    let buf = &mut arena.staging[ty];
    buf.resize(uniq.len() * dim, 0.0);
    let stats = store.gather_unique(ty, uniq, buf, |id| is_remote(ty, id))?;
    acc.stats.merge(stats);
    if let Some(c) = cache.as_deref_mut() {
        let t = c.access_unique(cost, ty, uniq, gpu);
        acc.cache_time_s += t;
        if !store.is_learnable(ty) {
            acc.cache_time_ro_s += t;
        }
    }
    arena.staged[ty] = true;
    Ok(())
}

/// Build the literal list for an artifact from its manifest spec.
///
/// `sample` provides block/mask ids, `extra` provides engine-computed
/// tensors (partial sums / gradients), `is_remote` classifies feature
/// rows for locality accounting, and `cache` (if present) accumulates
/// modeled miss time. With `frontier` present (the dedup hot path),
/// feature rows are staged once per distinct id through `arena` and
/// scattered into the padded literals; with `frontier = None` the
/// seed's per-slot gather and per-occurrence cache accounting run
/// instead (byte-identical literals either way).
#[allow(clippy::too_many_arguments)]
pub fn build_inputs(
    env: &MarshalEnv<'_>,
    spec: &ArtifactSpec,
    sample: Option<&TreeSample>,
    frontier: Option<&Frontier>,
    batch: &[NodeId],
    extra: &ExtraInputs,
    is_remote: &dyn Fn(usize, NodeId) -> bool,
    cache: Option<&mut FeatureCache>,
    gpu: usize,
    arena: &mut BatchArena,
) -> Result<(Vec<xla::Literal>, GatherAccounting)> {
    let mut acc = GatherAccounting::default();
    let mut lits = Vec::with_capacity(spec.inputs.len());
    let cost = env.cost;
    let mut cache = cache;
    for inp in &spec.inputs {
        match inp.kind.as_str() {
            "block" => {
                let sample = sample.ok_or_else(|| anyhow!("block input without sample"))?;
                let (child, src_ty) = edge_child(env.g, env.tree, inp.edge as usize);
                let ids = &sample.ids[child];
                let dim = env.store.dim(src_ty);
                let need = ids.len() * dim;
                if let Some(fr) = frontier {
                    // Dedup path: stage distinct rows once, then scatter
                    // slots from staging (every slot written: copies for
                    // valid rows, zero-fill for pads).
                    stage_type(
                        env.store,
                        cost,
                        fr,
                        src_ty,
                        is_remote,
                        &mut cache,
                        gpu,
                        arena,
                        &mut acc,
                    )?;
                    if arena.block.len() < need {
                        arena.block.resize(need, 0.0);
                    }
                    scatter_rows(
                        &arena.staging[src_ty],
                        &fr.slot_to_unique[child],
                        dim,
                        &mut arena.block[..need],
                    );
                    lits.push(lit_f32(&arena.block[..need], &inp.shape)?);
                } else {
                    // Seed path: every padded slot gathered independently,
                    // cache consulted per occurrence.
                    let buf = arena.block_slice(need);
                    let stats = env
                        .store
                        .gather(src_ty, ids, buf, |id| is_remote(src_ty, id))?;
                    acc.stats.merge(stats);
                    if let Some(c) = cache.as_deref_mut() {
                        let learnable = env.store.is_learnable(src_ty);
                        for &id in ids.iter().filter(|&&id| id != PAD) {
                            let t = c.access(cost, src_ty, id, gpu, false);
                            acc.cache_time_s += t;
                            if !learnable {
                                acc.cache_time_ro_s += t;
                            }
                        }
                    }
                    lits.push(lit_f32(&arena.block[..need], &inp.shape)?);
                }
            }
            "mask" => {
                let sample = sample.ok_or_else(|| anyhow!("mask input without sample"))?;
                let (child, _) = edge_child(env.g, env.tree, inp.edge as usize);
                let ids = &sample.ids[child];
                if arena.mask.len() < ids.len() {
                    arena.mask.resize(ids.len(), 0.0);
                }
                let mask = &mut arena.mask[..ids.len()];
                for (m, &id) in mask.iter_mut().zip(ids) {
                    *m = if id == PAD { 0.0 } else { 1.0 };
                }
                lits.push(lit_f32(mask, &inp.shape)?);
            }
            "weight" => {
                lits.push(lit_f32(env.params.get(&inp.name)?, &inp.shape)?);
            }
            "target_feat" => {
                let ty = env.g.schema.target;
                let dim = env.store.dim(ty);
                let need = batch.len() * dim;
                if let Some(fr) = frontier {
                    stage_type(
                        env.store,
                        cost,
                        fr,
                        ty,
                        is_remote,
                        &mut cache,
                        gpu,
                        arena,
                        &mut acc,
                    )?;
                    if arena.block.len() < need {
                        arena.block.resize(need, 0.0);
                    }
                    let block = &mut arena.block[..need];
                    let staging = &arena.staging[ty];
                    for (i, &id) in batch.iter().enumerate() {
                        let dst = &mut block[i * dim..(i + 1) * dim];
                        match fr.unique_index(ty, id) {
                            Some(u) => dst.copy_from_slice(&staging[u * dim..(u + 1) * dim]),
                            None => {
                                // Defensive: callers whose spec gathers
                                // target features build the frontier with
                                // `include_root`, which covers the batch;
                                // an out-of-frontier id falls back to a
                                // per-row gather with its own accounting.
                                let stats = env.store.gather(
                                    ty,
                                    std::slice::from_ref(&id),
                                    dst,
                                    |id| is_remote(ty, id),
                                )?;
                                acc.stats.merge(stats);
                                if let Some(c) = cache.as_deref_mut() {
                                    let t = c.access(cost, ty, id, gpu, false);
                                    acc.cache_time_s += t;
                                    if !env.store.is_learnable(ty) {
                                        acc.cache_time_ro_s += t;
                                    }
                                }
                            }
                        }
                    }
                    lits.push(lit_f32(&arena.block[..need], &inp.shape)?);
                } else {
                    let buf = arena.block_slice(need);
                    let stats = env.store.gather(ty, batch, buf, |id| is_remote(ty, id))?;
                    acc.stats.merge(stats);
                    if let Some(c) = cache.as_deref_mut() {
                        let learnable = env.store.is_learnable(ty);
                        for &id in batch {
                            let t = c.access(cost, ty, id, gpu, false);
                            acc.cache_time_s += t;
                            if !learnable {
                                acc.cache_time_ro_s += t;
                            }
                        }
                    }
                    lits.push(lit_f32(&arena.block[..need], &inp.shape)?);
                }
            }
            "labels" => {
                arena.labels.clear();
                arena
                    .labels
                    .extend(batch.iter().map(|&b| env.g.labels[b as usize] as i32));
                lits.push(lit_i32(&arena.labels, &inp.shape)?);
            }
            "partial_sum" | "grad" => {
                let key = (inp.kind.clone(), inp.layer);
                let data = extra
                    .get(&key)
                    .ok_or_else(|| anyhow!("missing extra input {key:?}"))?;
                lits.push(lit_f32(data, &inp.shape)?);
            }
            other => anyhow::bail!("unknown input kind '{other}'"),
        }
    }
    Ok((lits, acc))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_begin_batch_invalidates_staging_keeps_capacity() {
        let mut a = BatchArena::new();
        a.begin_batch(3);
        a.staging[1].resize(128, 1.0);
        a.staged[1] = true;
        let cap = a.staging[1].capacity();
        a.begin_batch(3);
        assert!(a.staged.iter().all(|&s| !s), "staging must be invalidated");
        assert!(a.staging[1].capacity() >= cap, "buffers must be recycled");
    }
}
