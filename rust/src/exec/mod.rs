//! The execution layer (PR 3): per-worker execution contexts and the
//! unified per-batch stage pipeline shared by all four engine drivers.
//!
//! Before this layer existed, the per-batch marshal → forward →
//! partial-agg exchange → backward → update bodies were copy-pasted
//! four ways across `coordinator/{raf,vanilla}.rs` and
//! `cluster/{raf,vanilla}.rs`, and every artifact execution serialized
//! on one `Mutex`-guarded monolithic `Session`. The split:
//!
//! * [`ExecContext`] — what one worker *owns*: its own PJRT client and
//!   lazily compiled executables ([`crate::runtime::Runtime`]), its
//!   feature cache, and its marshalling scratch ([`BatchArena`]). Each
//!   cluster worker thread holds an exclusive `&mut ExecContext`, so
//!   forward/backward of different partitions genuinely run
//!   concurrently — there is no shared session and no lock around
//!   artifact execution.
//! * [`ParamSnapshot`](crate::runtime::ParamSnapshot) /
//!   [`ParamsView`] — parameters are leader-owned and distributed per
//!   batch as a versioned read-only snapshot broadcast through the
//!   collectives (copy-on-write, so a published snapshot can never be
//!   mutated under a marshalling worker). The sequential runtime reads
//!   the store directly through [`ParamsView::Owner`]; byte-identical
//!   either way.
//! * [`EpochWorld`] — the state workers share read-only during an
//!   epoch: config, graph, metatree, and the feature KV store behind a
//!   reader-writer lock (many concurrent marshal-stage readers; the
//!   leader's update stage is the only writer, and the two phases never
//!   overlap in the batch protocol).
//! * [`BatchPlan`] — the per-batch stage pipeline, expressed **once**:
//!   resolved artifact specs per worker plus the stage functions
//!   (`raf_forward`, `raf_leader_step`, `raf_backward`,
//!   `raf_apply_updates`, `vanilla_step`, `vanilla_apply_updates`).
//!   The four engine drivers are thin schedulers over these stages.
//! * [`ExecGate`] — the `train.shared_session = true` escape hatch: an
//!   explicit serialization token that reproduces the pre-PR-3
//!   one-execution-at-a-time behavior for A/B timing. Losses are
//!   byte-identical across both settings and both runtimes regardless
//!   (reductions fold in worker-id order).
//!
//! PR 4 made the stages **resumable** for the bounded-staleness
//! pipeline (`train.staleness = k`): arenas are batch-scoped rather
//! than context-owned (a worker inside the window keeps up to `k + 1`
//! batches open as [`InFlight`] state, each owning the arena its
//! backward rebuild scatters from), the vanilla fused step splits at
//! its marshal/execute boundary (so the windowed worker can announce
//! its feature-store reads are done — the leader's update barrier),
//! and every [`WorkerGrads`] carries the `ParamSnapshot` version it was
//! produced against, which [`GradAccumulator`] enforces per batch (the
//! stale-gradient contract). At `k = 0` all of this is inert and the
//! synchronous protocol is reproduced byte-for-byte.

pub mod context;
pub mod marshal;
pub mod plan;

pub use context::{EpochWorld, ExecContext, ExecGate, ParamsView};
pub use marshal::{build_inputs, BatchArena, ExtraInputs, GatherAccounting, MarshalEnv};
pub use plan::{BatchPlan, GradAccumulator, InFlight, WorkerGrads, WorkerPlan};
