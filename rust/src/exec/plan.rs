//! The per-batch stage pipeline, expressed once.
//!
//! A [`BatchPlan`] resolves, at engine-build time, which artifact each
//! worker role executes per stage (RAF: `worker_fwd_p*` → `leader` →
//! `worker_bwd_p*`; vanilla: the fused `vanilla` step) and carries the
//! stage functions every runtime drives:
//!
//! ```text
//! marshal → forward → partial-agg exchange → backward → update
//! ```
//!
//! The four engine drivers — `coordinator/{raf,vanilla}.rs` (sequential
//! scheduling) and `cluster/{raf,vanilla}.rs` (thread-per-partition
//! scheduling) — differ only in *when* and *on which thread* each stage
//! runs and how its results move (direct calls vs. collectives). The
//! stage bodies themselves live here and are written once, so an
//! execution-model change (e.g. backward-of-`i` / forward-of-`i+1`
//! overlap) is implemented in one place.
//!
//! Determinism contract: stage functions never reduce across workers —
//! they return per-worker results, and [`GradAccumulator`] folds them
//! in (worker, output) order, exactly the order the sequential engine
//! uses, so losses and parameter trajectories are byte-identical across
//! runtimes, `shared_session` settings, and thread interleavings.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cache::FeatureCache;
use crate::comm::{CostModel, Lane, SimNet};
use crate::hetgraph::NodeId;
use crate::kvstore::{FeatureStore, FetchStats};
use crate::metrics::timeline::WorkerSpan;
use crate::metrics::{Stage, StageTimes};
use crate::optim::AdamParams;
use crate::partition::NodePartition;
use crate::runtime::{lit_scalar, lit_to_vec, ArtifactSpec, Manifest, ParamStore};
use crate::sampling::{Frontier, TreeSample, PAD};
use crate::util::{add_assign, scale};

use super::context::{EpochWorld, ExecContext, ParamsView};
use super::marshal::{
    build_inputs, edge_child, BatchArena, ExtraInputs, GatherAccounting, MarshalEnv,
};

/// One worker role's resolved artifacts within a [`BatchPlan`].
pub struct WorkerPlan {
    /// Forward artifact (`worker_fwd_p{p}`) or the fused train step
    /// (`vanilla`).
    pub fwd_art: String,
    pub spec_fwd: ArtifactSpec,
    /// Backward artifact (`worker_bwd_p{p}`); `None` when the forward
    /// artifact is a fused fwd+bwd step.
    pub bwd_art: Option<String>,
    pub spec_bwd: Option<ArtifactSpec>,
    /// Whether the forward artifact gathers target features — only then
    /// do root rows join this worker's dedup frontier.
    pub needs_root: bool,
}

/// The engine's per-batch stage pipeline: one [`WorkerPlan`] per
/// partition plus the RAF cross-relation leader artifact (absent for
/// the vanilla engine, whose exchange stage is the dense all-reduce).
pub struct BatchPlan {
    pub workers: Vec<WorkerPlan>,
    pub leader_art: String,
    pub leader_spec: Option<ArtifactSpec>,
}

impl BatchPlan {
    /// Resolve the RAF pipeline: per-partition forward/backward worker
    /// artifacts plus the `leader` cross-relation step.
    pub fn raf(manifest: &Manifest, parts: usize) -> Result<BatchPlan> {
        let mut workers = Vec::with_capacity(parts);
        for p in 0..parts {
            let fwd_art = format!("worker_fwd_p{p}");
            let bwd_art = format!("worker_bwd_p{p}");
            let spec_fwd = manifest.spec(&fwd_art)?.clone();
            let spec_bwd = manifest.spec(&bwd_art)?.clone();
            let needs_root = spec_fwd.inputs.iter().any(|i| i.kind == "target_feat");
            workers.push(WorkerPlan {
                fwd_art,
                spec_fwd,
                bwd_art: Some(bwd_art),
                spec_bwd: Some(spec_bwd),
                needs_root,
            });
        }
        Ok(BatchPlan {
            workers,
            leader_art: "leader".to_string(),
            leader_spec: Some(manifest.spec("leader")?.clone()),
        })
    }

    /// Resolve the forward-only serving pipeline: the per-partition
    /// `worker_fwd_p*` artifacts with **no** backward and **no** leader
    /// step. Serving never touches gradients or the optimizer, and the
    /// fused `vanilla` artifact has no standalone embedding output, so
    /// both engines serve through this decomposition; the per-target
    /// embedding is the worker partials folded in worker order (the same
    /// fold the training leader stage consumes).
    pub fn forward_only(manifest: &Manifest, parts: usize) -> Result<BatchPlan> {
        let mut workers = Vec::with_capacity(parts);
        for p in 0..parts {
            let fwd_art = format!("worker_fwd_p{p}");
            let spec_fwd = manifest.spec(&fwd_art)?.clone();
            let needs_root = spec_fwd.inputs.iter().any(|i| i.kind == "target_feat");
            workers.push(WorkerPlan {
                fwd_art,
                spec_fwd,
                bwd_art: None,
                spec_bwd: None,
                needs_root,
            });
        }
        Ok(BatchPlan {
            workers,
            leader_art: String::new(),
            leader_spec: None,
        })
    }

    /// Resolve the vanilla pipeline: every worker drives the same fused
    /// `vanilla` train-step artifact; there is no leader artifact.
    pub fn vanilla(manifest: &Manifest, parts: usize) -> Result<BatchPlan> {
        let spec = manifest.spec("vanilla")?.clone();
        let needs_root = spec.inputs.iter().any(|i| i.kind == "target_feat");
        let workers = (0..parts)
            .map(|_| WorkerPlan {
                fwd_art: "vanilla".to_string(),
                spec_fwd: spec.clone(),
                bwd_art: None,
                spec_bwd: None,
                needs_root,
            })
            .collect();
        Ok(BatchPlan {
            workers,
            leader_art: String::new(),
            leader_spec: None,
        })
    }
}

/// Where a `target_feat_grad` output goes during gradient collection.
pub enum TargetGrads<'a> {
    /// RAF: accumulate into the partial root gradient shipped upward.
    Accumulate,
    /// Vanilla with a learnable target type: sparse rows of the
    /// microbatch.
    Rows(&'a [NodeId]),
    /// Vanilla with read-only target features: nothing to update.
    Discard,
}

/// One worker's unreduced gradient outputs. Shipped (or handed) to the
/// accumulator **unmerged** so the fold happens in (worker, output)
/// order regardless of runtime.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct WorkerGrads {
    /// One entry per `wgrad` output.
    pub wgrads: Vec<(String, Vec<f32>)>,
    /// `(src_ty, sampled ids, grads)` per `block_grad` output (plus the
    /// learnable-target rows under [`TargetGrads::Rows`]).
    pub row_grads: Vec<(usize, Vec<NodeId>, Vec<f32>)>,
    /// One entry per `target_feat_grad` output under
    /// [`TargetGrads::Accumulate`].
    pub gx: Vec<Vec<f32>>,
    /// `(ty, valid rows, remote rows)` per learnable type, sorted by
    /// type — filled only when the caller supplies a remote classifier
    /// (the vanilla update-cost model).
    pub learnable_rows: Vec<(usize, u64, u64)>,
    /// Version of the [`ParamSnapshot`](crate::runtime::ParamSnapshot)
    /// (or live store) these gradients were produced against. Under the
    /// bounded-staleness pipeline every batch's gradients must carry
    /// one version — the one the leader shipped with that batch — and
    /// [`GradAccumulator::absorb`] enforces it.
    pub param_version: u64,
}

/// Classify one artifact execution's outputs into [`WorkerGrads`] —
/// the collection loop previously copy-pasted across all four engines.
pub fn collect_worker_grads(
    env_g: &crate::hetgraph::HetGraph,
    tree: &crate::hetgraph::MetaTree,
    spec: &ArtifactSpec,
    outs: &[xla::Literal],
    sample: &TreeSample,
    target: TargetGrads<'_>,
    count_remote: Option<&dyn Fn(usize, NodeId) -> bool>,
) -> Result<WorkerGrads> {
    let mut wg = WorkerGrads::default();
    let mut counts: HashMap<usize, (u64, u64)> = HashMap::new();
    for (o, out) in spec.outputs.iter().zip(outs) {
        match o.kind.as_str() {
            "wgrad" => wg.wgrads.push((o.name.clone(), lit_to_vec(out)?)),
            "block_grad" => {
                let (child, src_ty) = edge_child(env_g, tree, o.edge as usize);
                if let Some(is_remote) = count_remote {
                    let c = counts.entry(src_ty).or_insert((0, 0));
                    for &id in sample.ids[child].iter().filter(|&&id| id != PAD) {
                        c.0 += 1;
                        if is_remote(src_ty, id) {
                            c.1 += 1;
                        }
                    }
                }
                wg.row_grads
                    .push((src_ty, sample.ids[child].clone(), lit_to_vec(out)?));
            }
            "target_feat_grad" => match target {
                TargetGrads::Accumulate => wg.gx.push(lit_to_vec(out)?),
                TargetGrads::Rows(micro) => {
                    if count_remote.is_some() {
                        counts.entry(env_g.schema.target).or_insert((0, 0)).0 +=
                            micro.len() as u64;
                    }
                    wg.row_grads
                        .push((env_g.schema.target, micro.to_vec(), lit_to_vec(out)?));
                }
                TargetGrads::Discard => {}
            },
            _ => {}
        }
    }
    let mut lr: Vec<(usize, u64, u64)> =
        counts.into_iter().map(|(ty, (r, rem))| (ty, r, rem)).collect();
    lr.sort_unstable_by_key(|e| e.0);
    wg.learnable_rows = lr;
    Ok(wg)
}

/// Worker-order gradient accumulator: the reduction half of the
/// exchange stage, shared by every driver. Absorbing in worker-id order
/// is what keeps float accumulation byte-identical across runtimes.
///
/// Since PR 4 the accumulator also enforces the **snapshot-version
/// contract** of the bounded-staleness pipeline: every gradient it
/// absorbs must have been produced against the same parameter version —
/// the one the leader shipped with the batch. A worker that marshalled
/// its backward from a stale (or future) snapshot is a protocol bug the
/// fold rejects instead of silently mixing gradients of different
/// weight states.
#[derive(Debug, Default)]
pub struct GradAccumulator {
    pub wgrads: HashMap<String, Vec<f32>>,
    pub row_grads: HashMap<usize, (Vec<NodeId>, Vec<f32>)>,
    /// Accumulated `target_feat_grad` (RAF).
    pub gx: Vec<f32>,
    /// type → (valid rows, remote rows), merged across workers
    /// (vanilla update-cost model).
    pub learnable_counts: HashMap<usize, (u64, u64)>,
    /// The parameter version every absorbed gradient must carry.
    /// `None` (the default) adopts the first gradient's version; the
    /// cluster leaders pin it to the version they broadcast.
    expect_version: Option<u64>,
}

impl GradAccumulator {
    /// An accumulator that only accepts gradients produced against
    /// parameter version `v` (the snapshot the leader shipped with this
    /// batch's release or gradient scatter).
    pub fn for_version(v: u64) -> GradAccumulator {
        GradAccumulator {
            expect_version: Some(v),
            ..Default::default()
        }
    }

    /// The `(type, ids)` groups whose learnable rows this batch's
    /// update stage will write — what a TCP leader captures into the
    /// [`StoreDelta`](crate::kvstore::StoreDelta) it broadcasts (the
    /// RAF leader adds the target chunk separately).
    pub fn touched_rows(&self) -> Vec<(usize, Vec<crate::hetgraph::NodeId>)> {
        self.row_grads
            .iter()
            .map(|(ty, (ids, _))| (*ty, ids.clone()))
            .collect()
    }

    pub fn absorb(&mut self, wg: WorkerGrads) -> Result<()> {
        match self.expect_version {
            None => self.expect_version = Some(wg.param_version),
            Some(v) if v != wg.param_version => bail!(
                "stale gradient: produced against parameter version {} but this \
                 batch's fold expects version {v} (worker marshalled its backward \
                 from the wrong snapshot)",
                wg.param_version
            ),
            Some(_) => {}
        }
        for (name, gvec) in wg.wgrads {
            match self.wgrads.get_mut(&name) {
                Some(acc) => add_assign(acc, &gvec),
                None => {
                    self.wgrads.insert(name, gvec);
                }
            }
        }
        for (ty, ids, gvec) in wg.row_grads {
            let entry = self
                .row_grads
                .entry(ty)
                .or_insert_with(|| (Vec::new(), Vec::new()));
            entry.0.extend_from_slice(&ids);
            entry.1.extend_from_slice(&gvec);
        }
        for gvec in wg.gx {
            if self.gx.is_empty() {
                self.gx = gvec;
            } else {
                add_assign(&mut self.gx, &gvec);
            }
        }
        for (ty, rows, remote) in wg.learnable_rows {
            let c = self.learnable_counts.entry(ty).or_insert((0, 0));
            c.0 += rows;
            c.1 += remote;
        }
        Ok(())
    }
}

/// Result of one RAF worker forward stage (marshal + execute).
pub struct RafForward {
    pub p1: Vec<f32>,
    pub p2: Vec<f32>,
    pub stats: FetchStats,
    pub span: WorkerSpan,
    pub stages: StageTimes,
    /// Wall-clock marshal+forward-execution interval relative to the
    /// epoch origin — the overlap evidence per-worker contexts exist
    /// for (and exactly the region the shared-session token covers).
    pub wall_fwd: (f64, f64),
}

/// Result of the RAF leader stage.
pub struct RafLeaderOut {
    pub loss: f64,
    pub acc: f64,
    pub g1: Vec<f32>,
    pub g2: Vec<f32>,
    pub gx_root: Vec<f32>,
    pub stats: FetchStats,
    /// Marshal + cross-relation-agg + head + loss + backward (scaled).
    pub leader_s: f64,
    /// The leader's own head-weight updates.
    pub head_update_s: f64,
}

/// Result of one RAF worker backward stage.
pub struct RafBackward {
    pub grads: WorkerGrads,
    pub bwd_s: f64,
    pub stages: StageTimes,
    /// Wall-clock marshal+backward-execution interval relative to the
    /// epoch origin — with a staleness window open, the evidence this
    /// backward genuinely overlapped a later batch's forward.
    pub wall_bwd: (f64, f64),
}

/// One batch a worker holds **open** inside the staleness window: after
/// its forward shipped, everything the later backward stage still needs
/// — the sample, its dedup frontier, and the arena whose staging the
/// backward rebuild scatters from. The windowed cluster schedulers keep
/// up to `train.staleness + 1` of these per worker and recycle the
/// arena/frontier allocations through pools when a batch closes; the
/// synchronous path is the degenerate single-slot case.
pub struct InFlight {
    pub bi: usize,
    pub sample: TreeSample,
    pub frontier: Option<Frontier>,
    pub arena: BatchArena,
}

/// Result of the RAF update stage.
pub struct RafUpdateOut {
    pub update_s: f64,
    pub lf_s: f64,
    pub sync_bytes: u64,
}

/// The marshalled-but-not-yet-executed half of one vanilla fused step
/// (the resumable point of the stage's state machine): the input
/// literals plus the accounting the execution half folds into its
/// report. Producing this value means the worker's feature-store reads
/// for the batch are **done** — exactly what the windowed leader must
/// know before its update stage may write the store.
pub struct VanillaMarshal {
    lits: Vec<xla::Literal>,
    acc: GatherAccounting,
    target_learnable: bool,
    copy_s: f64,
    fetch_s: f64,
    /// Wall start of the marshal (epoch-relative).
    w0: f64,
}

/// Result of one vanilla fused-step stage.
pub struct VanillaStep {
    pub loss: f64,
    pub acc: f64,
    pub grads: WorkerGrads,
    pub stats: FetchStats,
    pub fetch_s: f64,
    pub span: WorkerSpan,
    pub stages: StageTimes,
    pub wall_fwd: (f64, f64),
}

/// Result of the vanilla update stage.
pub struct VanillaUpdateOut {
    pub allreduce_s: f64,
    pub update_s: f64,
    pub lf_s: f64,
}

impl WorkerPlan {
    /// RAF stages 1–2 for one worker: marshal the sampled mono-relation
    /// blocks (dedup-staged through the caller's batch arena) and
    /// execute the worker-forward artifact, producing the layer
    /// partials. Meta-partitioning makes every fetch local, hence no
    /// remote classifier. The arena is batch-scoped: the backward stage
    /// of the *same* batch must be handed the same arena (its staged
    /// rows are the backward rebuild's source), even when a staleness
    /// window ran other batches' forwards in between.
    #[allow(clippy::too_many_arguments)]
    pub fn raf_forward(
        &self,
        ctx: &mut ExecContext,
        world: &EpochWorld<'_>,
        params: ParamsView<'_>,
        sample: &TreeSample,
        frontier: Option<&Frontier>,
        chunk: &[NodeId],
        sample_s: f64,
        arena: &mut BatchArena,
    ) -> Result<RafForward> {
        let cfg = world.cfg;
        let scale = cfg.cost.compute_scale;
        let gpus = cfg.train.gpus_per_machine.max(1) as f64;
        arena.begin_batch(world.g.schema.node_types.len());
        let _token = world.serialize();
        // Wall span covers marshal + execute: exactly the region the
        // shared-session token serializes, so per-context overlap (and
        // its absence under the escape hatch) is directly observable.
        let w0 = world.now();
        let extra = ExtraInputs::new();
        let t1 = Instant::now();
        let (lits, acc) = {
            let _s = crate::obs::span(crate::obs::KIND_MARSHAL, crate::obs::LANE_NONE, "fwd-marshal");
            let store = world.store();
            let env = MarshalEnv {
                cost: &cfg.cost,
                g: world.g,
                tree: world.tree,
                store: &store,
                params,
            };
            build_inputs(
                &env,
                &self.spec_fwd,
                Some(sample),
                frontier,
                chunk,
                &extra,
                &|_, _| false,
                ctx.cache.as_mut(),
                ctx.gpu,
                arena,
            )?
        };
        let copy_s = t1.elapsed().as_secs_f64() * scale;
        let t2 = Instant::now();
        let outs = {
            let _s = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "fwd");
            ctx.rt.exec(&self.fwd_art, &lits)?
        };
        let fwd_s = t2.elapsed().as_secs_f64() * scale / gpus;
        let w1 = world.now();
        let art = &self.fwd_art;
        let p1 = lit_to_vec(outs.first().ok_or_else(|| anyhow!("{art}: no outputs"))?)?;
        let p2 = lit_to_vec(outs.get(1).ok_or_else(|| anyhow!("{art}: missing output 1"))?)?;
        let span = WorkerSpan {
            sample_s,
            fetch_ro_s: acc.cache_time_ro_s,
            fetch_lr_s: acc.cache_time_s - acc.cache_time_ro_s,
            copy_s,
            fwd_s,
            bwd_s: 0.0,
        };
        let mut stages = StageTimes::default();
        stages.add(Stage::Sample, span.sample_s);
        stages.add(Stage::Copy, span.copy_s);
        stages.add(Stage::Fetch, span.fetch_ro_s + span.fetch_lr_s);
        stages.add(Stage::Forward, span.fwd_s);
        Ok(RafForward {
            p1,
            p2,
            stats: acc.stats,
            span,
            stages,
            wall_fwd: (w0, w1),
        })
    }

    /// RAF stage 4 for one worker: rebuild the batch's inputs from the
    /// forward pass's staged rows (same batch, same frontier, same
    /// arena — the staging is what makes the rebuild independent of
    /// feature-store updates a staleness window may have applied since),
    /// execute the worker-backward artifact and classify its gradient
    /// outputs, tagging them with the snapshot version they were
    /// produced against.
    #[allow(clippy::too_many_arguments)]
    pub fn raf_backward(
        &self,
        ctx: &mut ExecContext,
        world: &EpochWorld<'_>,
        params: ParamsView<'_>,
        sample: &TreeSample,
        frontier: Option<&Frontier>,
        chunk: &[NodeId],
        g1: Vec<f32>,
        g2: Vec<f32>,
        arena: &mut BatchArena,
    ) -> Result<RafBackward> {
        let cfg = world.cfg;
        let scale = cfg.cost.compute_scale;
        let gpus = cfg.train.gpus_per_machine.max(1) as f64;
        let art = self
            .bwd_art
            .as_ref()
            .ok_or_else(|| anyhow!("{}: no backward artifact (fused step?)", self.fwd_art))?;
        let spec = self.spec_bwd.as_ref().expect("bwd_art implies spec_bwd");
        let mut extra = ExtraInputs::new();
        extra.insert(("grad".into(), 1), g1);
        extra.insert(("grad".into(), 2), g2);
        let _token = world.serialize();
        let w0 = world.now();
        let t5 = Instant::now();
        let (lits, _) = {
            let _s = crate::obs::span(crate::obs::KIND_MARSHAL, crate::obs::LANE_NONE, "bwd-marshal");
            let store = world.store();
            let env = MarshalEnv {
                cost: &cfg.cost,
                g: world.g,
                tree: world.tree,
                store: &store,
                params,
            };
            build_inputs(
                &env,
                spec,
                Some(sample),
                frontier,
                chunk,
                &extra,
                &|_, _| false,
                None, // rows already resident from forward
                ctx.gpu,
                arena,
            )?
        };
        let outs = {
            let _s = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "bwd");
            ctx.rt.exec(art, &lits)?
        };
        let bwd_s = t5.elapsed().as_secs_f64() * scale / gpus;
        let w1 = world.now();
        let mut grads = collect_worker_grads(
            world.g,
            world.tree,
            spec,
            &outs,
            sample,
            TargetGrads::Accumulate,
            None,
        )?;
        grads.param_version = params.version();
        let mut stages = StageTimes::default();
        stages.add(Stage::Backward, bwd_s);
        Ok(RafBackward {
            grads,
            bwd_s,
            stages,
            wall_bwd: (w0, w1),
        })
    }

    /// The marshal half of the vanilla fused stage: build the input
    /// literals (feature-store reads happen here and only here) without
    /// executing. The windowed cluster worker announces the marshal's
    /// completion to the leader between the two halves — the store
    /// barrier that keeps learnable-row reads deterministic while
    /// updates overlap execution. Callers holding the
    /// `train.shared_session` gate must bracket *both* halves with one
    /// token (as [`Self::vanilla_step`] does); the halves themselves do
    /// not serialize.
    #[allow(clippy::too_many_arguments)]
    pub fn vanilla_marshal(
        &self,
        ctx: &mut ExecContext,
        world: &EpochWorld<'_>,
        params: ParamsView<'_>,
        part: &NodePartition,
        sample: &TreeSample,
        frontier: Option<&Frontier>,
        micro: &[NodeId],
        arena: &mut BatchArena,
    ) -> Result<VanillaMarshal> {
        let cfg = world.cfg;
        let scale = cfg.cost.compute_scale;
        let parts = part.num_parts;
        let w = ctx.worker;
        let is_remote = |ty: usize, id: NodeId| part.owner_of(ty, id) != w;
        arena.begin_batch(world.g.schema.node_types.len());
        // Wall span covers marshal + execute (see `raf_forward`).
        let w0 = world.now();
        let extra = ExtraInputs::new();
        let t1 = Instant::now();
        let (lits, acc, target_learnable) = {
            let _s = crate::obs::span(crate::obs::KIND_MARSHAL, crate::obs::LANE_NONE, "marshal");
            let store = world.store();
            let env = MarshalEnv {
                cost: &cfg.cost,
                g: world.g,
                tree: world.tree,
                store: &store,
                params,
            };
            let (lits, acc) = build_inputs(
                &env,
                &self.spec_fwd,
                Some(sample),
                frontier,
                micro,
                &extra,
                &is_remote,
                ctx.cache.as_mut(),
                ctx.gpu,
                arena,
            )?;
            (lits, acc, store.is_learnable(world.g.schema.target))
        };
        let copy_s = t1.elapsed().as_secs_f64() * scale;
        let fetch_s = vanilla_fetch_time(&cfg.cost, &acc, ctx.cache.is_some(), parts);
        Ok(VanillaMarshal {
            lits,
            acc,
            target_learnable,
            copy_s,
            fetch_s,
            w0,
        })
    }

    /// The execution half of the vanilla fused stage: run the artifact
    /// over the marshalled literals and classify the gradient outputs,
    /// tagging them with the parameter version the marshal read
    /// (`param_version` — the stale-gradient contract).
    #[allow(clippy::too_many_arguments)]
    pub fn vanilla_execute(
        &self,
        ctx: &mut ExecContext,
        world: &EpochWorld<'_>,
        m: VanillaMarshal,
        part: &NodePartition,
        sample: &TreeSample,
        micro: &[NodeId],
        sample_s: f64,
        param_version: u64,
    ) -> Result<VanillaStep> {
        let cfg = world.cfg;
        let scale = cfg.cost.compute_scale;
        let gpus = cfg.train.gpus_per_machine.max(1) as f64;
        let w = ctx.worker;
        let is_remote = |ty: usize, id: NodeId| part.owner_of(ty, id) != w;
        let t2 = Instant::now();
        let outs = {
            let _s = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "step");
            ctx.rt.exec(&self.fwd_art, &m.lits)?
        };
        let step_s = t2.elapsed().as_secs_f64() * scale / gpus;
        let w1 = world.now();
        if outs.len() < 2 {
            bail!(
                "{} artifact returned {} outputs, expected >= 2",
                self.fwd_art,
                outs.len()
            );
        }
        let loss = lit_scalar(&outs[0])? as f64;
        let acc_v = lit_scalar(&outs[1])? as f64;
        let target = if m.target_learnable {
            TargetGrads::Rows(micro)
        } else {
            TargetGrads::Discard
        };
        let mut grads = collect_worker_grads(
            world.g,
            world.tree,
            &self.spec_fwd,
            &outs,
            sample,
            target,
            Some(&is_remote),
        )?;
        grads.param_version = param_version;
        let mut stages = StageTimes::default();
        stages.add(Stage::Sample, sample_s);
        stages.add(Stage::Copy, m.copy_s);
        stages.add(Stage::Fetch, m.fetch_s);
        stages.add(Stage::Forward, step_s * 0.45);
        stages.add(Stage::Backward, step_s * 0.55);
        let span = WorkerSpan {
            sample_s,
            // Vanilla fetch mixes remote and learnable rows, so the
            // whole fetch stays slot-bound (conservative); sampling is
            // the prefetchable stage here.
            fetch_ro_s: 0.0,
            fetch_lr_s: m.fetch_s,
            copy_s: m.copy_s,
            fwd_s: step_s,
            bwd_s: 0.0,
        };
        Ok(VanillaStep {
            loss,
            acc: acc_v,
            grads,
            stats: m.acc.stats,
            fetch_s: m.fetch_s,
            span,
            stages,
            wall_fwd: (m.w0, w1),
        })
    }

    /// The vanilla fused stage (marshal + fwd+bwd step) for one worker:
    /// the two halves composed under one shared-session token — the
    /// synchronous path. `is_remote` classifies feature rows against
    /// the edge-cut partition; the caller owns the sampling (and its
    /// remote-RPC pricing) because only scheduling differs between
    /// runtimes.
    #[allow(clippy::too_many_arguments)]
    pub fn vanilla_step(
        &self,
        ctx: &mut ExecContext,
        world: &EpochWorld<'_>,
        params: ParamsView<'_>,
        part: &NodePartition,
        sample: &TreeSample,
        frontier: Option<&Frontier>,
        micro: &[NodeId],
        sample_s: f64,
        arena: &mut BatchArena,
    ) -> Result<VanillaStep> {
        let _token = world.serialize();
        let version = params.version();
        let m = self.vanilla_marshal(ctx, world, params, part, sample, frontier, micro, arena)?;
        self.vanilla_execute(ctx, world, m, part, sample, micro, sample_s, version)
    }
}

impl BatchPlan {
    /// RAF stage 3 (leader): cross-relation aggregation + head + loss +
    /// backward over the summed partials, then the leader's own head
    /// weight updates. Bumps the shared sparse-Adam timestep — both
    /// runtimes call this exactly once per batch, before any update.
    #[allow(clippy::too_many_arguments)]
    pub fn raf_leader_step(
        &self,
        ctx: &mut ExecContext,
        world: &EpochWorld<'_>,
        params: &mut ParamStore,
        adam_t: &mut i32,
        cache: Option<&mut FeatureCache>,
        partial_sums: &[Vec<f32>; 2],
        chunk: &[NodeId],
        arena: &mut BatchArena,
    ) -> Result<RafLeaderOut> {
        let cfg = world.cfg;
        let spec = self
            .leader_spec
            .as_ref()
            .ok_or_else(|| anyhow!("batch plan has no leader artifact"))?;
        *adam_t += 1;
        let mut extra = ExtraInputs::new();
        extra.insert(("partial_sum".into(), 1), partial_sums[0].clone());
        extra.insert(("partial_sum".into(), 2), partial_sums[1].clone());
        let _token = world.serialize();
        let t3 = Instant::now();
        let (lits, leader_acc) = {
            let _s = crate::obs::span(crate::obs::KIND_MARSHAL, crate::obs::LANE_NONE, "leader-marshal");
            let store = world.store();
            let env = MarshalEnv {
                cost: &cfg.cost,
                g: world.g,
                tree: world.tree,
                store: &store,
                params: ParamsView::Owner(params),
            };
            build_inputs(
                &env,
                spec,
                None,
                None, // no sample → no frontier; batch ids are unique anyway
                chunk,
                &extra,
                &|_, _| false,
                cache,
                0,
                arena,
            )?
        };
        let outs = {
            let _s = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "leader");
            ctx.rt.exec(&self.leader_art, &lits)?
        };
        let leader_s = t3.elapsed().as_secs_f64() * cfg.cost.compute_scale;
        if outs.len() < 5 {
            bail!("leader artifact returned {} outputs, expected >= 5", outs.len());
        }
        let loss = lit_scalar(&outs[0])? as f64;
        let acc = lit_scalar(&outs[1])? as f64;
        let g1 = lit_to_vec(&outs[2])?;
        let g2 = lit_to_vec(&outs[3])?;
        let gx_root = lit_to_vec(&outs[4])?;
        // Leader's own (head) weight updates.
        let t4 = Instant::now();
        {
            let _s = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "head-update");
            for (o, out) in spec.outputs.iter().zip(&outs) {
                if o.kind == "wgrad" {
                    let grad = lit_to_vec(out)?;
                    params.step(&o.name, &grad)?;
                }
            }
        }
        let head_update_s = t4.elapsed().as_secs_f64();
        Ok(RafLeaderOut {
            loss,
            acc,
            g1,
            g2,
            gx_root,
            stats: leader_acc.stats,
            leader_s,
            head_update_s,
        })
    }
}

/// RAF stage 5: model-parallel weight updates (replicas push grads to
/// the owner — priced as `sync_bytes`), then the sparse learnable-
/// feature updates with write-back through the owning partition's
/// cache. The caller passes the leader-partition and partition-0 cache
/// handles (direct in the sequential runtime, fork-ledger views in the
/// cluster runtime — residency is shared, so times are identical).
#[allow(clippy::too_many_arguments)]
pub fn raf_apply_updates(
    world: &EpochWorld<'_>,
    params: &mut ParamStore,
    adam_t: i32,
    replica_count: &HashMap<String, usize>,
    acc: &GradAccumulator,
    gx_root: &mut Vec<f32>,
    chunk: &[NodeId],
    cache_leader: Option<&mut FeatureCache>,
    cache_p0: Option<&mut FeatureCache>,
) -> Result<RafUpdateOut> {
    let cfg = world.cfg;
    let t6 = Instant::now();
    let mut sync_bytes = 0u64;
    {
        let _s = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "update");
        for (name, grad) in &acc.wgrads {
            // Replicated relations: replicas push grads to the owner.
            let replicas = replica_count.get(name).copied().unwrap_or(1);
            if replicas > 1 {
                sync_bytes += (grad.len() * 4 * (replicas - 1)) as u64;
            }
            params.step(name, grad)?;
        }
    }
    let update_s = t6.elapsed().as_secs_f64();

    // Learnable-feature updates (sparse Adam, local rows).
    let t7 = Instant::now();
    let _lf_span = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "lf-update");
    let mut cache_write_s = 0.0;
    if !acc.gx.is_empty() {
        add_assign(gx_root, &acc.gx);
    }
    let lr = cfg.train.lr as f32;
    let tgt = world.g.schema.target;
    let mut store = world.store_mut();
    if store.is_learnable(tgt) {
        apply_learnable_grads(&mut store, lr, adam_t, tgt, chunk, gx_root, 1.0);
        if let Some(c) = cache_leader {
            for &id in chunk {
                cache_write_s += c.access(&cfg.cost, tgt, id, 0, true);
            }
        }
    }
    let mut cache_p0 = cache_p0;
    for (ty, (ids, grads)) in &acc.row_grads {
        apply_learnable_grads(&mut store, lr, adam_t, *ty, ids, grads, 1.0);
        // Write-back path through the owning partition's cache.
        if let Some(c) = cache_p0.as_deref_mut() {
            for &id in ids.iter().filter(|&&id| id != PAD) {
                cache_write_s += c.access(&cfg.cost, *ty, id, 0, true);
            }
        }
    }
    let lf_s = t7.elapsed().as_secs_f64() + cache_write_s;
    Ok(RafUpdateOut {
        update_s,
        lf_s,
        sync_bytes,
    })
}

/// Vanilla stage 3+5: price the ring all-reduce of the dense gradients,
/// apply the mean gradient to every replica, then the sparse
/// learnable-feature updates (remote rows pay a network round trip).
/// Bumps the shared sparse-Adam timestep.
pub fn vanilla_apply_updates(
    world: &EpochWorld<'_>,
    params: &mut ParamStore,
    adam_t: &mut i32,
    mut acc: GradAccumulator,
    net: &mut SimNet,
    parts: usize,
) -> Result<VanillaUpdateOut> {
    *adam_t += 1;
    let grad_bytes = (params.total_elems() * 4) as u64;
    let allreduce_s = net.allreduce(grad_bytes);

    // Model update: every replica applies the mean grad.
    let t3 = Instant::now();
    let inv = 1.0 / parts as f32;
    {
        let _s = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "update");
        for (name, mut grad) in acc.wgrads.drain() {
            scale(&mut grad, inv);
            params.step(&name, &grad)?;
        }
    }
    let update_s = t3.elapsed().as_secs_f64();

    // Learnable-feature updates: remote rows pay the network.
    let t4 = Instant::now();
    let _lf_span = crate::obs::span(crate::obs::KIND_COMPUTE, crate::obs::LANE_NONE, "lf-update");
    let lr = world.cfg.train.lr as f32;
    let mut store = world.store_mut();
    for (ty, (ids, grads)) in &acc.row_grads {
        apply_learnable_grads(&mut store, lr, *adam_t, *ty, ids, grads, inv);
    }
    let mut lf_s = t4.elapsed().as_secs_f64();
    let lrows = learnable_rows_sorted(std::mem::take(&mut acc.learnable_counts), &store);
    let (cost_s, remote_bytes) = vanilla_learnable_update_cost(&net.cost, &lrows, parts);
    lf_s += cost_s;
    if remote_bytes > 0 {
        net.charge(0, Lane::Net, remote_bytes, 0.0)?;
    }
    Ok(VanillaUpdateOut {
        allreduce_s,
        update_s,
        lf_s,
    })
}

/// `FeatureStore`-backed learnable-row update: accumulate row grads and
/// apply sparse Adam. Returns rows updated.
pub fn apply_learnable_grads(
    store: &mut FeatureStore,
    lr: f32,
    adam_t: i32,
    ty: usize,
    ids: &[NodeId],
    grads: &[f32],
    lr_scale: f32,
) -> usize {
    let dim = store.dim(ty);
    let mut rows = crate::optim::accumulate_rows(ids, grads, dim, PAD);
    if lr_scale != 1.0 {
        for (_, g) in &mut rows {
            scale(g, lr_scale);
        }
    }
    let hp = AdamParams {
        lr,
        ..Default::default()
    };
    if let Some((w, m, v)) = store.learnable_mut(ty) {
        crate::optim::sparse_adam_step(&rows, w, m, v, dim, adam_t, hp)
    } else {
        0
    }
}

/// Modeled feature-fetch time of one vanilla-engine input build: local
/// rows through the cache model (or the full DRAM+PCIe miss path when
/// uncached), remote rows over the network + PCIe. Single source of
/// truth for both runtimes — the sequential-vs-cluster A/B timing is
/// only meaningful if they price fetches identically.
pub fn vanilla_fetch_time(
    cost: &CostModel,
    acc: &super::marshal::GatherAccounting,
    cached: bool,
    parts: usize,
) -> f64 {
    let mut fetch_t = acc.cache_time_s;
    if !cached {
        // No cache: every local row pays the batched DRAM→staging→PCIe
        // path. With a dedup frontier, `acc.stats` holds unique rows
        // only, so staging prices each distinct row exactly once.
        let local_bytes = acc.stats.bytes - acc.stats.remote_bytes;
        fetch_t += cost.staging_time(local_bytes, acc.stats.rows - acc.stats.remote_rows);
    }
    fetch_t
        + cost.xfer_time_msgs(Lane::Net, acc.stats.remote_bytes, (parts - 1).max(1) as u64)
        + cost.xfer_time(Lane::Pcie, acc.stats.remote_bytes)
}

/// Per-type row counts of one batch's sparse learnable-feature update.
#[derive(Debug, Clone, Copy)]
pub struct LearnableRows {
    /// Feature dimension of the type, threaded from [`FeatureStore`].
    pub dim: usize,
    /// Valid (non-pad) gradient rows of the type this batch.
    pub rows: u64,
    /// The subset owned by other machines (vanilla edge-cut).
    pub remote_rows: u64,
}

/// Convert per-type `(valid rows, remote rows)` counts into the sorted
/// [`LearnableRows`] list [`vanilla_learnable_update_cost`] expects.
/// Single source of truth for both vanilla runtimes: sorted by type so
/// the float summation order is deterministic, real dims from the store.
pub fn learnable_rows_sorted(
    counts: HashMap<usize, (u64, u64)>,
    store: &FeatureStore,
) -> Vec<LearnableRows> {
    let mut by_ty: Vec<(usize, u64, u64)> = counts
        .into_iter()
        .map(|(ty, (rows, remote))| (ty, rows, remote))
        .collect();
    by_ty.sort_unstable_by_key(|e| e.0);
    by_ty
        .into_iter()
        .map(|(ty, rows, remote_rows)| LearnableRows {
            dim: store.dim(ty),
            rows,
            remote_rows,
        })
        .collect()
}

/// Modeled cost of the vanilla engine's sparse learnable-feature
/// update: per-row random DRAM read-modify-write of weight + moments at
/// each type's **real** dimension, plus one network round trip covering
/// all remote rows. Returns the modeled seconds and the remote bytes to
/// charge to the network ledger. Callers pass `rows` sorted by type
/// ([`learnable_rows_sorted`]) so the float summation order is
/// deterministic across runtimes.
pub fn vanilla_learnable_update_cost(
    cost: &CostModel,
    rows: &[LearnableRows],
    parts: usize,
) -> (f64, u64) {
    let mut t = 0.0f64;
    let mut remote_bytes = 0u64;
    for r in rows {
        let row_bytes = r.dim as u64 * 4;
        t += cost.xfer_time_msgs(Lane::Dram, r.rows * row_bytes * 3, r.rows * 2);
        remote_bytes += r.remote_rows * row_bytes;
    }
    if remote_bytes > 0 {
        t += cost.xfer_time_msgs(Lane::Net, remote_bytes, (parts - 1).max(1) as u64);
    }
    (t, remote_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learnable_update_cost_threads_real_dims() {
        let cost = CostModel::default();
        let small = vanilla_learnable_update_cost(
            &cost,
            &[LearnableRows { dim: 8, rows: 10, remote_rows: 2 }],
            2,
        );
        let big = vanilla_learnable_update_cost(
            &cost,
            &[LearnableRows { dim: 512, rows: 10, remote_rows: 2 }],
            2,
        );
        assert!(big.0 > small.0, "bigger rows must cost more DRAM time");
        assert_eq!(small.1, 2 * 8 * 4);
        assert_eq!(big.1, 2 * 512 * 4);
        assert_eq!(vanilla_learnable_update_cost(&cost, &[], 2), (0.0, 0));
        // Two types accumulate both time and remote bytes.
        let both = vanilla_learnable_update_cost(
            &cost,
            &[
                LearnableRows { dim: 8, rows: 10, remote_rows: 2 },
                LearnableRows { dim: 512, rows: 10, remote_rows: 2 },
            ],
            2,
        );
        assert!(both.0 > big.0);
        assert_eq!(both.1, small.1 + big.1);
    }

    #[test]
    fn accumulator_folds_in_worker_order() {
        let mut acc = GradAccumulator::default();
        acc.absorb(WorkerGrads {
            wgrads: vec![("w".into(), vec![1.0, 2.0])],
            row_grads: vec![(0, vec![1, 2], vec![0.5, 0.5])],
            gx: vec![vec![1.0]],
            learnable_rows: vec![(0, 2, 1)],
            param_version: 3,
        })
        .unwrap();
        acc.absorb(WorkerGrads {
            wgrads: vec![("w".into(), vec![10.0, 20.0])],
            row_grads: vec![(0, vec![3], vec![0.25])],
            gx: vec![vec![2.0]],
            learnable_rows: vec![(0, 1, 0)],
            param_version: 3,
        })
        .unwrap();
        assert_eq!(acc.wgrads["w"], vec![11.0, 22.0]);
        assert_eq!(acc.row_grads[&0].0, vec![1, 2, 3]);
        assert_eq!(acc.row_grads[&0].1, vec![0.5, 0.5, 0.25]);
        assert_eq!(acc.gx, vec![3.0]);
        assert_eq!(acc.learnable_counts[&0], (3, 1));
    }

    #[test]
    fn accumulator_rejects_version_mismatched_gradients() {
        // Pinned expectation: the leader knows which snapshot version it
        // shipped with the batch; a gradient tagged otherwise is a
        // protocol bug, not data.
        let mut acc = GradAccumulator::for_version(5);
        let wg = |v: u64| WorkerGrads {
            wgrads: vec![("w".into(), vec![1.0])],
            param_version: v,
            ..Default::default()
        };
        let err = acc.absorb(wg(4)).unwrap_err();
        assert!(
            err.to_string().contains("version 4") && err.to_string().contains("version 5"),
            "error must name both versions: {err}"
        );
        acc.absorb(wg(5)).unwrap();
        assert_eq!(acc.wgrads["w"], vec![1.0]);
        // Unpinned accumulators adopt the first version they see and
        // hold every later worker to it (the sequential drivers).
        let mut acc = GradAccumulator::default();
        acc.absorb(wg(7)).unwrap();
        assert!(acc.absorb(wg(8)).is_err());
        acc.absorb(wg(7)).unwrap();
    }

    #[test]
    fn batch_plan_raf_requires_manifest_artifacts() {
        let manifest = Manifest {
            config: String::new(),
            arch: String::new(),
            artifacts: HashMap::new(),
        };
        assert!(BatchPlan::raf(&manifest, 2).is_err());
        assert!(BatchPlan::vanilla(&manifest, 2).is_err());
    }
}
