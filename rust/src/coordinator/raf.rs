//! The RAF (Relation-Aggregation-First) execution engine — paper §4,
//! Algorithm 1, over meta-partitioning (§5) and the miss-penalty-aware
//! cache (§6).
//!
//! Per batch: every worker (one per partition/machine) samples **only its
//! own relations** (zero sampling communication — its mono-relation
//! subgraphs are complete), gathers features **locally** through its GPU
//! cache, and executes its `worker_fwd` artifact to produce layer-1/2
//! partial aggregations of the target nodes. Partials are gathered at the
//! designated worker (leader), which runs the cross-relation aggregation
//! + head + loss + backward (`leader` artifact), scatters `∂partials`
//! back, and every worker backprops its local stack (`worker_bwd`,
//! rematerializing) and updates its local weights and learnable features.
//! Wire traffic per batch per worker: `2·[B,H]` forward + `2·[B,H]`
//! backward — Θ(|targets|), independent of fan-out (Props. 2–3).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::cache::{FeatureCache, Policy, TypeProfile};
use crate::comm::SimNet;
use crate::config::{partition_edge_filter, RuntimeKind};
use crate::hetgraph::NodeId;
use crate::kvstore::FetchStats;
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::MetaPartition;
use crate::sampling::{presample_hotness, sample_tree, Frontier};
use crate::util::rng::Rng;

use super::common::{
    add_assign, apply_learnable_grads, build_inputs, BatchArena, ExtraInputs, Session,
};

pub struct RafEngine {
    pub mp: MetaPartition,
    /// One cache per machine (non-replicative split across its GPUs).
    caches: Vec<FeatureCache>,
    /// Weight name → number of partitions holding a replica (metagraph
    /// cycles duplicate relations; replicas ship grads to the owner).
    replica_count: HashMap<String, usize>,
    pub leader: usize,
    /// Per-partition marshalling scratch + dedup frontier, recycled
    /// across batches (sequential runtime; the cluster runtime keeps its
    /// own per-thread arenas). The forward pass stages each type's
    /// distinct rows once; the backward rebuild scatters from the same
    /// staging.
    arenas: Vec<BatchArena>,
    frontiers: Vec<Frontier>,
}

impl RafEngine {
    pub fn new(sess: &Session, mp: MetaPartition, policy: Policy) -> Result<RafEngine> {
        let cfg = &sess.cfg;
        // Pre-sampling hotness (paper §6) + per-partition cache build over
        // the node types that partition actually holds — the locality that
        // gives Heta its Fig. 12 hit-rate edge.
        let hotness = presample_hotness(
            &sess.g,
            &sess.tree,
            &cfg.model.fanouts,
            cfg.train.batch_size,
            2,
            cfg.train.seed ^ 0x807,
        );
        let mut caches = Vec::new();
        for part in 0..mp.num_parts {
            let present = mp.types_in_part(&sess.g, part);
            let profiles: Vec<TypeProfile> = sess
                .g
                .schema
                .node_types
                .iter()
                .map(|t| TypeProfile {
                    name: t.name.clone(),
                    count: t.count,
                    feat_dim: t.feat_dim,
                    learnable: t.learnable,
                })
                .collect();
            // Types absent from the partition get zero hotness — they are
            // never fetched here, so they get no cache share.
            let hot: Vec<Vec<u32>> = hotness
                .iter()
                .enumerate()
                .map(|(ty, h)| {
                    if present.contains(&ty) {
                        h.clone()
                    } else {
                        vec![0; h.len()]
                    }
                })
                .collect();
            caches.push(FeatureCache::build(
                policy,
                &profiles,
                &hot,
                &cfg.cost,
                cfg.train.cache_bytes_per_gpu * cfg.train.gpus_per_machine as u64,
                cfg.train.gpus_per_machine,
            ));
        }
        // Replica counts from the manifest: a weight appearing in several
        // worker artifacts is replicated across those partitions.
        let mut replica_count: HashMap<String, usize> = HashMap::new();
        for part in 0..mp.num_parts {
            let name = format!("worker_fwd_p{part}");
            if let Ok(spec) = sess.rt.manifest.spec(&name) {
                for inp in &spec.inputs {
                    if inp.kind == "weight" {
                        *replica_count.entry(inp.name.clone()).or_insert(0) += 1;
                    }
                }
            }
        }
        let arenas = (0..mp.num_parts).map(|_| BatchArena::new()).collect();
        let frontiers = vec![Frontier::default(); mp.num_parts];
        Ok(RafEngine {
            mp,
            caches,
            replica_count,
            leader: 0,
            arenas,
            frontiers,
        })
    }

    /// Run one epoch; `epoch` seeds the batch shuffle. Dispatches to the
    /// runtime selected by `train.runtime` — the thread-per-partition
    /// cluster runtime or the sequential (seed) path. Both produce
    /// byte-identical samples, losses and parameter trajectories.
    pub fn run_epoch(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        match sess.cfg.train.runtime {
            RuntimeKind::Cluster => crate::cluster::raf::run_epoch(
                &self.mp,
                &mut self.caches,
                &self.replica_count,
                self.leader,
                sess,
                epoch,
            ),
            RuntimeKind::Sequential => self.run_epoch_sequential(sess, epoch),
        }
    }

    /// The sequential (single-thread) epoch, kept for A/B comparison.
    fn run_epoch_sequential(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        let cfg = sess.cfg.clone();
        let b = cfg.train.batch_size;
        let h = cfg.model.hidden;
        let parts = self.mp.num_parts;
        let gpus = cfg.train.gpus_per_machine.max(1);
        let ntypes = sess.g.schema.node_types.len();
        let mut net = SimNet::new(parts, cfg.cost.clone());
        let mut stages = StageTimes::default();
        let mut epoch_time = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut batches = 0usize;
        let mut worker_busy = vec![0.0f64; parts];
        let mut fetch = FetchStats::default();

        let mut train = sess.g.train_nodes();
        let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
        shuffle_rng.shuffle(&mut train);

        for (bi, chunk) in train.chunks(b).enumerate() {
            if chunk.len() < b {
                break; // drop the ragged tail (static shapes)
            }
            sess.adam_t += 1;
            let batch_seed = cfg.train.batch_seed(epoch, bi);

            // ---- worker forward phase (parallel across machines) ----
            let mut fwd_worker_time = vec![0.0f64; parts];
            let mut samples = Vec::with_capacity(parts);
            let mut partial_sums = vec![vec![0f32; b * h]; 2];
            let mut worker_partials: Vec<[Vec<f32>; 2]> = Vec::with_capacity(parts);
            for p in 0..parts {
                let mut st = StageTimes::default();
                let t0 = Instant::now();
                let filter = partition_edge_filter(&sess.tree, &self.mp, p);
                let sample = sample_tree(
                    &sess.g,
                    &sess.tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    batch_seed,
                    filter,
                );
                st.add(Stage::Sample, t0.elapsed().as_secs_f64() * cfg.cost.compute_scale);

                let art = format!("worker_fwd_p{p}");
                let spec = sess.rt.manifest.spec(&art)?.clone();
                let t1 = Instant::now();
                let extra = ExtraInputs::new();
                let frontier = if cfg.train.dedup_fetch {
                    // Root (target) rows join the fetch frontier only if
                    // this worker's artifact actually gathers them — the
                    // leader fetches the batch's target rows itself.
                    let needs_root = spec.inputs.iter().any(|i| i.kind == "target_feat");
                    self.frontiers[p].rebuild(&sess.tree, &sample, ntypes, needs_root);
                    Some(&self.frontiers[p])
                } else {
                    None
                };
                self.arenas[p].begin_batch(ntypes);
                let (lits, acc) = build_inputs(
                    sess,
                    &spec,
                    Some(&sample),
                    frontier,
                    chunk,
                    &extra,
                    &|_, _| false, // meta-partitioning: all fetches local
                    Some(&mut self.caches[p]),
                    p % gpus,
                    &mut self.arenas[p],
                )?;
                st.add(Stage::Copy, t1.elapsed().as_secs_f64() * cfg.cost.compute_scale);
                st.add(Stage::Fetch, acc.cache_time_s);
                fetch.merge(acc.stats);

                let t2 = Instant::now();
                let outs = sess.rt.exec(&art, &lits)?;
                st.add(Stage::Forward, t2.elapsed().as_secs_f64() * cfg.cost.compute_scale / gpus as f64);
                let p1 = crate::runtime::lit_to_vec(&outs[0])?;
                let p2 = crate::runtime::lit_to_vec(&outs[1])?;
                add_assign(&mut partial_sums[0], &p1);
                add_assign(&mut partial_sums[1], &p2);
                worker_partials.push([p1, p2]);
                samples.push(sample);
                fwd_worker_time[p] = st.total();
                stage_max(&mut stages, &st);
            }
            epoch_time += fwd_worker_time.iter().cloned().fold(0.0, f64::max);
            for p in 0..parts {
                worker_busy[p] += fwd_worker_time[p];
            }

            // ---- gather partials at the leader (2 tensors per worker) ----
            let per_worker = (2 * b * h * 4) as u64;
            let gather_bytes: Vec<u64> = (0..parts)
                .map(|p| if p == self.leader { 0 } else { per_worker })
                .collect();
            let t_gather = net.gather(self.leader, &gather_bytes)?;
            stages.add(Stage::Forward, t_gather);
            epoch_time += t_gather;

            // ---- leader: cross-relation agg + head + loss + backward ----
            let spec = sess.rt.manifest.spec("leader")?.clone();
            let mut extra = ExtraInputs::new();
            extra.insert(("partial_sum".into(), 1), partial_sums[0].clone());
            extra.insert(("partial_sum".into(), 2), partial_sums[1].clone());
            let t3 = Instant::now();
            let (lits, leader_acc) = build_inputs(
                sess,
                &spec,
                None,
                None, // no sample → no frontier; batch ids are unique anyway
                chunk,
                &extra,
                &|_, _| false,
                Some(&mut self.caches[self.leader]),
                0,
                &mut self.arenas[self.leader],
            )?;
            fetch.merge(leader_acc.stats);
            let outs = sess.rt.exec("leader", &lits)?;
            let leader_t = t3.elapsed().as_secs_f64() * cfg.cost.compute_scale;
            stages.add(Stage::Forward, leader_t * 0.5);
            stages.add(Stage::Backward, leader_t * 0.5);
            epoch_time += leader_t;

            let loss = crate::runtime::lit_scalar(&outs[0])? as f64;
            let acc = crate::runtime::lit_scalar(&outs[1])? as f64;
            let g1 = crate::runtime::lit_to_vec(&outs[2])?;
            let g2 = crate::runtime::lit_to_vec(&outs[3])?;
            let mut gx_root = crate::runtime::lit_to_vec(&outs[4])?;
            loss_sum += loss;
            acc_sum += acc;

            // Leader's own (head) weight updates.
            let t4 = Instant::now();
            for (o, out) in spec.outputs.iter().zip(&outs) {
                if o.kind == "wgrad" {
                    let grad = crate::runtime::lit_to_vec(out)?;
                    sess.params.step(&o.name, &grad)?;
                }
            }
            stages.add(Stage::Update, t4.elapsed().as_secs_f64());
            epoch_time += t4.elapsed().as_secs_f64();

            // ---- scatter gradients back (2 tensors per worker) ----
            let t_scatter = net.gather(self.leader, &gather_bytes)?; // symmetric
            stages.add(Stage::Backward, t_scatter);
            epoch_time += t_scatter;

            // ---- worker backward + updates ----
            let mut bwd_worker_time = vec![0.0f64; parts];
            let mut wgrads: HashMap<String, Vec<f32>> = HashMap::new();
            let mut row_grads: HashMap<usize, (Vec<NodeId>, Vec<f32>)> = HashMap::new();
            let mut gx_extra: Vec<f32> = Vec::new();
            for p in 0..parts {
                let mut st = StageTimes::default();
                let art = format!("worker_bwd_p{p}");
                let spec = sess.rt.manifest.spec(&art)?.clone();
                let mut extra = ExtraInputs::new();
                extra.insert(("grad".into(), 1), g1.clone());
                extra.insert(("grad".into(), 2), g2.clone());
                let t5 = Instant::now();
                // Reuses the forward pass's staged rows: same batch, same
                // frontier, features unmodified until the update phase.
                let frontier = cfg.train.dedup_fetch.then(|| &self.frontiers[p]);
                let (lits, _) = build_inputs(
                    sess,
                    &spec,
                    Some(&samples[p]),
                    frontier,
                    chunk,
                    &extra,
                    &|_, _| false,
                    None, // rows already resident from forward
                    p % gpus,
                    &mut self.arenas[p],
                )?;
                let outs = sess.rt.exec(&art, &lits)?;
                st.add(Stage::Backward, t5.elapsed().as_secs_f64() * cfg.cost.compute_scale / gpus as f64);

                for (o, out) in spec.outputs.iter().zip(&outs) {
                    match o.kind.as_str() {
                        "wgrad" => {
                            let g = crate::runtime::lit_to_vec(out)?;
                            match wgrads.get_mut(&o.name) {
                                Some(acc) => add_assign(acc, &g),
                                None => {
                                    wgrads.insert(o.name.clone(), g);
                                }
                            }
                        }
                        "block_grad" => {
                            let (child, src_ty) = sess.edge_child(o.edge as usize);
                            let g = crate::runtime::lit_to_vec(out)?;
                            let entry = row_grads
                                .entry(src_ty)
                                .or_insert_with(|| (Vec::new(), Vec::new()));
                            entry.0.extend_from_slice(&samples[p].ids[child]);
                            entry.1.extend_from_slice(&g);
                        }
                        "target_feat_grad" => {
                            let g = crate::runtime::lit_to_vec(out)?;
                            if gx_extra.is_empty() {
                                gx_extra = g;
                            } else {
                                add_assign(&mut gx_extra, &g);
                            }
                        }
                        _ => {}
                    }
                }
                bwd_worker_time[p] = st.total();
                stage_max(&mut stages, &st);
            }
            epoch_time += bwd_worker_time.iter().cloned().fold(0.0, f64::max);
            for p in 0..parts {
                worker_busy[p] += bwd_worker_time[p];
            }

            // ---- model-parallel weight updates (local per partition) ----
            let t6 = Instant::now();
            let mut sync_bytes = 0u64;
            for (name, grad) in &wgrads {
                // Replicated relations: replicas push grads to the owner.
                let replicas = self.replica_count.get(name).copied().unwrap_or(1);
                if replicas > 1 {
                    sync_bytes += (grad.len() * 4 * (replicas - 1)) as u64;
                }
                sess.params.step(name, grad)?;
            }
            let update_t = t6.elapsed().as_secs_f64();
            stages.add(Stage::Update, update_t);
            epoch_time += update_t;
            if sync_bytes > 0 {
                let t = net.send(1 % parts, self.leader, sync_bytes)?;
                stages.add(Stage::GradSync, t);
                epoch_time += t;
            }

            // ---- learnable-feature updates (sparse Adam, local rows) ----
            let t7 = Instant::now();
            let mut cache_write_t = 0.0;
            if !gx_extra.is_empty() {
                add_assign(&mut gx_root, &gx_extra);
            }
            let tgt = sess.g.schema.target;
            if sess.store.is_learnable(tgt) {
                apply_learnable_grads(sess, tgt, chunk, &gx_root, 1.0);
                let cost = cfg.cost.clone();
                for &id in chunk {
                    cache_write_t +=
                        self.caches[self.leader].access(&cost, tgt, id, 0, true);
                }
            }
            for (ty, (ids, grads)) in &row_grads {
                apply_learnable_grads(sess, *ty, ids, grads, 1.0);
                let cost = cfg.cost.clone();
                // Write-back path through the owning partition's cache.
                for &id in ids.iter().filter(|&&id| id != crate::sampling::PAD) {
                    cache_write_t += self.caches[0].access(&cost, *ty, id, 0, true);
                }
            }
            let t_upd = t7.elapsed().as_secs_f64() + cache_write_t;
            stages.add(Stage::Update, t_upd);
            epoch_time += t_upd;

            batches += 1;
        }

        let comm = net.total();
        Ok(EpochReport {
            epoch_time_s: epoch_time,
            // No overlap in the sequential runtime: the critical path
            // is the summed epoch time itself.
            critical_path_s: epoch_time,
            worker_busy_s: worker_busy,
            stages,
            comm,
            fetch,
            loss_mean: if batches > 0 { loss_sum / batches as f64 } else { f64::NAN },
            accuracy: if batches > 0 {
                acc_sum / (batches * b) as f64
            } else {
                f64::NAN
            },
            batches,
        })
    }

    /// Cache hit-rate report per node type (Fig. 12).
    pub fn hit_rates(&self) -> Vec<Vec<f64>> {
        self.caches.iter().map(|c| c.hit_rates()).collect()
    }
}

/// Accumulate per-stage maxima across parallel workers: for each stage,
/// the slowest worker defines the critical path.
fn stage_max(total: &mut StageTimes, worker: &StageTimes) {
    for i in 0..total.secs.len() {
        // Stages are accumulated per batch; take max by adding only the
        // excess over what's already recorded for this batch's workers.
        // (Approximation documented in DESIGN.md §Perf.)
        if worker.secs[i] > 0.0 {
            total.secs[i] += worker.secs[i];
        }
    }
}
