//! The RAF (Relation-Aggregation-First) execution engine — paper §4,
//! Algorithm 1, over meta-partitioning (§5) and the miss-penalty-aware
//! cache (§6).
//!
//! Per batch: every worker (one per partition/machine) samples **only its
//! own relations** (zero sampling communication — its mono-relation
//! subgraphs are complete), gathers features **locally** through its GPU
//! cache, and executes its `worker_fwd` artifact to produce layer-1/2
//! partial aggregations of the target nodes. Partials are gathered at the
//! designated worker (leader), which runs the cross-relation aggregation
//! + head + loss + backward (`leader` artifact), scatters `∂partials`
//! back, and every worker backprops its local stack (`worker_bwd`,
//! rematerializing) and updates its local weights and learnable features.
//! Wire traffic per batch per worker: `2·[B,H]` forward + `2·[B,H]`
//! backward — Θ(|targets|), independent of fan-out (Props. 2–3).
//!
//! Since PR 3 the per-batch stage bodies live in
//! [`crate::exec::BatchPlan`]; this file owns only engine construction
//! (caches, per-worker [`ExecContext`]s, replica counts) and the
//! *sequential* scheduling of those stages — the thread-per-partition
//! scheduling lives in [`crate::cluster::raf`]. Both runtimes produce
//! byte-identical samples, losses and parameter trajectories.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cache::{FeatureCache, Policy, TypeProfile};
use crate::comm::SimNet;
use crate::config::{partition_edge_filter, RuntimeKind};
use crate::exec::plan::raf_apply_updates;
use crate::exec::{
    BatchArena, BatchPlan, EpochWorld, ExecContext, ExecGate, GradAccumulator, ParamsView,
};
use crate::kvstore::FetchStats;
use crate::metrics::timeline::{EpochTimeline, LeaderSpan, WallClock, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::MetaPartition;
use crate::sampling::{presample_hotness, sample_tree, Frontier};
use crate::util::{add_assign, rng::Rng};

use super::common::Session;

pub struct RafEngine {
    pub mp: MetaPartition,
    /// The per-batch stage pipeline (resolved artifact specs).
    plan: BatchPlan,
    /// One execution context per partition: the worker's own PJRT
    /// client + executables, its cache, its marshalling scratch.
    contexts: Vec<ExecContext>,
    /// The leader role's own context (the `leader` artifact); its cache
    /// accounting goes through fork-ledger views of the partition
    /// caches.
    leader_ctx: ExecContext,
    /// Weight name → number of partitions holding a replica (metagraph
    /// cycles duplicate relations; replicas ship grads to the owner).
    replica_count: HashMap<String, usize>,
    pub leader: usize,
    /// Per-partition dedup frontiers, recycled across batches
    /// (sequential runtime; cluster workers ping-pong their own).
    frontiers: Vec<Frontier>,
    /// Per-partition marshalling arenas (batch-scoped scratch since the
    /// exec contexts stopped owning one; the sequential schedule holds
    /// one batch open per partition). Cluster workers pool their own.
    arenas: Vec<BatchArena>,
    /// Scratch for the leader artifact's marshal.
    leader_arena: BatchArena,
    /// `Some` iff `train.shared_session` — serializes marshal+execute.
    gate: Option<ExecGate>,
    /// The typed socket lanes of a TCP session, opened on the first
    /// epoch and reused (each lane's receive queue exists once).
    tcp: Option<crate::cluster::raf::TcpLanes>,
}

impl RafEngine {
    pub fn new(sess: &mut Session, mp: MetaPartition, policy: Policy) -> Result<RafEngine> {
        let cfg = &sess.cfg;
        // Pre-sampling hotness (paper §6) + per-partition cache build over
        // the node types that partition actually holds — the locality that
        // gives Heta its Fig. 12 hit-rate edge.
        let hotness = presample_hotness(
            &sess.g,
            &sess.tree,
            &cfg.model.fanouts,
            cfg.train.batch_size,
            2,
            cfg.train.seed ^ 0x807,
        );
        let gpus = cfg.train.gpus_per_machine.max(1);
        // Role-gated construction (PR 8): a TCP process plays exactly
        // one rank, so only that rank's context gets an eager PJRT
        // client; every other context is deferred — it keeps its cache
        // (the leader's fork-ledger accounting reads them) but never
        // spins up a client or loads executables it will never run. A
        // K-worker cluster now builds K+1 clients total instead of
        // (K+1)². In-process runs (channel transport, sequential
        // driver) still build everything eagerly — one process plays
        // every rank.
        let role = match &sess.net {
            crate::net::Backend::Tcp(node) => Some(node.role()),
            crate::net::Backend::Channel => None,
        };
        let mut contexts = Vec::with_capacity(mp.num_parts);
        for part in 0..mp.num_parts {
            let present = mp.types_in_part(&sess.g, part);
            let profiles: Vec<TypeProfile> = sess
                .g
                .schema
                .node_types
                .iter()
                .map(|t| TypeProfile {
                    name: t.name.clone(),
                    count: t.count,
                    feat_dim: t.feat_dim,
                    learnable: t.learnable,
                })
                .collect();
            // Types absent from the partition get zero hotness — they are
            // never fetched here, so they get no cache share.
            let hot: Vec<Vec<u32>> = hotness
                .iter()
                .enumerate()
                .map(|(ty, h)| {
                    if present.contains(&ty) {
                        h.clone()
                    } else {
                        vec![0; h.len()]
                    }
                })
                .collect();
            let cache = FeatureCache::build(
                policy,
                &profiles,
                &hot,
                &cfg.cost,
                cfg.train.cache_bytes_per_gpu * cfg.train.gpus_per_machine as u64,
                cfg.train.gpus_per_machine,
            );
            let eager = match role {
                None => true,
                Some(crate::net::Role::Worker(w)) => w == part,
                Some(crate::net::Role::Leader) => false,
            };
            contexts.push(if eager {
                ExecContext::new(
                    part,
                    part % gpus,
                    &sess.artifacts_dir,
                    Arc::clone(&sess.manifest),
                    Some(cache),
                )?
            } else {
                ExecContext::deferred(
                    part,
                    part % gpus,
                    &sess.artifacts_dir,
                    Arc::clone(&sess.manifest),
                    Some(cache),
                )
            });
        }
        let leader_ctx = if matches!(role, None | Some(crate::net::Role::Leader)) {
            ExecContext::new(
                mp.num_parts,
                0,
                &sess.artifacts_dir,
                Arc::clone(&sess.manifest),
                None,
            )?
        } else {
            ExecContext::deferred(
                mp.num_parts,
                0,
                &sess.artifacts_dir,
                Arc::clone(&sess.manifest),
                None,
            )
        };
        // Replica counts from the manifest: a weight appearing in several
        // worker artifacts is replicated across those partitions.
        let mut replica_count: HashMap<String, usize> = HashMap::new();
        for part in 0..mp.num_parts {
            let name = format!("worker_fwd_p{part}");
            if let Ok(spec) = sess.manifest.spec(&name) {
                for inp in &spec.inputs {
                    if inp.kind == "weight" {
                        *replica_count.entry(inp.name.clone()).or_insert(0) += 1;
                    }
                }
            }
        }
        let plan = BatchPlan::raf(&sess.manifest, mp.num_parts)?;
        // Initialize every weight the pipeline's artifacts declare, so
        // marshalling (and the per-batch snapshots) is read-only.
        let art_names: Vec<String> = plan
            .workers
            .iter()
            .flat_map(|w| [Some(w.fwd_art.clone()), w.bwd_art.clone()])
            .flatten()
            .chain([plan.leader_art.clone()])
            .collect();
        sess.params
            .ensure_artifacts(&sess.manifest, art_names.iter().map(|s| s.as_str()));
        let frontiers = vec![Frontier::default(); mp.num_parts];
        let arenas = (0..mp.num_parts).map(|_| BatchArena::new()).collect();
        let gate = sess.cfg.train.shared_session.then(ExecGate::new);
        Ok(RafEngine {
            mp,
            plan,
            contexts,
            leader_ctx,
            replica_count,
            leader: 0,
            frontiers,
            arenas,
            leader_arena: BatchArena::new(),
            gate,
            tcp: None,
        })
    }

    /// Run one epoch; `epoch` seeds the batch shuffle. Dispatches to the
    /// runtime selected by `train.runtime` — the thread-per-partition
    /// cluster runtime or the sequential (seed) path. Both drive the
    /// same [`BatchPlan`] stages and produce byte-identical samples,
    /// losses and parameter trajectories.
    pub fn run_epoch(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        // Open the socket lanes (once) before dispatching, so the
        // borrow of `sess.net` ends before `sess` moves on mutably.
        if let crate::net::Backend::Tcp(node) = &sess.net {
            crate::net::require_cluster_runtime(sess.cfg.train.runtime)?;
            if self.tcp.is_none() {
                self.tcp = Some(crate::cluster::raf::TcpLanes::open(
                    node,
                    self.mp.num_parts,
                    sess.cfg.train.wire_exchange.is_mesh(),
                )?);
            }
        }
        if let Some(lanes) = &self.tcp {
            return crate::cluster::raf::run_epoch_tcp(
                &self.plan,
                &mut self.contexts,
                &mut self.leader_ctx,
                &self.mp,
                &self.replica_count,
                self.leader,
                self.gate.as_ref(),
                sess,
                epoch,
                lanes,
            );
        }
        match sess.cfg.train.runtime {
            RuntimeKind::Cluster => crate::cluster::raf::run_epoch(
                &self.plan,
                &mut self.contexts,
                &mut self.leader_ctx,
                &self.mp,
                &self.replica_count,
                self.leader,
                self.gate.as_ref(),
                sess,
                epoch,
            ),
            RuntimeKind::Sequential => self.run_epoch_sequential(sess, epoch),
        }
    }

    /// The sequential (single-thread) driver, kept for A/B comparison:
    /// plays every worker's stages in turn on one thread. It is the
    /// synchronous reference — `train.staleness` is a cluster-runtime
    /// scheduling knob and has no effect here (one thread has no
    /// leader phase to overlap).
    fn run_epoch_sequential(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        let cfg = sess.cfg.clone();
        let b = cfg.train.batch_size;
        let h = cfg.model.hidden;
        let parts = self.mp.num_parts;
        let ntypes = sess.g.schema.node_types.len();
        let g = Arc::clone(&sess.g);
        let tree = Arc::clone(&sess.tree);
        let mut net = SimNet::new(parts, cfg.cost.clone());
        let mut timeline = EpochTimeline::new(parts);
        let mut stages = StageTimes::default();
        let mut worker_stages = vec![StageTimes::default(); parts];
        let mut wall = WallClock::new(parts);
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut batch_losses = Vec::new();
        let mut batches = 0usize;
        let mut fetch = FetchStats::default();

        // Flight recorder (PR 6): the sequential driver plays every
        // rank on one thread, so it registers once and re-tags the
        // current rank around each worker/leader phase. The leader's
        // rank id is `parts`, one past the workers.
        if cfg.train.trace {
            crate::obs::thread_register(parts as u32, "driver");
        }
        let cache_bases: Vec<_> = self
            .contexts
            .iter()
            .map(|c| crate::obs::cache_obs_base(c.cache.as_ref()))
            .collect();

        // The leader role prices its cache traffic through fork-ledger
        // views (shared residency ⇒ identical modeled times), folded
        // back into the owning contexts at epoch end — the same scheme
        // the cluster runtime uses, so hit rates match across runtimes.
        let mut fork_leader = self.contexts[self.leader]
            .cache
            .as_ref()
            .map(|c| c.fork_ledger());
        let mut fork_p0 = self.contexts[0].cache.as_ref().map(|c| c.fork_ledger());

        let world = EpochWorld {
            cfg: &cfg,
            g: &g,
            tree: &tree,
            store: &sess.store,
            gate: self.gate.as_ref(),
            epoch_t0: Instant::now(),
        };

        let mut train = sess.g.train_nodes();
        let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
        shuffle_rng.shuffle(&mut train);

        for (bi, chunk) in train.chunks(b).enumerate() {
            if chunk.len() < b {
                break; // drop the ragged tail (static shapes)
            }
            let batch_seed = cfg.train.batch_seed(epoch, bi);
            crate::obs::set_batch(bi as u64);

            // ---- worker forward stages (played in partition order) ----
            let mut partial_sums = [vec![0f32; b * h], vec![0f32; b * h]];
            let mut samples = Vec::with_capacity(parts);
            let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
            for p in 0..parts {
                crate::obs::set_rank(p as u32);
                let t0 = Instant::now();
                let filter = partition_edge_filter(&tree, &self.mp, p);
                let sample =
                    sample_tree(&g, &tree, &cfg.model.fanouts, chunk, 0, batch_seed, filter);
                let sample_s = t0.elapsed().as_secs_f64() * cfg.cost.compute_scale;
                if cfg.train.dedup_fetch {
                    // Root (target) rows join the fetch frontier only if
                    // this worker's artifact actually gathers them — the
                    // leader fetches the batch's target rows itself.
                    self.frontiers[p].rebuild(
                        &tree,
                        &sample,
                        ntypes,
                        self.plan.workers[p].needs_root,
                    );
                }
                let frontier = cfg.train.dedup_fetch.then(|| &self.frontiers[p]);
                let fwd = self.plan.workers[p].raf_forward(
                    &mut self.contexts[p],
                    &world,
                    ParamsView::Owner(&sess.params),
                    &sample,
                    frontier,
                    chunk,
                    sample_s,
                    &mut self.arenas[p],
                )?;
                add_assign(&mut partial_sums[0], &fwd.p1);
                add_assign(&mut partial_sums[1], &fwd.p2);
                fetch.merge(fwd.stats);
                stages.merge(&fwd.stages);
                worker_stages[p].merge(&fwd.stages);
                wall.record_forward(p, fwd.wall_fwd);
                worker_spans.push(fwd.span);
                samples.push(sample);
            }

            crate::obs::set_rank(parts as u32);
            // ---- gather partials at the leader (2 tensors per worker) ----
            let per_worker = (2 * b * h * 4) as u64;
            let gather_bytes: Vec<u64> = (0..parts)
                .map(|p| if p == self.leader { 0 } else { per_worker })
                .collect();
            let t_gather = net.gather(self.leader, &gather_bytes)?;
            stages.add(Stage::Forward, t_gather);

            // ---- leader stage: cross-relation agg + head + loss + bwd ----
            let lo = self.plan.raf_leader_step(
                &mut self.leader_ctx,
                &world,
                &mut sess.params,
                &mut sess.adam_t,
                fork_leader.as_mut(),
                &partial_sums,
                chunk,
                &mut self.leader_arena,
            )?;
            fetch.merge(lo.stats);
            stages.add(Stage::Forward, lo.leader_s * 0.5);
            stages.add(Stage::Backward, lo.leader_s * 0.5);
            stages.add(Stage::Update, lo.head_update_s);
            loss_sum += lo.loss;
            acc_sum += lo.acc;
            batch_losses.push(lo.loss);

            // ---- scatter gradients back (2 tensors per worker) ----
            let t_scatter = net.gather(self.leader, &gather_bytes)?; // symmetric
            stages.add(Stage::Backward, t_scatter);

            // ---- worker backward stages ----
            let mut gacc = GradAccumulator::default();
            for p in 0..parts {
                crate::obs::set_rank(p as u32);
                // Reuses the forward pass's staged rows: same batch, same
                // frontier, features unmodified until the update phase.
                let frontier = cfg.train.dedup_fetch.then(|| &self.frontiers[p]);
                let bwd = self.plan.workers[p].raf_backward(
                    &mut self.contexts[p],
                    &world,
                    ParamsView::Owner(&sess.params),
                    &samples[p],
                    frontier,
                    chunk,
                    lo.g1.clone(),
                    lo.g2.clone(),
                    &mut self.arenas[p],
                )?;
                stages.merge(&bwd.stages);
                worker_stages[p].merge(&bwd.stages);
                worker_spans[p].bwd_s = bwd.bwd_s;
                wall.record_backward(p, bwd.wall_bwd);
                gacc.absorb(bwd.grads)?;
            }

            // ---- update stage (weights + learnable features) ----
            crate::obs::set_rank(parts as u32);
            let mut gx_root = lo.gx_root;
            let upd = raf_apply_updates(
                &world,
                &mut sess.params,
                sess.adam_t,
                &self.replica_count,
                &gacc,
                &mut gx_root,
                chunk,
                fork_leader.as_mut(),
                fork_p0.as_mut(),
            )?;
            stages.add(Stage::Update, upd.update_s + upd.lf_s);
            let sync_t = if upd.sync_bytes > 0 {
                let t = net.send(1 % parts, self.leader, upd.sync_bytes)?;
                stages.add(Stage::GradSync, t);
                t
            } else {
                0.0
            };

            timeline.push_batch(
                worker_spans,
                LeaderSpan {
                    gather_s: t_gather,
                    leader_s: lo.leader_s,
                    scatter_s: t_scatter,
                    update_s: lo.head_update_s + upd.update_s + upd.lf_s,
                    sync_s: sync_t,
                },
            );
            batches += 1;
        }

        if let Some(f) = fork_leader {
            if let Some(c) = self.contexts[self.leader].cache.as_mut() {
                c.absorb_ledger(&f);
            }
        }
        if let Some(f) = fork_p0 {
            if let Some(c) = self.contexts[0].cache.as_mut() {
                c.absorb_ledger(&f);
            }
        }

        // ---- flight recorder: publish per-context cache deltas (the
        // leader's fork-ledger traffic was just absorbed, so it is
        // counted) and collect this thread's tracks + the metrics
        // snapshot into the report ----
        for (ctx, base) in self.contexts.iter().zip(&cache_bases) {
            crate::obs::record_cache_obs(&g, ctx.cache.as_ref(), base.as_deref());
        }
        let mut obs = crate::obs::ObsReport::default();
        crate::obs::TraceBlob::collect(parts as u32).merge_into(&mut obs);

        // No overlap in the sequential runtime: the critical path is the
        // summed schedule itself.
        let epoch_time_s = timeline.sequential_time();
        Ok(EpochReport {
            epoch_time_s,
            critical_path_s: epoch_time_s,
            worker_busy_s: timeline.worker_busy_s(),
            worker_stages,
            wall,
            stages,
            comm: net.total(),
            fetch,
            wire: Default::default(), // the in-process transports move no frames
            loss_mean: if batches > 0 { loss_sum / batches as f64 } else { f64::NAN },
            accuracy: if batches > 0 {
                acc_sum / (batches * b) as f64
            } else {
                f64::NAN
            },
            batches,
            batch_losses,
            obs,
        })
    }

    /// Cache hit-rate report per node type (Fig. 12).
    pub fn hit_rates(&self) -> Vec<Vec<f64>> {
        self.contexts
            .iter()
            .filter_map(|c| c.cache.as_ref().map(|c| c.hit_rates()))
            .collect()
    }
}
