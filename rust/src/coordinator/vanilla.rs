//! The vanilla execution engine — the paper's Fig. 3 pipeline as used by
//! DGL / GraphLearn: edge-cut partitioning + data parallelism. Each
//! worker samples the **full** k-hop tree for its microbatch (remote
//! sampling RPCs), fetches features from the distributed KV store
//! (remote rows cross the network — the communication bottleneck the
//! paper attacks), runs the fused `vanilla` train-step artifact, ring-
//! all-reduces dense gradients, and applies sparse updates to learnable
//! features (remote rows pay another network round trip).
//!
//! Baseline variants (paper §8.1): DGL-Random / DGL-METIS (no cache),
//! DGL-Opt (read-only feature cache), GraphLearn (per-type partitioning
//! + feature cache, no learnable-feature support).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::cache::{FeatureCache, Policy, TypeProfile};
use crate::comm::{Lane, SimNet};
use crate::config::RuntimeKind;
use crate::hetgraph::NodeId;
use crate::kvstore::FetchStats;
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::NodePartition;
use crate::sampling::{presample_hotness, remote_counts, sample_tree, Frontier, PAD};
use crate::util::rng::Rng;

use super::common::{
    add_assign, apply_learnable_grads, build_inputs, BatchArena, ExtraInputs, Session,
};

pub struct VanillaEngine {
    pub part: NodePartition,
    /// Per-worker feature cache (None = DGL-Random/METIS baseline).
    caches: Option<Vec<FeatureCache>>,
    /// Per-worker marshalling scratch + dedup frontier, recycled across
    /// batches (sequential runtime; the cluster runtime keeps its own
    /// per-thread arenas).
    arenas: Vec<BatchArena>,
    frontiers: Vec<Frontier>,
}

impl VanillaEngine {
    /// `cache_policy`: `None` disables caching; baselines that cache
    /// (DGL-Opt, GraphLearn) cache **read-only** features only — caching
    /// non-replicated learnable rows buys them nothing because remote
    /// workers still fetch over the network (paper §8.1).
    pub fn new(
        sess: &Session,
        part: NodePartition,
        cache_policy: Policy,
    ) -> Result<VanillaEngine> {
        let cfg = &sess.cfg;
        let caches = if cache_policy == Policy::None {
            None
        } else {
            let hotness = presample_hotness(
                &sess.g,
                &sess.tree,
                &cfg.model.fanouts,
                cfg.train.batch_size,
                2,
                cfg.train.seed ^ 0x807,
            );
            let profiles: Vec<TypeProfile> = sess
                .g
                .schema
                .node_types
                .iter()
                .map(|t| TypeProfile {
                    name: t.name.clone(),
                    count: t.count,
                    feat_dim: t.feat_dim,
                    learnable: t.learnable,
                })
                .collect();
            // Read-only restriction: learnable types get no cache share.
            let hot: Vec<Vec<u32>> = hotness
                .iter()
                .enumerate()
                .map(|(ty, h)| {
                    if profiles[ty].learnable {
                        vec![0; h.len()]
                    } else {
                        h.clone()
                    }
                })
                .collect();
            Some(
                (0..part.num_parts)
                    .map(|_| {
                        FeatureCache::build(
                            cache_policy,
                            &profiles,
                            &hot,
                            &cfg.cost,
                            cfg.train.cache_bytes_per_gpu * cfg.train.gpus_per_machine as u64,
                            cfg.train.gpus_per_machine,
                        )
                    })
                    .collect(),
            )
        };
        let arenas = (0..part.num_parts).map(|_| BatchArena::new()).collect();
        let frontiers = vec![Frontier::default(); part.num_parts];
        Ok(VanillaEngine {
            part,
            caches,
            arenas,
            frontiers,
        })
    }

    /// Run one epoch, dispatching to the runtime selected by
    /// `train.runtime`; both runtimes produce byte-identical losses.
    pub fn run_epoch(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        match sess.cfg.train.runtime {
            RuntimeKind::Cluster => crate::cluster::vanilla::run_epoch(
                &self.part,
                self.caches.as_mut(),
                sess,
                epoch,
            ),
            RuntimeKind::Sequential => self.run_epoch_sequential(sess, epoch),
        }
    }

    /// The sequential (single-thread) epoch, kept for A/B comparison.
    fn run_epoch_sequential(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        let cfg = sess.cfg.clone();
        let b = cfg.train.batch_size;
        let parts = self.part.num_parts;
        let vb = (b / parts).max(1);
        let gpus = cfg.train.gpus_per_machine.max(1);
        let layers = cfg.model.layers;
        let ntypes = sess.g.schema.node_types.len();
        let mut net = SimNet::new(parts, cfg.cost.clone());
        let mut stages = StageTimes::default();
        let mut epoch_time = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut batches = 0usize;
        let mut worker_busy = vec![0.0f64; parts];
        let mut fetch = FetchStats::default();

        let mut train = sess.g.train_nodes();
        let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
        shuffle_rng.shuffle(&mut train);

        let spec = sess.rt.manifest.spec("vanilla")?.clone();
        // Root (target) rows join the fetch frontier only if the
        // artifact actually gathers them.
        let needs_root = spec.inputs.iter().any(|i| i.kind == "target_feat");

        for (bi, chunk) in train.chunks(b).enumerate() {
            if chunk.len() < vb * parts {
                break;
            }
            sess.adam_t += 1;
            let batch_seed = cfg.train.batch_seed(epoch, bi);

            let mut worker_time = vec![0.0f64; parts];
            let mut wgrads: HashMap<String, Vec<f32>> = HashMap::new();
            let mut row_grads: HashMap<usize, (Vec<NodeId>, Vec<f32>)> = HashMap::new();
            // type → (valid rows, remote rows) for the update-cost model.
            let mut learnable_rows: HashMap<usize, (u64, u64)> = HashMap::new();

            for w in 0..parts {
                let mut st = StageTimes::default();
                let micro = &chunk[w * vb..(w + 1) * vb];

                // -- sampling over the whole graph: remote hops are RPCs --
                let t0 = Instant::now();
                let sample = sample_tree(
                    &sess.g,
                    &sess.tree,
                    &cfg.model.fanouts,
                    micro,
                    w * vb,
                    batch_seed,
                    |_| true,
                );
                let mut sample_t = t0.elapsed().as_secs_f64() * cfg.cost.compute_scale;
                let rstats = remote_counts(&sess.tree, &sample, &self.part, w);
                // Remote neighbor lookups: id traffic + one RPC per hop
                // per remote machine.
                sample_t += net.cost.xfer_time_msgs(
                    Lane::Net,
                    rstats.remote * 8,
                    (layers * (parts - 1)).max(1) as u64,
                );
                net.ledgers[w].charge(Lane::Net, rstats.remote * 8, 0.0);
                st.add(Stage::Sample, sample_t);

                // -- feature fetching: local via cache, remote via net --
                let owner = &self.part;
                let t1 = Instant::now();
                let extra = ExtraInputs::new();
                let frontier = if cfg.train.dedup_fetch {
                    self.frontiers[w].rebuild(&sess.tree, &sample, ntypes, needs_root);
                    Some(&self.frontiers[w])
                } else {
                    None
                };
                self.arenas[w].begin_batch(ntypes);
                let cache = self.caches.as_mut().map(|c| &mut c[w]);
                let (lits, acc) = build_inputs(
                    sess,
                    &spec,
                    Some(&sample),
                    frontier,
                    micro,
                    &extra,
                    &|ty, id| owner.owner_of(ty, id) != w,
                    cache,
                    0,
                    &mut self.arenas[w],
                )?;
                st.add(Stage::Copy, t1.elapsed().as_secs_f64() * cfg.cost.compute_scale);
                fetch.merge(acc.stats);
                let fetch_t =
                    super::common::vanilla_fetch_time(&net.cost, &acc, self.caches.is_some(), parts);
                net.ledgers[w].charge(Lane::Net, acc.stats.remote_bytes, 0.0);
                st.add(Stage::Fetch, fetch_t);

                // -- fused fwd+bwd step --
                let t2 = Instant::now();
                let outs = sess.rt.exec("vanilla", &lits)?;
                let step_t = t2.elapsed().as_secs_f64() * cfg.cost.compute_scale / gpus as f64;
                st.add(Stage::Forward, step_t * 0.45);
                st.add(Stage::Backward, step_t * 0.55);

                loss_sum += crate::runtime::lit_scalar(&outs[0])? as f64 / parts as f64;
                acc_sum += crate::runtime::lit_scalar(&outs[1])? as f64;

                for (o, out) in spec.outputs.iter().zip(&outs) {
                    match o.kind.as_str() {
                        "wgrad" => {
                            let g = crate::runtime::lit_to_vec(out)?;
                            match wgrads.get_mut(&o.name) {
                                Some(accg) => add_assign(accg, &g),
                                None => {
                                    wgrads.insert(o.name.clone(), g);
                                }
                            }
                        }
                        "block_grad" => {
                            let (child, src_ty) = sess.edge_child(o.edge as usize);
                            let g = crate::runtime::lit_to_vec(out)?;
                            let entry = row_grads
                                .entry(src_ty)
                                .or_insert_with(|| (Vec::new(), Vec::new()));
                            let counts = learnable_rows.entry(src_ty).or_insert((0, 0));
                            for &id in &sample.ids[child] {
                                if id != PAD {
                                    counts.0 += 1;
                                    if owner.owner_of(src_ty, id) != w {
                                        counts.1 += 1;
                                    }
                                }
                            }
                            entry.0.extend_from_slice(&sample.ids[child]);
                            entry.1.extend_from_slice(&g);
                        }
                        "target_feat_grad" => {
                            if sess.store.is_learnable(sess.g.schema.target) {
                                let g = crate::runtime::lit_to_vec(out)?;
                                let entry = row_grads
                                    .entry(sess.g.schema.target)
                                    .or_insert_with(|| (Vec::new(), Vec::new()));
                                let counts =
                                    learnable_rows.entry(sess.g.schema.target).or_insert((0, 0));
                                counts.0 += micro.len() as u64;
                                entry.0.extend_from_slice(micro);
                                entry.1.extend_from_slice(&g);
                            }
                        }
                        _ => {}
                    }
                }
                worker_time[w] = st.total();
                for i in 0..stages.secs.len() {
                    stages.secs[i] += st.secs[i];
                }
            }
            epoch_time += worker_time.iter().cloned().fold(0.0, f64::max);
            for w in 0..parts {
                worker_busy[w] += worker_time[w];
            }

            // -- dense gradient all-reduce (data parallelism) --
            let grad_bytes = (sess.params.total_elems() * 4) as u64;
            let t_ar = net.allreduce(grad_bytes);
            stages.add(Stage::GradSync, t_ar);
            epoch_time += t_ar;

            // -- model update (every replica applies the mean grad) --
            let t3 = Instant::now();
            let inv = 1.0 / parts as f32;
            for (name, mut grad) in wgrads {
                for g in grad.iter_mut() {
                    *g *= inv;
                }
                sess.params.step(&name, &grad)?;
            }
            let upd_t = t3.elapsed().as_secs_f64();
            stages.add(Stage::Update, upd_t);
            epoch_time += upd_t;

            // -- learnable-feature updates: remote rows pay the network --
            let t4 = Instant::now();
            for (ty, (ids, grads)) in &row_grads {
                apply_learnable_grads(sess, *ty, ids, grads, inv);
            }
            let mut lf_t = t4.elapsed().as_secs_f64();
            let lr = super::common::learnable_rows_sorted(learnable_rows, &sess.store);
            let (cost_t, remote_bytes) =
                super::common::vanilla_learnable_update_cost(&net.cost, &lr, parts);
            lf_t += cost_t;
            if remote_bytes > 0 {
                net.ledgers[0].charge(Lane::Net, remote_bytes, 0.0);
            }
            stages.add(Stage::Update, lf_t);
            epoch_time += lf_t;

            batches += 1;
        }

        Ok(EpochReport {
            epoch_time_s: epoch_time,
            // No overlap in the sequential runtime.
            critical_path_s: epoch_time,
            worker_busy_s: worker_busy,
            stages,
            comm: net.total(),
            fetch,
            loss_mean: if batches > 0 { loss_sum / batches as f64 } else { f64::NAN },
            accuracy: if batches > 0 {
                acc_sum / (batches * vb * parts) as f64
            } else {
                f64::NAN
            },
            batches,
        })
    }

    pub fn hit_rates(&self) -> Vec<Vec<f64>> {
        self.caches
            .as_ref()
            .map(|cs| cs.iter().map(|c| c.hit_rates()).collect())
            .unwrap_or_default()
    }
}
