//! The vanilla execution engine — the paper's Fig. 3 pipeline as used by
//! DGL / GraphLearn: edge-cut partitioning + data parallelism. Each
//! worker samples the **full** k-hop tree for its microbatch (remote
//! sampling RPCs), fetches features from the distributed KV store
//! (remote rows cross the network — the communication bottleneck the
//! paper attacks), runs the fused `vanilla` train-step artifact, ring-
//! all-reduces dense gradients, and applies sparse updates to learnable
//! features (remote rows pay another network round trip).
//!
//! Baseline variants (paper §8.1): DGL-Random / DGL-METIS (no cache),
//! DGL-Opt (read-only feature cache), GraphLearn (per-type partitioning
//! + feature cache, no learnable-feature support).
//!
//! Since PR 3 the fused-step and update bodies live in
//! [`crate::exec::BatchPlan`]; this file owns engine construction and
//! the sequential scheduling — the thread-per-partition scheduling
//! lives in [`crate::cluster::vanilla`].

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cache::{FeatureCache, Policy, TypeProfile};
use crate::comm::{Lane, SimNet};
use crate::config::RuntimeKind;
use crate::exec::plan::vanilla_apply_updates;
use crate::exec::{
    BatchArena, BatchPlan, EpochWorld, ExecContext, ExecGate, GradAccumulator, ParamsView,
};
use crate::kvstore::FetchStats;
use crate::metrics::timeline::{EpochTimeline, LeaderSpan, WallClock, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::NodePartition;
use crate::sampling::{presample_hotness, remote_counts, sample_tree, Frontier};
use crate::util::rng::Rng;

use super::common::Session;

pub struct VanillaEngine {
    pub part: NodePartition,
    /// The per-batch stage pipeline (the fused `vanilla` step).
    plan: BatchPlan,
    /// One execution context per worker; `cache` is `None` for the
    /// DGL-Random/METIS baselines.
    contexts: Vec<ExecContext>,
    /// Per-worker dedup frontiers, recycled across batches (sequential
    /// runtime; cluster workers ping-pong their own).
    frontiers: Vec<Frontier>,
    /// Per-worker marshalling arenas (batch-scoped scratch since the
    /// exec contexts stopped owning one).
    arenas: Vec<BatchArena>,
    /// `Some` iff `train.shared_session` — serializes marshal+execute.
    gate: Option<ExecGate>,
    /// The typed socket lanes of a TCP session, opened on the first
    /// epoch and reused (each lane's receive queue exists once).
    tcp: Option<crate::cluster::vanilla::TcpLanes>,
}

impl VanillaEngine {
    /// `cache_policy`: `None` disables caching; baselines that cache
    /// (DGL-Opt, GraphLearn) cache **read-only** features only — caching
    /// non-replicated learnable rows buys them nothing because remote
    /// workers still fetch over the network (paper §8.1).
    pub fn new(
        sess: &mut Session,
        part: NodePartition,
        cache_policy: Policy,
    ) -> Result<VanillaEngine> {
        let cfg = &sess.cfg;
        let mut caches: Vec<Option<FeatureCache>> = if cache_policy == Policy::None {
            (0..part.num_parts).map(|_| None).collect()
        } else {
            let hotness = presample_hotness(
                &sess.g,
                &sess.tree,
                &cfg.model.fanouts,
                cfg.train.batch_size,
                2,
                cfg.train.seed ^ 0x807,
            );
            let profiles: Vec<TypeProfile> = sess
                .g
                .schema
                .node_types
                .iter()
                .map(|t| TypeProfile {
                    name: t.name.clone(),
                    count: t.count,
                    feat_dim: t.feat_dim,
                    learnable: t.learnable,
                })
                .collect();
            // Read-only restriction: learnable types get no cache share.
            let hot: Vec<Vec<u32>> = hotness
                .iter()
                .enumerate()
                .map(|(ty, h)| {
                    if profiles[ty].learnable {
                        vec![0; h.len()]
                    } else {
                        h.clone()
                    }
                })
                .collect();
            (0..part.num_parts)
                .map(|_| {
                    Some(FeatureCache::build(
                        cache_policy,
                        &profiles,
                        &hot,
                        &cfg.cost,
                        cfg.train.cache_bytes_per_gpu * cfg.train.gpus_per_machine as u64,
                        cfg.train.gpus_per_machine,
                    ))
                })
                .collect()
        };
        // Role-gated construction (PR 8): a TCP process plays one rank,
        // so only that worker's context gets an eager PJRT client; the
        // rest are deferred (they keep their caches for `hit_rates`,
        // but never load executables). The vanilla leader runs no
        // artifacts at all — every context stays deferred there.
        // In-process runs build everything eagerly as before.
        let role = match &sess.net {
            crate::net::Backend::Tcp(node) => Some(node.role()),
            crate::net::Backend::Channel => None,
        };
        let mut contexts = Vec::with_capacity(part.num_parts);
        for w in 0..part.num_parts {
            let eager = match role {
                None => true,
                Some(crate::net::Role::Worker(r)) => r == w,
                Some(crate::net::Role::Leader) => false,
            };
            contexts.push(if eager {
                ExecContext::new(
                    w,
                    0,
                    &sess.artifacts_dir,
                    Arc::clone(&sess.manifest),
                    caches[w].take(),
                )?
            } else {
                ExecContext::deferred(
                    w,
                    0,
                    &sess.artifacts_dir,
                    Arc::clone(&sess.manifest),
                    caches[w].take(),
                )
            });
        }
        let plan = BatchPlan::vanilla(&sess.manifest, part.num_parts)?;
        sess.params.ensure_artifacts(&sess.manifest, ["vanilla"]);
        let frontiers = vec![Frontier::default(); part.num_parts];
        let arenas = (0..part.num_parts).map(|_| BatchArena::new()).collect();
        let gate = sess.cfg.train.shared_session.then(ExecGate::new);
        Ok(VanillaEngine {
            part,
            plan,
            contexts,
            frontiers,
            arenas,
            gate,
            tcp: None,
        })
    }

    /// Run one epoch, dispatching to the runtime selected by
    /// `train.runtime`; both runtimes drive the same [`BatchPlan`]
    /// stages and produce byte-identical losses.
    pub fn run_epoch(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        // Open the socket lanes (once) before dispatching, so the
        // borrow of `sess.net` ends before `sess` moves on mutably.
        if let crate::net::Backend::Tcp(node) = &sess.net {
            crate::net::require_cluster_runtime(sess.cfg.train.runtime)?;
            if self.tcp.is_none() {
                self.tcp =
                    Some(crate::cluster::vanilla::TcpLanes::open(node, self.part.num_parts)?);
            }
        }
        if let Some(lanes) = &self.tcp {
            return crate::cluster::vanilla::run_epoch_tcp(
                &self.plan,
                &mut self.contexts,
                &self.part,
                self.gate.as_ref(),
                sess,
                epoch,
                lanes,
            );
        }
        match sess.cfg.train.runtime {
            RuntimeKind::Cluster => crate::cluster::vanilla::run_epoch(
                &self.plan,
                &mut self.contexts,
                &self.part,
                self.gate.as_ref(),
                sess,
                epoch,
            ),
            RuntimeKind::Sequential => self.run_epoch_sequential(sess, epoch),
        }
    }

    /// The sequential (single-thread) driver, kept for A/B comparison.
    fn run_epoch_sequential(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        let cfg = sess.cfg.clone();
        let b = cfg.train.batch_size;
        let parts = self.part.num_parts;
        let vb = (b / parts).max(1);
        let layers = cfg.model.layers;
        let ntypes = sess.g.schema.node_types.len();
        let g = Arc::clone(&sess.g);
        let tree = Arc::clone(&sess.tree);
        let mut net = SimNet::new(parts, cfg.cost.clone());
        let mut timeline = EpochTimeline::new(parts);
        let mut stages = StageTimes::default();
        let mut worker_stages = vec![StageTimes::default(); parts];
        let mut wall = WallClock::new(parts);
        let mut loss_sum = 0.0f64;
        let mut acc_sum = 0.0f64;
        let mut batch_losses = Vec::new();
        let mut batches = 0usize;
        let mut fetch = FetchStats::default();

        // Flight recorder (PR 6): the sequential driver plays every
        // rank on one thread — register once, re-tag the current rank
        // around each worker phase (`parts` is the shared update
        // phase's rank, matching the cluster leader's id).
        if cfg.train.trace {
            crate::obs::thread_register(parts as u32, "driver");
        }
        let cache_bases: Vec<_> = self
            .contexts
            .iter()
            .map(|c| crate::obs::cache_obs_base(c.cache.as_ref()))
            .collect();

        let world = EpochWorld {
            cfg: &cfg,
            g: &g,
            tree: &tree,
            store: &sess.store,
            gate: self.gate.as_ref(),
            epoch_t0: Instant::now(),
        };

        let mut train = sess.g.train_nodes();
        let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
        shuffle_rng.shuffle(&mut train);

        for (bi, chunk) in train.chunks(b).enumerate() {
            if chunk.len() < vb * parts {
                break;
            }
            let batch_seed = cfg.train.batch_seed(epoch, bi);
            crate::obs::set_batch(bi as u64);
            let mut gacc = GradAccumulator::default();
            let mut batch_loss = 0.0f64;
            let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);

            for w in 0..parts {
                crate::obs::set_rank(w as u32);
                let micro = &chunk[w * vb..(w + 1) * vb];

                // -- sampling over the whole graph: remote hops are RPCs --
                let t0 = Instant::now();
                let sample =
                    sample_tree(&g, &tree, &cfg.model.fanouts, micro, w * vb, batch_seed, |_| {
                        true
                    });
                let mut sample_s = t0.elapsed().as_secs_f64() * cfg.cost.compute_scale;
                let rstats = remote_counts(&tree, &sample, &self.part, w);
                // Remote neighbor lookups: id traffic + one RPC per hop
                // per remote machine.
                sample_s += cfg.cost.xfer_time_msgs(
                    Lane::Net,
                    rstats.remote * 8,
                    (layers * (parts - 1)).max(1) as u64,
                );
                net.ledgers[w].charge(Lane::Net, rstats.remote * 8, 0.0);

                // -- fused marshal + train step (the shared stage) --
                if cfg.train.dedup_fetch {
                    self.frontiers[w].rebuild(
                        &tree,
                        &sample,
                        ntypes,
                        self.plan.workers[w].needs_root,
                    );
                }
                let frontier = cfg.train.dedup_fetch.then(|| &self.frontiers[w]);
                let step = self.plan.workers[w].vanilla_step(
                    &mut self.contexts[w],
                    &world,
                    ParamsView::Owner(&sess.params),
                    &self.part,
                    &sample,
                    frontier,
                    micro,
                    sample_s,
                    &mut self.arenas[w],
                )?;
                net.ledgers[w].charge(Lane::Net, step.stats.remote_bytes, 0.0);
                batch_loss += step.loss / parts as f64;
                acc_sum += step.acc;
                fetch.merge(step.stats);
                stages.merge(&step.stages);
                worker_stages[w].merge(&step.stages);
                wall.record_forward(w, step.wall_fwd);
                worker_spans.push(step.span);
                gacc.absorb(step.grads)?;
            }
            loss_sum += batch_loss;
            batch_losses.push(batch_loss);

            // -- all-reduce + model + learnable updates (shared stage) --
            crate::obs::set_rank(parts as u32);
            let upd = vanilla_apply_updates(
                &world,
                &mut sess.params,
                &mut sess.adam_t,
                gacc,
                &mut net,
                parts,
            )?;
            stages.add(Stage::GradSync, upd.allreduce_s);
            stages.add(Stage::Update, upd.update_s + upd.lf_s);

            timeline.push_batch(
                worker_spans,
                LeaderSpan {
                    gather_s: upd.allreduce_s,
                    leader_s: 0.0,
                    scatter_s: 0.0,
                    update_s: upd.update_s + upd.lf_s,
                    sync_s: 0.0,
                },
            );
            batches += 1;
        }

        // ---- flight recorder: publish per-context cache deltas and
        // collect this thread's tracks + metrics into the report ----
        for (ctx, base) in self.contexts.iter().zip(&cache_bases) {
            crate::obs::record_cache_obs(&g, ctx.cache.as_ref(), base.as_deref());
        }
        let mut obs = crate::obs::ObsReport::default();
        crate::obs::TraceBlob::collect(parts as u32).merge_into(&mut obs);

        // No overlap in the sequential runtime.
        let epoch_time_s = timeline.sequential_time();
        Ok(EpochReport {
            epoch_time_s,
            critical_path_s: epoch_time_s,
            worker_busy_s: timeline.worker_busy_s(),
            worker_stages,
            wall,
            stages,
            comm: net.total(),
            fetch,
            wire: Default::default(), // the in-process transports move no frames
            loss_mean: if batches > 0 { loss_sum / batches as f64 } else { f64::NAN },
            accuracy: if batches > 0 {
                acc_sum / (batches * vb * parts) as f64
            } else {
                f64::NAN
            },
            batches,
            batch_losses,
            obs,
        })
    }

    pub fn hit_rates(&self) -> Vec<Vec<f64>> {
        self.contexts
            .iter()
            .filter_map(|c| c.cache.as_ref().map(|c| c.hit_rates()))
            .collect()
    }
}
