//! Coordinator engines: the RAF (Heta) engine and the vanilla
//! (DGL/GraphLearn-style) baseline engine, plus the `run_training` entry
//! point used by the CLI, examples and benches.

pub mod common;
pub mod raf;
pub mod vanilla;

use anyhow::{bail, Result};

pub use common::Session;
pub use raf::RafEngine;
pub use vanilla::VanillaEngine;

use crate::cache::Policy;
use crate::config::Config;
use crate::metrics::EpochReport;
use crate::partition::{edgecut, meta::meta_partition, metis_like};

/// Which baseline system an engine configuration models (paper §8.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// Heta: RAF + meta-partitioning + miss-penalty-aware cache.
    Heta,
    /// DGL-Random: vanilla engine, random edge-cut, no cache.
    DglRandom,
    /// DGL-METIS: vanilla engine, METIS-like edge-cut, no cache.
    DglMetis,
    /// DGL-Opt: DGL-METIS + read-only feature cache.
    DglOpt,
    /// GraphLearn: per-type random partitioning + feature cache.
    GraphLearn,
}

impl SystemKind {
    pub fn parse(s: &str) -> Option<SystemKind> {
        match s {
            "heta" | "raf" => Some(SystemKind::Heta),
            "dgl-random" => Some(SystemKind::DglRandom),
            "dgl-metis" | "vanilla" => Some(SystemKind::DglMetis),
            "dgl-opt" => Some(SystemKind::DglOpt),
            "graphlearn" => Some(SystemKind::GraphLearn),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Heta => "Heta",
            SystemKind::DglRandom => "DGL-Random",
            SystemKind::DglMetis => "DGL-METIS",
            SystemKind::DglOpt => "DGL-Opt",
            SystemKind::GraphLearn => "GraphLearn",
        }
    }

    pub fn all() -> [SystemKind; 5] {
        [
            SystemKind::Heta,
            SystemKind::DglRandom,
            SystemKind::DglMetis,
            SystemKind::DglOpt,
            SystemKind::GraphLearn,
        ]
    }
}

/// Engine wrapper so callers can drive either execution model uniformly.
pub enum Engine {
    Raf(RafEngine),
    Vanilla(VanillaEngine),
}

impl Engine {
    /// Build the engine modelling `system` for a session. Takes the
    /// session mutably to initialize every weight the engine's
    /// artifacts declare up front — marshalling (and the per-batch
    /// parameter snapshots the cluster runtime broadcasts) is then
    /// read-only over the parameter store.
    pub fn build(sess: &mut Session, system: SystemKind) -> Result<Engine> {
        let cfg = sess.cfg.clone();
        let cfg = &cfg;
        let p = cfg.train.num_partitions;
        Ok(match system {
            SystemKind::Heta => {
                let (mp, _) = meta_partition(&sess.g, p, cfg.model.layers, None);
                Engine::Raf(RafEngine::new(sess, mp, cfg.train.cache_policy)?)
            }
            SystemKind::DglRandom => {
                let part = edgecut::random(&sess.g, p, cfg.train.seed);
                Engine::Vanilla(VanillaEngine::new(sess, part, Policy::None)?)
            }
            SystemKind::DglMetis => {
                let part = metis_like::metis_like(&sess.g, p, cfg.train.seed);
                Engine::Vanilla(VanillaEngine::new(sess, part, Policy::None)?)
            }
            SystemKind::DglOpt => {
                let part = metis_like::metis_like(&sess.g, p, cfg.train.seed);
                Engine::Vanilla(VanillaEngine::new(sess, part, cfg.train.cache_policy)?)
            }
            SystemKind::GraphLearn => {
                let part = edgecut::by_type(&sess.g, p, cfg.train.seed);
                Engine::Vanilla(VanillaEngine::new(sess, part, cfg.train.cache_policy)?)
            }
        })
    }

    pub fn run_epoch(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        match self {
            Engine::Raf(e) => e.run_epoch(sess, epoch),
            Engine::Vanilla(e) => e.run_epoch(sess, epoch),
        }
    }
}

/// CLI entry point: train `epochs` epochs with the named engine and
/// return the merged report (stage times summed, loss from last epoch).
pub fn run_training(
    cfg: &Config,
    artifacts_dir: &str,
    engine_name: &str,
    epochs: usize,
) -> Result<EpochReport> {
    let system = match SystemKind::parse(engine_name) {
        Some(s) => s,
        None => bail!(
            "unknown engine '{engine_name}' (expected heta|dgl-random|dgl-metis|dgl-opt|graphlearn)"
        ),
    };
    let mut sess = Session::new(cfg, artifacts_dir)?;
    let mut engine = Engine::build(&mut sess, system)?;
    let mut total = EpochReport::default();
    for ep in 0..epochs {
        let rep = engine.run_epoch(&mut sess, ep)?;
        println!(
            "epoch {ep}: loss {:.4} acc {:.3} time {} (critical path {}, {} runtime)",
            rep.loss_mean,
            rep.accuracy,
            crate::util::fmt_secs(rep.epoch_time_s),
            crate::util::fmt_secs(rep.critical_path_s),
            cfg.train.runtime.name(),
        );
        total.absorb(&rep);
    }
    Ok(total)
}

/// Bench/report helper: load `configs/<name>.json`, build the engine for
/// `system`, run `epochs` epochs and return (merged report, last engine).
/// Panics on missing artifacts — bench targets require `make artifacts`.
pub fn bench_run(cfg_name: &str, system: SystemKind, epochs: usize) -> (EpochReport, Engine) {
    let cfg = Config::load(&format!("configs/{cfg_name}.json"))
        .unwrap_or_else(|e| panic!("loading config {cfg_name}: {e}"));
    let dir = format!("artifacts/{cfg_name}");
    let mut sess = Session::new(&cfg, &dir)
        .unwrap_or_else(|e| panic!("session for {cfg_name}: {e} (run `make artifacts`)"));
    let mut engine = Engine::build(&mut sess, system).unwrap();
    let mut total = EpochReport::default();
    for ep in 0..epochs {
        let rep = engine.run_epoch(&mut sess, ep).unwrap();
        total.absorb(&rep);
    }
    total.epoch_time_s /= epochs.max(1) as f64;
    total.critical_path_s /= epochs.max(1) as f64;
    (total, engine)
}
