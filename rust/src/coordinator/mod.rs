//! Coordinator engines: the RAF (Heta) engine and the vanilla
//! (DGL/GraphLearn-style) baseline engine, plus the `run_training` entry
//! point used by the CLI, examples and benches.

pub mod common;
pub mod raf;
pub mod vanilla;

use anyhow::{bail, Result};

pub use common::Session;
pub use raf::RafEngine;
pub use vanilla::VanillaEngine;

use crate::cache::Policy;
use crate::config::Config;
use crate::metrics::EpochReport;
use crate::partition::{edgecut, meta::meta_partition, metis_like};

/// Which baseline system an engine configuration models (paper §8.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// Heta: RAF + meta-partitioning + miss-penalty-aware cache.
    Heta,
    /// DGL-Random: vanilla engine, random edge-cut, no cache.
    DglRandom,
    /// DGL-METIS: vanilla engine, METIS-like edge-cut, no cache.
    DglMetis,
    /// DGL-Opt: DGL-METIS + read-only feature cache.
    DglOpt,
    /// GraphLearn: per-type random partitioning + feature cache.
    GraphLearn,
}

impl SystemKind {
    pub fn parse(s: &str) -> Option<SystemKind> {
        match s {
            "heta" | "raf" => Some(SystemKind::Heta),
            "dgl-random" => Some(SystemKind::DglRandom),
            "dgl-metis" | "vanilla" => Some(SystemKind::DglMetis),
            "dgl-opt" => Some(SystemKind::DglOpt),
            "graphlearn" => Some(SystemKind::GraphLearn),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Heta => "Heta",
            SystemKind::DglRandom => "DGL-Random",
            SystemKind::DglMetis => "DGL-METIS",
            SystemKind::DglOpt => "DGL-Opt",
            SystemKind::GraphLearn => "GraphLearn",
        }
    }

    pub fn all() -> [SystemKind; 5] {
        [
            SystemKind::Heta,
            SystemKind::DglRandom,
            SystemKind::DglMetis,
            SystemKind::DglOpt,
            SystemKind::GraphLearn,
        ]
    }
}

/// Engine wrapper so callers can drive either execution model uniformly.
pub enum Engine {
    Raf(RafEngine),
    Vanilla(VanillaEngine),
}

impl Engine {
    /// Build the engine modelling `system` for a session. Takes the
    /// session mutably to initialize every weight the engine's
    /// artifacts declare up front — marshalling (and the per-batch
    /// parameter snapshots the cluster runtime broadcasts) is then
    /// read-only over the parameter store.
    pub fn build(sess: &mut Session, system: SystemKind) -> Result<Engine> {
        let cfg = sess.cfg.clone();
        let cfg = &cfg;
        let p = cfg.train.num_partitions;
        Ok(match system {
            SystemKind::Heta => {
                let (mp, _) = meta_partition(&sess.g, p, cfg.model.layers, None);
                Engine::Raf(RafEngine::new(sess, mp, cfg.train.cache_policy)?)
            }
            SystemKind::DglRandom => {
                let part = edgecut::random(&sess.g, p, cfg.train.seed);
                Engine::Vanilla(VanillaEngine::new(sess, part, Policy::None)?)
            }
            SystemKind::DglMetis => {
                let part = metis_like::metis_like(&sess.g, p, cfg.train.seed);
                Engine::Vanilla(VanillaEngine::new(sess, part, Policy::None)?)
            }
            SystemKind::DglOpt => {
                let part = metis_like::metis_like(&sess.g, p, cfg.train.seed);
                Engine::Vanilla(VanillaEngine::new(sess, part, cfg.train.cache_policy)?)
            }
            SystemKind::GraphLearn => {
                let part = edgecut::by_type(&sess.g, p, cfg.train.seed);
                Engine::Vanilla(VanillaEngine::new(sess, part, cfg.train.cache_policy)?)
            }
        })
    }

    pub fn run_epoch(&mut self, sess: &mut Session, epoch: usize) -> Result<EpochReport> {
        match self {
            Engine::Raf(e) => e.run_epoch(sess, epoch),
            Engine::Vanilla(e) => e.run_epoch(sess, epoch),
        }
    }
}

/// Checkpoint/restore options threaded from the CLI
/// (`--checkpoint-dir`, `--resume`) into the training loop.
#[derive(Debug, Clone)]
pub struct CkptOpts {
    /// Directory the epoch-boundary checkpoint lives in.
    pub dir: String,
    /// Restore from an existing checkpoint before training. A missing
    /// checkpoint file is not an error — the run starts fresh, so a
    /// blanket `--resume` relaunch works on attempt one too.
    pub resume: bool,
}

/// Restore `sess` from `opts.dir` when `--resume` asked for it.
/// Returns the epoch training should start from (0 when no checkpoint
/// applies). Every rank of a cluster restores — the leader for the
/// real trajectory, workers so their learnable-feature replicas start
/// consistent with the leader's store.
fn resume_session(sess: &mut Session, ckpt: Option<&CkptOpts>) -> Result<usize> {
    let Some(opts) = ckpt.filter(|o| o.resume) else {
        return Ok(0);
    };
    match crate::ckpt::load(&opts.dir)? {
        Some(ck) => {
            crate::ckpt::restore(sess, &ck)?;
            crate::log!(
                Info,
                "resumed from {} — continuing at epoch {}",
                crate::ckpt::path(&opts.dir),
                ck.epoch
            );
            Ok(ck.epoch)
        }
        None => {
            crate::log!(
                Info,
                "--resume: no checkpoint at {} — starting fresh",
                crate::ckpt::path(&opts.dir)
            );
            Ok(0)
        }
    }
}

/// CLI entry point: train `epochs` epochs with the named engine and
/// return the merged report (stage times summed, loss from last epoch).
pub fn run_training(
    cfg: &Config,
    artifacts_dir: &str,
    engine_name: &str,
    epochs: usize,
) -> Result<EpochReport> {
    run_training_with(cfg, artifacts_dir, engine_name, epochs, crate::net::Backend::Channel)
}

/// [`run_training`] over an explicit transport backend. With
/// `Backend::Tcp` this process plays exactly one rank of a
/// multi-process cluster: the leader prints and returns the real
/// trajectory, worker ranks print their wire traffic and return empty
/// reports (the losses live with the leader).
pub fn run_training_with(
    cfg: &Config,
    artifacts_dir: &str,
    engine_name: &str,
    epochs: usize,
    net: crate::net::Backend,
) -> Result<EpochReport> {
    run_training_ckpt(cfg, artifacts_dir, engine_name, epochs, net, None)
}

/// [`run_training_with`] plus checkpointing: with `ckpt` set, the run
/// restores from the checkpoint first (under `--resume`) and the
/// leader rewrites it at every epoch boundary, so a killed cluster
/// relaunched with `--resume` replays the remaining epochs
/// bit-for-bit. TCP worker ranks restore but never write — their
/// stores are replicas of the leader's.
pub fn run_training_ckpt(
    cfg: &Config,
    artifacts_dir: &str,
    engine_name: &str,
    epochs: usize,
    net: crate::net::Backend,
    ckpt: Option<&CkptOpts>,
) -> Result<EpochReport> {
    let system = match SystemKind::parse(engine_name) {
        Some(s) => s,
        None => bail!(
            "unknown engine '{engine_name}' (expected heta|dgl-random|dgl-metis|dgl-opt|graphlearn)"
        ),
    };
    let mut sess = Session::new(cfg, artifacts_dir)?;
    sess.net = net;
    let worker_rank = sess.net.is_tcp_worker();
    let start_epoch = resume_session(&mut sess, ckpt)?;
    let mut engine = Engine::build(&mut sess, system)?;
    let mut total = EpochReport::default();
    for ep in start_epoch..epochs.max(start_epoch) {
        // /healthz progress: a no-op (one relaxed load) unless this
        // rank armed its telemetry plane with --metrics-addr.
        crate::obs::health_set_epoch(ep as i64);
        let rep = engine.run_epoch(&mut sess, ep)?;
        if worker_rank {
            crate::log!(
                Info,
                "epoch {ep}: worker rank done (wire: {} sent, {} received)",
                crate::util::fmt_bytes(rep.wire.real_sent),
                crate::util::fmt_bytes(rep.wire.real_recv),
            );
        } else {
            crate::log!(
                Info,
                "epoch {ep}: loss {:.4} acc {:.3} time {} (critical path {}, {} runtime)",
                rep.loss_mean,
                rep.accuracy,
                crate::util::fmt_secs(rep.epoch_time_s),
                crate::util::fmt_secs(rep.critical_path_s),
                cfg.train.runtime.name(),
            );
        }
        total.absorb(&rep);
        if let Some(opts) = ckpt {
            if !worker_rank {
                // The boundary snapshot records `ep + 1`: the next
                // epoch a resumed run should execute.
                let ck = crate::ckpt::capture(&sess, ep + 1);
                crate::ckpt::save(&opts.dir, &ck)?;
            }
        }
    }
    Ok(total)
}

/// Run `epochs` cluster epochs over a **loopback TCP star**: one OS
/// thread per rank, each with its *own* [`Session`] — its own feature
/// store, parameter store and execution contexts — connected through
/// real sockets on `127.0.0.1` (an ephemeral port, so parallel tests
/// never collide). Process semantics without subprocess management:
/// every cluster message crosses the wire through the codec, and the
/// leader's learnable-feature updates reach the other stores only via
/// the replication deltas. Returns the leader's per-epoch reports.
///
/// This is the equivalence half of `tests/test_net_transport.rs` and
/// the TCP side of `benches/net_transport.rs`; `heta launch` runs the
/// same protocol with real processes.
pub fn run_loopback_tcp(
    cfg: &Config,
    artifacts_dir: &str,
    system: SystemKind,
    epochs: usize,
) -> Result<Vec<EpochReport>> {
    // The socket star only exists under the cluster runtime; force it
    // rather than let a sequential config run every rank independently
    // under a "tcp" label.
    let mut cfg = cfg.clone();
    cfg.train.runtime = crate::config::RuntimeKind::Cluster;
    let cfg = &cfg;
    let parts = cfg.train.num_partitions;
    // Mesh configs need the brokered worker↔worker handshake on both
    // sides of the star — a plain dial against a mesh leader (or vice
    // versa) would hang waiting for the table.
    let mesh = cfg.train.wire_exchange.is_mesh();
    let listener = std::net::TcpListener::bind("127.0.0.1:0")
        .map_err(|e| anyhow::anyhow!("binding a loopback listener: {e}"))?;
    let addr = listener
        .local_addr()
        .map_err(|e| anyhow::anyhow!("reading the loopback address: {e}"))?
        .to_string();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..parts)
            .map(|w| {
                let addr = addr.clone();
                s.spawn(move || -> Result<()> {
                    let node = if mesh {
                        crate::net::tcp::dial_mesh_with(
                            &addr,
                            w,
                            parts,
                            crate::net::tcp::DIAL_TIMEOUT,
                            crate::net::tcp::HbCfg::default(),
                        )?
                    } else {
                        crate::net::tcp::dial(&addr, w, parts, crate::net::tcp::DIAL_TIMEOUT)?
                    };
                    let mut sess = Session::new(cfg, artifacts_dir)?;
                    sess.net = crate::net::Backend::Tcp(node);
                    let mut engine = Engine::build(&mut sess, system)?;
                    for ep in 0..epochs {
                        engine.run_epoch(&mut sess, ep)?;
                    }
                    Ok(())
                })
            })
            .collect();
        let run_leader = || -> Result<Vec<EpochReport>> {
            let node = if mesh {
                crate::net::tcp::accept_workers_mesh_with(
                    listener,
                    parts,
                    crate::net::tcp::HbCfg::default(),
                )?
            } else {
                crate::net::tcp::accept_workers(listener, parts)?
            };
            let mut sess = Session::new(cfg, artifacts_dir)?;
            sess.net = crate::net::Backend::Tcp(node);
            let mut engine = Engine::build(&mut sess, system)?;
            (0..epochs).map(|ep| engine.run_epoch(&mut sess, ep)).collect()
        };
        let led = run_leader();
        let mut worker_err: Option<anyhow::Error> = None;
        for (w, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e.context(format!("loopback worker rank {w}")));
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err =
                            Some(anyhow::anyhow!("loopback worker rank {w} panicked"));
                    }
                }
            }
        }
        match (led, worker_err) {
            (Ok(reps), None) => Ok(reps),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    })
}

/// One checkpointing attempt of a loopback TCP cluster: every rank
/// restores from `ckpt_dir` (fresh start when no checkpoint exists
/// yet), the leader rewrites the checkpoint at each epoch boundary,
/// and heartbeat timing comes from the config's `hb_*` knobs. Returns
/// the reports of every epoch the leader *completed* this attempt plus
/// the error that stopped it, if any — the partial-progress shape the
/// recovery loop in [`run_loopback_tcp_recovering`] needs.
pub fn run_loopback_tcp_ckpt(
    cfg: &Config,
    artifacts_dir: &str,
    system: SystemKind,
    epochs: usize,
    ckpt_dir: &str,
) -> (Vec<EpochReport>, Option<anyhow::Error>) {
    let mut cfg = cfg.clone();
    cfg.train.runtime = crate::config::RuntimeKind::Cluster;
    let cfg = &cfg;
    let parts = cfg.train.num_partitions;
    let hb = crate::net::tcp::HbCfg::from_train(&cfg.train);
    let mesh = cfg.train.wire_exchange.is_mesh();
    let opts = CkptOpts { dir: ckpt_dir.to_string(), resume: true };
    let listener = match std::net::TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l,
        Err(e) => return (Vec::new(), Some(anyhow::anyhow!("binding a loopback listener: {e}"))),
    };
    let addr = match listener.local_addr() {
        Ok(a) => a.to_string(),
        Err(e) => {
            return (Vec::new(), Some(anyhow::anyhow!("reading the loopback address: {e}")))
        }
    };
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..parts)
            .map(|w| {
                let addr = addr.clone();
                let opts = opts.clone();
                s.spawn(move || -> Result<()> {
                    let dial = if mesh {
                        crate::net::tcp::dial_mesh_with
                    } else {
                        crate::net::tcp::dial_with
                    };
                    let node = dial(&addr, w, parts, crate::net::tcp::DIAL_TIMEOUT, hb)?;
                    let mut sess = Session::new(cfg, artifacts_dir)?;
                    sess.net = crate::net::Backend::Tcp(node);
                    let start = resume_session(&mut sess, Some(&opts))?;
                    let mut engine = Engine::build(&mut sess, system)?;
                    for ep in start..epochs.max(start) {
                        engine.run_epoch(&mut sess, ep)?;
                    }
                    Ok(())
                })
            })
            .collect();
        let mut reports: Vec<EpochReport> = Vec::new();
        let led: Result<()> = (|| {
            let accept = if mesh {
                crate::net::tcp::accept_workers_mesh_with
            } else {
                crate::net::tcp::accept_workers_with
            };
            let node = accept(listener, parts, hb)?;
            let mut sess = Session::new(cfg, artifacts_dir)?;
            sess.net = crate::net::Backend::Tcp(node);
            let start = resume_session(&mut sess, Some(&opts))?;
            let mut engine = Engine::build(&mut sess, system)?;
            for ep in start..epochs.max(start) {
                let rep = engine.run_epoch(&mut sess, ep)?;
                reports.push(rep);
                let ck = crate::ckpt::capture(&sess, ep + 1);
                crate::ckpt::save(&opts.dir, &ck)?;
            }
            Ok(())
        })();
        let mut worker_err: Option<anyhow::Error> = None;
        for (w, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e.context(format!("loopback worker rank {w}")));
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow::anyhow!("loopback worker rank {w} panicked"));
                    }
                }
            }
        }
        (reports, led.err().or(worker_err))
    })
}

/// Kill-and-recover driver over [`run_loopback_tcp_ckpt`]: run the
/// cluster, and on failure clear the injected fault spec (it fired;
/// the respawned cluster must run clean, exactly like `heta launch`
/// dropping `--fail` on respawn) and relaunch resuming from the last
/// epoch-boundary checkpoint, up to `max_attempts` total attempts.
/// The concatenation of the completed-epoch reports across attempts is
/// the full trajectory — byte-identical to a fault-free run, which is
/// precisely what `tests/test_fault_tolerance.rs` pins.
pub fn run_loopback_tcp_recovering(
    cfg: &Config,
    artifacts_dir: &str,
    system: SystemKind,
    epochs: usize,
    ckpt_dir: &str,
    max_attempts: usize,
) -> Result<Vec<EpochReport>> {
    let attempts = max_attempts.max(1);
    let mut cfg = cfg.clone();
    let mut reports: Vec<EpochReport> = Vec::new();
    for attempt in 1..=attempts {
        let (mut got, err) =
            run_loopback_tcp_ckpt(&cfg, artifacts_dir, system, epochs, ckpt_dir);
        reports.append(&mut got);
        let Some(e) = err else { return Ok(reports) };
        if attempt == attempts {
            return Err(e.context(format!("cluster still failing after {attempts} attempts")));
        }
        crate::log!(
            Warn,
            "cluster attempt {attempt} failed ({e:#}); recovering from {}",
            crate::ckpt::path(ckpt_dir)
        );
        cfg.train.fail = None;
    }
    bail!("recovery loop needs at least one attempt")
}

/// Bench/report helper: load `configs/<name>.json`, build the engine for
/// `system`, run `epochs` epochs and return (merged report, last engine).
/// Panics on missing artifacts — bench targets require `make artifacts`.
pub fn bench_run(cfg_name: &str, system: SystemKind, epochs: usize) -> (EpochReport, Engine) {
    let cfg = Config::load(&format!("configs/{cfg_name}.json"))
        .unwrap_or_else(|e| panic!("loading config {cfg_name}: {e}"));
    let dir = format!("artifacts/{cfg_name}");
    let mut sess = Session::new(&cfg, &dir)
        .unwrap_or_else(|e| panic!("session for {cfg_name}: {e} (run `make artifacts`)"));
    let mut engine = Engine::build(&mut sess, system)
        .unwrap_or_else(|e| panic!("building {} engine for {cfg_name}: {e:#}", system.name()));
    let mut total = EpochReport::default();
    for ep in 0..epochs {
        let rep = engine
            .run_epoch(&mut sess, ep)
            .unwrap_or_else(|e| panic!("{}/{cfg_name} epoch {ep}: {e:#}", system.name()));
        total.absorb(&rep);
    }
    total.epoch_time_s /= epochs.max(1) as f64;
    total.critical_path_s /= epochs.max(1) as f64;
    (total, engine)
}
