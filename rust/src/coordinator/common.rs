//! The training session: the state one training run *shares* across
//! its workers, after PR 3 split everything execution-related out into
//! per-worker [`crate::exec::ExecContext`]s.
//!
//! What remains here is exactly the state with distributed-system
//! semantics:
//!
//! * the immutable substrates (`cfg`, `g`, `tree`, the parsed artifact
//!   [`Manifest`]) — `Arc`-shared, read lock-free;
//! * the feature KV store behind a reader-writer lock (the "KVStore"
//!   of the paper's Fig. 3): marshal stages on any worker read
//!   concurrently, the leader's update stage is the only writer, and
//!   the batch protocol keeps the two phases disjoint;
//! * the leader-owned [`ParamStore`] plus the shared sparse-Adam
//!   timestep — workers never touch either; they marshal weights from
//!   the per-batch [`ParamSnapshot`](crate::runtime::ParamSnapshot)
//!   broadcast.
//!
//! The old monolithic `Session` also owned the PJRT runtime and every
//! marshalling buffer, which is why all artifact execution used to
//! serialize on one session mutex; those now live in each worker's
//! `ExecContext`, and the marshalling stage itself
//! (`build_inputs`, `BatchArena`) in [`crate::exec::marshal`].

use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::config::Config;
use crate::hetgraph::{HetGraph, MetaTree};
use crate::kvstore::FeatureStore;
use crate::optim::AdamParams;
use crate::runtime::{Manifest, ParamStore};

/// One training session: graph, features, parameters, artifact manifest.
pub struct Session {
    pub cfg: Config,
    pub g: Arc<HetGraph>,
    pub tree: Arc<MetaTree>,
    /// The distributed feature KV store. Reader-writer semantics: any
    /// worker's marshal stage reads concurrently; the leader's update
    /// stage writes (learnable tables) in a phase where no worker
    /// marshals.
    pub store: RwLock<FeatureStore>,
    /// Leader-owned parameters; workers read per-batch snapshots.
    pub params: ParamStore,
    /// The parsed artifact manifest, shared by every worker context's
    /// runtime.
    pub manifest: Arc<Manifest>,
    /// Where the artifacts live (worker contexts compile from here).
    pub artifacts_dir: String,
    /// Shared sparse-Adam timestep for learnable tables.
    pub adam_t: i32,
    /// Which transport the cluster runtime rides on:
    /// [`Backend::Channel`](crate::net::Backend) (default — every rank
    /// a thread of this process) or `Backend::Tcp` (this process plays
    /// one rank of a multi-process socket star; see [`crate::net`]).
    pub net: crate::net::Backend,
}

impl Session {
    pub fn new(cfg: &Config, artifacts_dir: &str) -> Result<Session> {
        let g = cfg.build_graph();
        let tree = MetaTree::build(&g.schema, cfg.model.layers);
        let store = FeatureStore::new(&g, cfg.train.seed);
        let hp = AdamParams {
            lr: cfg.train.lr as f32,
            ..Default::default()
        };
        let manifest = Arc::new(Manifest::load(artifacts_dir)?);
        Ok(Session {
            cfg: cfg.clone(),
            g: Arc::new(g),
            tree: Arc::new(tree),
            store: RwLock::new(store),
            params: ParamStore::new(cfg.train.seed, hp),
            manifest,
            artifacts_dir: artifacts_dir.to_string(),
            adam_t: 0,
            net: crate::net::Backend::Channel,
        })
    }

    /// Child vertex and source type of a metatree edge.
    pub fn edge_child(&self, edge: usize) -> (usize, usize) {
        crate::exec::marshal::edge_child(&self.g, &self.tree, edge)
    }
}

/// Modeled time to move `bytes` of gathered features host→device over
/// PCIe in one batched transfer (the Copy stage of Fig. 3).
pub fn h2d_time(sess: &Session, bytes: u64) -> f64 {
    sess.cfg.cost.xfer_time(crate::comm::Lane::Pcie, bytes)
}
