//! Shared training-session state and input-marshalling helpers used by
//! both execution engines (RAF and vanilla). Everything an engine needs
//! to turn a [`TreeSample`] plus the manifest's input specs into the
//! flat literal list a PJRT executable consumes.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cache::FeatureCache;
use crate::comm::Lane;
use crate::config::Config;
use crate::hetgraph::{HetGraph, MetaTree, NodeId};
use crate::kvstore::{FeatureStore, FetchStats};
use crate::optim::AdamParams;
use crate::runtime::{lit_f32, lit_i32, ArtifactSpec, ParamStore, Runtime};
use crate::sampling::{TreeSample, PAD};

/// Extra per-batch inputs supplied by the engine (leader partial sums,
/// backward gradients), keyed by (kind, layer).
pub type ExtraInputs = HashMap<(String, usize), Vec<f32>>;

/// One training session: graph, features, parameters, runtime.
///
/// The immutable substrates (`g`, `tree`) are `Arc`-shared so the
/// cluster runtime's worker threads can sample lock-free while the
/// mutable state (store/params/runtime) sits behind the session mutex.
pub struct Session {
    pub cfg: Config,
    pub g: Arc<HetGraph>,
    pub tree: Arc<MetaTree>,
    pub store: FeatureStore,
    pub params: ParamStore,
    pub rt: Runtime,
    /// Shared sparse-Adam timestep for learnable tables.
    pub adam_t: i32,
}

impl Session {
    pub fn new(cfg: &Config, artifacts_dir: &str) -> Result<Session> {
        let g = cfg.build_graph();
        let tree = MetaTree::build(&g.schema, cfg.model.layers);
        let store = FeatureStore::new(&g, cfg.train.seed);
        let hp = AdamParams {
            lr: cfg.train.lr as f32,
            ..Default::default()
        };
        let rt = Runtime::load(artifacts_dir)?;
        Ok(Session {
            cfg: cfg.clone(),
            g: Arc::new(g),
            tree: Arc::new(tree),
            store,
            params: ParamStore::new(cfg.train.seed, hp),
            rt,
            adam_t: 0,
        })
    }

    /// Child vertex and source type of a metatree edge.
    pub fn edge_child(&self, edge: usize) -> (usize, usize) {
        let e = &self.tree.edges[edge];
        (e.child, self.g.schema.relations[e.rel].src)
    }

    /// Target-type labels of a batch as i32.
    pub fn batch_labels(&self, batch: &[NodeId]) -> Vec<i32> {
        batch.iter().map(|&b| self.g.labels[b as usize] as i32).collect()
    }
}

/// Aggregate fetch accounting of one input build.
#[derive(Debug, Clone, Default)]
pub struct GatherAccounting {
    pub stats: FetchStats,
    /// Modeled cache/miss time (Fetch stage), all node types.
    pub cache_time_s: f64,
    /// The read-only share of `cache_time_s`. Read-only rows are
    /// immutable during training, so the cluster pipeline may prefetch
    /// them for batch `i+1` while batch `i` executes; learnable rows
    /// (the remainder) must wait for batch `i`'s update.
    pub cache_time_ro_s: f64,
    /// Per-(type,id) rows touched — reused for the learnable write-back.
    pub touched: Vec<(usize, Vec<NodeId>)>,
}

/// Build the literal list for an artifact from its manifest spec.
///
/// `sample` provides block/mask ids, `extra` provides engine-computed
/// tensors (partial sums / gradients), `is_remote` classifies feature
/// rows for locality accounting, and `cache` (if present) is consulted
/// per fetched row, accumulating modeled miss time.
#[allow(clippy::too_many_arguments)]
pub fn build_inputs(
    sess: &mut Session,
    spec: &ArtifactSpec,
    sample: Option<&TreeSample>,
    batch: &[NodeId],
    extra: &ExtraInputs,
    is_remote: &dyn Fn(usize, NodeId) -> bool,
    cache: Option<&mut FeatureCache>,
    gpu: usize,
) -> Result<(Vec<xla::Literal>, GatherAccounting)> {
    let mut acc = GatherAccounting::default();
    let mut lits = Vec::with_capacity(spec.inputs.len());
    let cost = sess.cfg.cost.clone();
    let mut cache = cache;
    for inp in &spec.inputs {
        match inp.kind.as_str() {
            "block" => {
                let sample = sample.ok_or_else(|| anyhow!("block input without sample"))?;
                let (child, src_ty) = sess.edge_child(inp.edge as usize);
                let ids = &sample.ids[child];
                let dim = sess.store.dim(src_ty);
                let mut buf = vec![0f32; ids.len() * dim];
                let stats = sess
                    .store
                    .gather(src_ty, ids, &mut buf, |id| is_remote(src_ty, id))?;
                acc.stats.merge(stats);
                if let Some(c) = cache.as_deref_mut() {
                    let learnable = sess.store.is_learnable(src_ty);
                    for &id in ids.iter().filter(|&&id| id != PAD) {
                        let t = c.access(&cost, src_ty, id, gpu, false);
                        acc.cache_time_s += t;
                        if !learnable {
                            acc.cache_time_ro_s += t;
                        }
                    }
                }
                acc.touched.push((src_ty, ids.clone()));
                lits.push(lit_f32(&buf, &inp.shape)?);
            }
            "mask" => {
                let sample = sample.ok_or_else(|| anyhow!("mask input without sample"))?;
                let (child, _) = sess.edge_child(inp.edge as usize);
                let mask: Vec<f32> = sample.ids[child]
                    .iter()
                    .map(|&id| if id == PAD { 0.0 } else { 1.0 })
                    .collect();
                lits.push(lit_f32(&mask, &inp.shape)?);
            }
            "weight" => {
                sess.params.ensure(inp);
                lits.push(lit_f32(sess.params.get(&inp.name), &inp.shape)?);
            }
            "target_feat" => {
                let ty = sess.g.schema.target;
                let dim = sess.store.dim(ty);
                let mut buf = vec![0f32; batch.len() * dim];
                let stats = sess
                    .store
                    .gather(ty, batch, &mut buf, |id| is_remote(ty, id))?;
                acc.stats.merge(stats);
                if let Some(c) = cache.as_deref_mut() {
                    let learnable = sess.store.is_learnable(ty);
                    for &id in batch {
                        let t = c.access(&cost, ty, id, gpu, false);
                        acc.cache_time_s += t;
                        if !learnable {
                            acc.cache_time_ro_s += t;
                        }
                    }
                }
                acc.touched.push((ty, batch.to_vec()));
                lits.push(lit_f32(&buf, &inp.shape)?);
            }
            "labels" => {
                let labels = sess.batch_labels(batch);
                lits.push(lit_i32(&labels, &inp.shape)?);
            }
            "partial_sum" | "grad" => {
                let key = (inp.kind.clone(), inp.layer);
                let data = extra
                    .get(&key)
                    .ok_or_else(|| anyhow!("missing extra input {key:?}"))?;
                lits.push(lit_f32(data, &inp.shape)?);
            }
            other => anyhow::bail!("unknown input kind '{other}'"),
        }
    }
    Ok((lits, acc))
}

/// Modeled time to move `bytes` of gathered features host→device over
/// PCIe in one batched transfer (the Copy stage of Fig. 3).
pub fn h2d_time(sess: &Session, bytes: u64) -> f64 {
    sess.cfg.cost.xfer_time(Lane::Pcie, bytes)
}

/// Modeled feature-fetch time of one vanilla-engine input build: local
/// rows through the cache model (or the full DRAM+PCIe miss path when
/// uncached), remote rows over the network + PCIe. Single source of
/// truth for both runtimes — the sequential-vs-cluster A/B timing is
/// only meaningful if they price fetches identically.
pub fn vanilla_fetch_time(
    cost: &crate::comm::CostModel,
    acc: &GatherAccounting,
    cached: bool,
    parts: usize,
) -> f64 {
    let mut fetch_t = acc.cache_time_s;
    if !cached {
        // No cache: every local row pays DRAM + PCIe.
        let local_bytes = acc.stats.bytes - acc.stats.remote_bytes;
        fetch_t += cost.xfer_time_msgs(
            Lane::Dram,
            local_bytes,
            acc.stats.rows - acc.stats.remote_rows,
        ) + cost.xfer_time(Lane::Pcie, local_bytes);
    }
    fetch_t
        + cost.xfer_time_msgs(Lane::Net, acc.stats.remote_bytes, (parts - 1).max(1) as u64)
        + cost.xfer_time(Lane::Pcie, acc.stats.remote_bytes)
}

/// Modeled cost of the vanilla engine's sparse learnable-feature
/// update: per-row random DRAM read-modify-write of weight + moments,
/// plus the network round trip for remote rows. Returns the modeled
/// seconds and the remote bytes to charge to the network ledger.
pub fn vanilla_learnable_update_cost(
    cost: &crate::comm::CostModel,
    total_rows: u64,
    remote_rows: u64,
    parts: usize,
) -> (f64, u64) {
    // Row dimension is approximated — the engines don't thread per-type
    // dims through this path (matches the seed accounting).
    const DIM_GUESS: u64 = 64;
    let mut t = cost.xfer_time_msgs(Lane::Dram, total_rows * DIM_GUESS * 4 * 3, total_rows * 2);
    let mut remote_bytes = 0;
    if remote_rows > 0 {
        remote_bytes = remote_rows * DIM_GUESS * 4;
        t += cost.xfer_time_msgs(Lane::Net, remote_bytes, (parts - 1).max(1) as u64);
    }
    (t, remote_bytes)
}

/// Sum two equal-length f32 vectors in place.
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Scale a vector in place.
pub fn scale(a: &mut [f32], s: f32) {
    for x in a.iter_mut() {
        *x *= s;
    }
}

/// `FeatureStore`-backed learnable-row update: accumulate row grads and
/// apply sparse Adam. Returns rows updated.
pub fn apply_learnable_grads(
    sess: &mut Session,
    ty: usize,
    ids: &[NodeId],
    grads: &[f32],
    lr_scale: f32,
) -> usize {
    let dim = sess.store.dim(ty);
    let mut rows = crate::optim::accumulate_rows(ids, grads, dim, PAD);
    if lr_scale != 1.0 {
        for (_, g) in &mut rows {
            scale(g, lr_scale);
        }
    }
    let hp = AdamParams {
        lr: sess.cfg.train.lr as f32,
        ..Default::default()
    };
    let t = sess.adam_t;
    if let Some((w, m, v)) = sess.store.learnable_mut(ty) {
        crate::optim::sparse_adam_step(&rows, w, m, v, dim, t, hp)
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_and_scale() {
        let mut a = vec![1.0, 2.0];
        add_assign(&mut a, &[0.5, 0.5]);
        assert_eq!(a, vec![1.5, 2.5]);
        scale(&mut a, 2.0);
        assert_eq!(a, vec![3.0, 5.0]);
    }
}
