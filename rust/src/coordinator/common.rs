//! Shared training-session state and input-marshalling helpers used by
//! both execution engines (RAF and vanilla). Everything an engine needs
//! to turn a [`TreeSample`] plus the manifest's input specs into the
//! flat literal list a PJRT executable consumes.
//!
//! The hot path is the **deduplicated-frontier gather**: when the caller
//! supplies a batch [`Frontier`], each node type's distinct rows are
//! fetched once per batch into a [`BatchArena`] staging buffer
//! ([`FeatureStore::gather_unique`]), the cache model is consulted once
//! per unique id with misses charged as one batched staging transfer
//! ([`FeatureCache::access_unique`]), and every padded block literal is
//! produced by an in-memory scatter. Without a frontier
//! (`train.dedup_fetch = false`) the seed's per-slot gather and
//! per-occurrence cache accounting are reproduced exactly, which is the
//! A/B baseline. Gathered bytes are identical either way — only where
//! the copies and charges happen moves — so losses are byte-identical
//! across both settings and both runtimes.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::cache::FeatureCache;
use crate::comm::{CostModel, Lane};
use crate::config::Config;
use crate::hetgraph::{HetGraph, MetaTree, NodeId};
use crate::kvstore::{scatter_rows, FeatureStore, FetchStats};
use crate::optim::AdamParams;
use crate::runtime::{lit_f32, lit_i32, ArtifactSpec, ParamStore, Runtime};
use crate::sampling::{Frontier, TreeSample, PAD};

/// Extra per-batch inputs supplied by the engine (leader partial sums,
/// backward gradients), keyed by (kind, layer).
pub type ExtraInputs = HashMap<(String, usize), Vec<f32>>;

/// One training session: graph, features, parameters, runtime.
///
/// The immutable substrates (`g`, `tree`) are `Arc`-shared so the
/// cluster runtime's worker threads can sample lock-free while the
/// mutable state (store/params/runtime) sits behind the session mutex.
pub struct Session {
    pub cfg: Config,
    pub g: Arc<HetGraph>,
    pub tree: Arc<MetaTree>,
    pub store: FeatureStore,
    pub params: ParamStore,
    pub rt: Runtime,
    /// Shared sparse-Adam timestep for learnable tables.
    pub adam_t: i32,
}

impl Session {
    pub fn new(cfg: &Config, artifacts_dir: &str) -> Result<Session> {
        let g = cfg.build_graph();
        let tree = MetaTree::build(&g.schema, cfg.model.layers);
        let store = FeatureStore::new(&g, cfg.train.seed);
        let hp = AdamParams {
            lr: cfg.train.lr as f32,
            ..Default::default()
        };
        let rt = Runtime::load(artifacts_dir)?;
        Ok(Session {
            cfg: cfg.clone(),
            g: Arc::new(g),
            tree: Arc::new(tree),
            store,
            params: ParamStore::new(cfg.train.seed, hp),
            rt,
            adam_t: 0,
        })
    }

    /// Child vertex and source type of a metatree edge.
    pub fn edge_child(&self, edge: usize) -> (usize, usize) {
        let e = &self.tree.edges[edge];
        (e.child, self.g.schema.relations[e.rel].src)
    }
}

/// Aggregate fetch accounting of one input build.
///
/// With a dedup frontier, `stats` counts **unique** rows only (each
/// distinct id fetched once per batch); without one it counts padded
/// slots, matching the seed accounting. The learnable write-back no
/// longer needs a per-input id clone here — engines hold the batch's
/// [`Frontier`] and the sample itself for that.
#[derive(Debug, Clone, Default)]
pub struct GatherAccounting {
    pub stats: FetchStats,
    /// Modeled cache/miss time (Fetch stage), all node types.
    pub cache_time_s: f64,
    /// The read-only share of `cache_time_s`. Read-only rows are
    /// immutable during training, so the cluster pipeline may prefetch
    /// them for batch `i+1` while batch `i` executes; learnable rows
    /// (the remainder) must wait for batch `i`'s update.
    pub cache_time_ro_s: f64,
}

/// Reusable per-worker marshalling scratch, recycled across batches so
/// the input-build hot loop performs no steady-state allocation.
///
/// `staging[ty]` holds the batch frontier's distinct rows of type `ty`,
/// gathered once per batch on first use and then scattered into every
/// padded block literal that references the type — including the
/// backward pass's rebuild of the same batch (feature rows cannot change
/// between a batch's forward and backward, so restaging would be pure
/// waste). `block` / `mask` / `labels` are literal scratch: literals
/// copy out of them, so one buffer serves every input of every batch.
#[derive(Debug, Default)]
pub struct BatchArena {
    staging: Vec<Vec<f32>>,
    staged: Vec<bool>,
    block: Vec<f32>,
    mask: Vec<f32>,
    labels: Vec<i32>,
}

impl BatchArena {
    pub fn new() -> BatchArena {
        BatchArena::default()
    }

    /// Invalidate the per-batch staging (learnable rows may have been
    /// updated since the previous batch); buffer capacity survives.
    /// Call once per (worker, batch) before the batch's first
    /// `build_inputs`; later builds of the *same* batch (the backward
    /// pass) then reuse the staged rows.
    pub fn begin_batch(&mut self, num_types: usize) {
        self.staged.clear();
        self.staged.resize(num_types, false);
        if self.staging.len() < num_types {
            self.staging.resize_with(num_types, Vec::new);
        }
    }

    /// Grow-and-slice helper for the literal scratch buffers.
    fn block_slice(&mut self, n: usize) -> &mut [f32] {
        if self.block.len() < n {
            self.block.resize(n, 0.0);
        }
        &mut self.block[..n]
    }
}

/// Fetch `ty`'s distinct frontier rows into the arena staging buffer —
/// once per batch — merging unique-row fetch stats and the batched
/// cache accounting on first staging only.
#[allow(clippy::too_many_arguments)]
fn stage_type(
    store: &FeatureStore,
    cost: &CostModel,
    fr: &Frontier,
    ty: usize,
    is_remote: &dyn Fn(usize, NodeId) -> bool,
    cache: &mut Option<&mut FeatureCache>,
    gpu: usize,
    arena: &mut BatchArena,
    acc: &mut GatherAccounting,
) -> Result<()> {
    // `begin_batch` owns the per-batch invalidation; a missing call must
    // fail fast (index panic / this assert), never silently scatter the
    // previous batch's staged rows.
    debug_assert!(
        arena.staged.len() > ty && arena.staging.len() > ty,
        "stage_type before BatchArena::begin_batch"
    );
    if arena.staged[ty] {
        return Ok(());
    }
    let uniq = fr.rows(ty);
    let dim = store.dim(ty);
    let buf = &mut arena.staging[ty];
    buf.resize(uniq.len() * dim, 0.0);
    let stats = store.gather_unique(ty, uniq, buf, |id| is_remote(ty, id))?;
    acc.stats.merge(stats);
    if let Some(c) = cache.as_deref_mut() {
        let t = c.access_unique(cost, ty, uniq, gpu);
        acc.cache_time_s += t;
        if !store.is_learnable(ty) {
            acc.cache_time_ro_s += t;
        }
    }
    arena.staged[ty] = true;
    Ok(())
}

/// Build the literal list for an artifact from its manifest spec.
///
/// `sample` provides block/mask ids, `extra` provides engine-computed
/// tensors (partial sums / gradients), `is_remote` classifies feature
/// rows for locality accounting, and `cache` (if present) accumulates
/// modeled miss time. With `frontier` present (the dedup hot path),
/// feature rows are staged once per distinct id through `arena` and
/// scattered into the padded literals; with `frontier = None` the
/// seed's per-slot gather and per-occurrence cache accounting run
/// instead (byte-identical literals either way).
#[allow(clippy::too_many_arguments)]
pub fn build_inputs(
    sess: &mut Session,
    spec: &ArtifactSpec,
    sample: Option<&TreeSample>,
    frontier: Option<&Frontier>,
    batch: &[NodeId],
    extra: &ExtraInputs,
    is_remote: &dyn Fn(usize, NodeId) -> bool,
    cache: Option<&mut FeatureCache>,
    gpu: usize,
    arena: &mut BatchArena,
) -> Result<(Vec<xla::Literal>, GatherAccounting)> {
    let mut acc = GatherAccounting::default();
    let mut lits = Vec::with_capacity(spec.inputs.len());
    let cost = sess.cfg.cost.clone();
    let mut cache = cache;
    for inp in &spec.inputs {
        match inp.kind.as_str() {
            "block" => {
                let sample = sample.ok_or_else(|| anyhow!("block input without sample"))?;
                let (child, src_ty) = sess.edge_child(inp.edge as usize);
                let ids = &sample.ids[child];
                let dim = sess.store.dim(src_ty);
                let need = ids.len() * dim;
                if let Some(fr) = frontier {
                    // Dedup path: stage distinct rows once, then scatter
                    // slots from staging (every slot written: copies for
                    // valid rows, zero-fill for pads).
                    stage_type(
                        &sess.store,
                        &cost,
                        fr,
                        src_ty,
                        is_remote,
                        &mut cache,
                        gpu,
                        arena,
                        &mut acc,
                    )?;
                    if arena.block.len() < need {
                        arena.block.resize(need, 0.0);
                    }
                    scatter_rows(
                        &arena.staging[src_ty],
                        &fr.slot_to_unique[child],
                        dim,
                        &mut arena.block[..need],
                    );
                    lits.push(lit_f32(&arena.block[..need], &inp.shape)?);
                } else {
                    // Seed path: every padded slot gathered independently,
                    // cache consulted per occurrence.
                    let buf = arena.block_slice(need);
                    let stats = sess
                        .store
                        .gather(src_ty, ids, buf, |id| is_remote(src_ty, id))?;
                    acc.stats.merge(stats);
                    if let Some(c) = cache.as_deref_mut() {
                        let learnable = sess.store.is_learnable(src_ty);
                        for &id in ids.iter().filter(|&&id| id != PAD) {
                            let t = c.access(&cost, src_ty, id, gpu, false);
                            acc.cache_time_s += t;
                            if !learnable {
                                acc.cache_time_ro_s += t;
                            }
                        }
                    }
                    lits.push(lit_f32(&arena.block[..need], &inp.shape)?);
                }
            }
            "mask" => {
                let sample = sample.ok_or_else(|| anyhow!("mask input without sample"))?;
                let (child, _) = sess.edge_child(inp.edge as usize);
                let ids = &sample.ids[child];
                if arena.mask.len() < ids.len() {
                    arena.mask.resize(ids.len(), 0.0);
                }
                let mask = &mut arena.mask[..ids.len()];
                for (m, &id) in mask.iter_mut().zip(ids) {
                    *m = if id == PAD { 0.0 } else { 1.0 };
                }
                lits.push(lit_f32(mask, &inp.shape)?);
            }
            "weight" => {
                sess.params.ensure(inp);
                lits.push(lit_f32(sess.params.get(&inp.name), &inp.shape)?);
            }
            "target_feat" => {
                let ty = sess.g.schema.target;
                let dim = sess.store.dim(ty);
                let need = batch.len() * dim;
                if let Some(fr) = frontier {
                    stage_type(
                        &sess.store,
                        &cost,
                        fr,
                        ty,
                        is_remote,
                        &mut cache,
                        gpu,
                        arena,
                        &mut acc,
                    )?;
                    if arena.block.len() < need {
                        arena.block.resize(need, 0.0);
                    }
                    let block = &mut arena.block[..need];
                    let staging = &arena.staging[ty];
                    for (i, &id) in batch.iter().enumerate() {
                        let dst = &mut block[i * dim..(i + 1) * dim];
                        match fr.unique_index(ty, id) {
                            Some(u) => dst.copy_from_slice(&staging[u * dim..(u + 1) * dim]),
                            None => {
                                // Defensive: callers whose spec gathers
                                // target features build the frontier with
                                // `include_root`, which covers the batch;
                                // an out-of-frontier id falls back to a
                                // per-row gather with its own accounting.
                                let stats = sess.store.gather(
                                    ty,
                                    std::slice::from_ref(&id),
                                    dst,
                                    |id| is_remote(ty, id),
                                )?;
                                acc.stats.merge(stats);
                                if let Some(c) = cache.as_deref_mut() {
                                    let t = c.access(&cost, ty, id, gpu, false);
                                    acc.cache_time_s += t;
                                    if !sess.store.is_learnable(ty) {
                                        acc.cache_time_ro_s += t;
                                    }
                                }
                            }
                        }
                    }
                    lits.push(lit_f32(&arena.block[..need], &inp.shape)?);
                } else {
                    let buf = arena.block_slice(need);
                    let stats = sess.store.gather(ty, batch, buf, |id| is_remote(ty, id))?;
                    acc.stats.merge(stats);
                    if let Some(c) = cache.as_deref_mut() {
                        let learnable = sess.store.is_learnable(ty);
                        for &id in batch {
                            let t = c.access(&cost, ty, id, gpu, false);
                            acc.cache_time_s += t;
                            if !learnable {
                                acc.cache_time_ro_s += t;
                            }
                        }
                    }
                    lits.push(lit_f32(&arena.block[..need], &inp.shape)?);
                }
            }
            "labels" => {
                arena.labels.clear();
                arena
                    .labels
                    .extend(batch.iter().map(|&b| sess.g.labels[b as usize] as i32));
                lits.push(lit_i32(&arena.labels, &inp.shape)?);
            }
            "partial_sum" | "grad" => {
                let key = (inp.kind.clone(), inp.layer);
                let data = extra
                    .get(&key)
                    .ok_or_else(|| anyhow!("missing extra input {key:?}"))?;
                lits.push(lit_f32(data, &inp.shape)?);
            }
            other => anyhow::bail!("unknown input kind '{other}'"),
        }
    }
    Ok((lits, acc))
}

/// Modeled time to move `bytes` of gathered features host→device over
/// PCIe in one batched transfer (the Copy stage of Fig. 3).
pub fn h2d_time(sess: &Session, bytes: u64) -> f64 {
    sess.cfg.cost.xfer_time(Lane::Pcie, bytes)
}

/// Modeled feature-fetch time of one vanilla-engine input build: local
/// rows through the cache model (or the full DRAM+PCIe miss path when
/// uncached), remote rows over the network + PCIe. Single source of
/// truth for both runtimes — the sequential-vs-cluster A/B timing is
/// only meaningful if they price fetches identically.
pub fn vanilla_fetch_time(
    cost: &crate::comm::CostModel,
    acc: &GatherAccounting,
    cached: bool,
    parts: usize,
) -> f64 {
    let mut fetch_t = acc.cache_time_s;
    if !cached {
        // No cache: every local row pays the batched DRAM→staging→PCIe
        // path. With a dedup frontier, `acc.stats` holds unique rows
        // only, so staging prices each distinct row exactly once.
        let local_bytes = acc.stats.bytes - acc.stats.remote_bytes;
        fetch_t += cost.staging_time(local_bytes, acc.stats.rows - acc.stats.remote_rows);
    }
    fetch_t
        + cost.xfer_time_msgs(Lane::Net, acc.stats.remote_bytes, (parts - 1).max(1) as u64)
        + cost.xfer_time(Lane::Pcie, acc.stats.remote_bytes)
}

/// Per-type row counts of one batch's sparse learnable-feature update.
#[derive(Debug, Clone, Copy)]
pub struct LearnableRows {
    /// Feature dimension of the type, threaded from [`FeatureStore`]
    /// (replaces the seed's flat `DIM_GUESS = 64` approximation).
    pub dim: usize,
    /// Valid (non-pad) gradient rows of the type this batch.
    pub rows: u64,
    /// The subset owned by other machines (vanilla edge-cut).
    pub remote_rows: u64,
}

/// Convert per-type `(valid rows, remote rows)` counts into the sorted
/// [`LearnableRows`] list [`vanilla_learnable_update_cost`] expects.
/// Single source of truth for both vanilla runtimes: sorted by type so
/// the float summation order is deterministic, real dims from the store.
pub fn learnable_rows_sorted(
    counts: HashMap<usize, (u64, u64)>,
    store: &FeatureStore,
) -> Vec<LearnableRows> {
    let mut by_ty: Vec<(usize, u64, u64)> = counts
        .into_iter()
        .map(|(ty, (rows, remote))| (ty, rows, remote))
        .collect();
    by_ty.sort_unstable_by_key(|e| e.0);
    by_ty
        .into_iter()
        .map(|(ty, rows, remote_rows)| LearnableRows {
            dim: store.dim(ty),
            rows,
            remote_rows,
        })
        .collect()
}

/// Modeled cost of the vanilla engine's sparse learnable-feature
/// update: per-row random DRAM read-modify-write of weight + moments at
/// each type's **real** dimension, plus one network round trip covering
/// all remote rows. Returns the modeled seconds and the remote bytes to
/// charge to the network ledger. Callers pass `rows` sorted by type
/// ([`learnable_rows_sorted`]) so the float summation order is
/// deterministic across runtimes.
pub fn vanilla_learnable_update_cost(
    cost: &crate::comm::CostModel,
    rows: &[LearnableRows],
    parts: usize,
) -> (f64, u64) {
    let mut t = 0.0f64;
    let mut remote_bytes = 0u64;
    for r in rows {
        let row_bytes = r.dim as u64 * 4;
        t += cost.xfer_time_msgs(Lane::Dram, r.rows * row_bytes * 3, r.rows * 2);
        remote_bytes += r.remote_rows * row_bytes;
    }
    if remote_bytes > 0 {
        t += cost.xfer_time_msgs(Lane::Net, remote_bytes, (parts - 1).max(1) as u64);
    }
    (t, remote_bytes)
}

/// Sum two equal-length f32 vectors in place.
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Scale a vector in place.
pub fn scale(a: &mut [f32], s: f32) {
    for x in a.iter_mut() {
        *x *= s;
    }
}

/// `FeatureStore`-backed learnable-row update: accumulate row grads and
/// apply sparse Adam. Returns rows updated.
pub fn apply_learnable_grads(
    sess: &mut Session,
    ty: usize,
    ids: &[NodeId],
    grads: &[f32],
    lr_scale: f32,
) -> usize {
    let dim = sess.store.dim(ty);
    let mut rows = crate::optim::accumulate_rows(ids, grads, dim, PAD);
    if lr_scale != 1.0 {
        for (_, g) in &mut rows {
            scale(g, lr_scale);
        }
    }
    let hp = AdamParams {
        lr: sess.cfg.train.lr as f32,
        ..Default::default()
    };
    let t = sess.adam_t;
    if let Some((w, m, v)) = sess.store.learnable_mut(ty) {
        crate::optim::sparse_adam_step(&rows, w, m, v, dim, t, hp)
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_and_scale() {
        let mut a = vec![1.0, 2.0];
        add_assign(&mut a, &[0.5, 0.5]);
        assert_eq!(a, vec![1.5, 2.5]);
        scale(&mut a, 2.0);
        assert_eq!(a, vec![3.0, 5.0]);
    }

    #[test]
    fn learnable_update_cost_threads_real_dims() {
        let cost = CostModel::default();
        let small = vanilla_learnable_update_cost(
            &cost,
            &[LearnableRows { dim: 8, rows: 10, remote_rows: 2 }],
            2,
        );
        let big = vanilla_learnable_update_cost(
            &cost,
            &[LearnableRows { dim: 512, rows: 10, remote_rows: 2 }],
            2,
        );
        assert!(big.0 > small.0, "bigger rows must cost more DRAM time");
        assert_eq!(small.1, 2 * 8 * 4);
        assert_eq!(big.1, 2 * 512 * 4);
        assert_eq!(vanilla_learnable_update_cost(&cost, &[], 2), (0.0, 0));
        // Two types accumulate both time and remote bytes.
        let both = vanilla_learnable_update_cost(
            &cost,
            &[
                LearnableRows { dim: 8, rows: 10, remote_rows: 2 },
                LearnableRows { dim: 512, rows: 10, remote_rows: 2 },
            ],
            2,
        );
        assert!(both.0 > big.0);
        assert_eq!(both.1, small.1 + big.1);
    }

    #[test]
    fn arena_begin_batch_invalidates_staging_keeps_capacity() {
        let mut a = BatchArena::new();
        a.begin_batch(3);
        a.staging[1].resize(128, 1.0);
        a.staged[1] = true;
        let cap = a.staging[1].capacity();
        a.begin_batch(3);
        assert!(a.staged.iter().all(|&s| !s), "staging must be invalidated");
        assert!(a.staging[1].capacity() >= cap, "buffers must be recycled");
    }
}
