//! Feature KV store (the host-memory "KVStore" of the paper's Fig. 3):
//! per-node-type feature tables with explicit locality accounting.
//!
//! Raw (read-only) features are *lazy* — synthesized on access from a
//! hash (`datagen::feature_value`), so multi-GB tables never materialize.
//! Learnable features are *dense* tables with Adam state (they are model
//! parameters: random-initialized, updated every step — the update path
//! whose DRAM cost the paper measures at 24–35% of epoch time, Fig. 4).
//!
//! `gather` fills the padded block buffers consumed by the PJRT
//! executables, returning per-call fetch statistics (local vs remote
//! rows) that the engines charge to the communication cost model.

use anyhow::{bail, ensure, Result};

use crate::datagen::feature_value;
use crate::hetgraph::{HetGraph, NodeId};
use crate::sampling::PAD;
use crate::util::rng::Rng;

/// One node type's storage.
pub enum Table {
    /// Read-only features, synthesized lazily (seeded).
    Lazy { seed: u64 },
    /// Learnable embeddings + Adam moments (updated during training).
    Learnable {
        weight: Vec<f32>,
        adam_m: Vec<f32>,
        adam_v: Vec<f32>,
    },
}

/// Feature store over all node types of a graph.
pub struct FeatureStore {
    pub dims: Vec<usize>,
    pub counts: Vec<usize>,
    pub tables: Vec<Table>,
    /// Labels of target nodes (for feature synthesis correlation).
    labels: Vec<u16>,
    target_ty: usize,
}

/// Statistics of one gather call.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FetchStats {
    pub rows: u64,
    pub bytes: u64,
    pub remote_rows: u64,
    pub remote_bytes: u64,
}

impl FetchStats {
    pub fn merge(&mut self, o: FetchStats) {
        self.rows += o.rows;
        self.bytes += o.bytes;
        self.remote_rows += o.remote_rows;
        self.remote_bytes += o.remote_bytes;
    }
}

impl FeatureStore {
    /// Build the store for a graph. Learnable tables are initialized
    /// `N(0, 0.1)`; raw features are lazy.
    pub fn new(g: &HetGraph, seed: u64) -> FeatureStore {
        let mut rng = Rng::new(seed ^ 0xFEA7);
        let tables = g
            .schema
            .node_types
            .iter()
            .enumerate()
            .map(|(ty, t)| {
                if t.learnable {
                    let n = t.count * t.feat_dim;
                    let mut r = rng.fork(ty as u64);
                    Table::Learnable {
                        weight: (0..n).map(|_| (r.gaussian() * 0.1) as f32).collect(),
                        adam_m: vec![0.0; n],
                        adam_v: vec![0.0; n],
                    }
                } else {
                    Table::Lazy {
                        seed: seed ^ (ty as u64) << 8,
                    }
                }
            })
            .collect();
        FeatureStore {
            dims: g.schema.node_types.iter().map(|t| t.feat_dim).collect(),
            counts: g.schema.node_types.iter().map(|t| t.count).collect(),
            tables,
            labels: g.labels.clone(),
            target_ty: g.schema.target,
        }
    }

    pub fn dim(&self, ty: usize) -> usize {
        self.dims[ty]
    }

    pub fn is_learnable(&self, ty: usize) -> bool {
        matches!(self.tables[ty], Table::Learnable { .. })
    }

    /// Copy the feature row of `(ty, id)` into `out` (len = dim).
    /// Errors on an out-of-range type/id or a mis-sized buffer so a
    /// bad fetch from a worker thread surfaces as `anyhow::Error`
    /// instead of a panic that poisons shared-session mutexes.
    pub fn read_row(&self, ty: usize, id: NodeId, out: &mut [f32]) -> Result<()> {
        ensure!(ty < self.tables.len(), "read_row: type {ty} out of range");
        ensure!(
            (id as usize) < self.counts[ty],
            "read_row: id {id} out of range for type {ty} ({} rows)",
            self.counts[ty]
        );
        let d = self.dims[ty];
        ensure!(
            out.len() == d,
            "read_row: buffer {} != dim {d} for type {ty}",
            out.len()
        );
        match &self.tables[ty] {
            Table::Lazy { seed } => {
                let hint = if ty == self.target_ty {
                    self.labels[id as usize]
                } else {
                    (id % 16) as u16
                };
                for (c, o) in out.iter_mut().enumerate() {
                    *o = feature_value(*seed, ty, id, c, hint);
                }
            }
            Table::Learnable { weight, .. } => {
                let base = id as usize * d;
                out.copy_from_slice(&weight[base..base + d]);
            }
        }
        Ok(())
    }

    /// Gather (possibly padded) `ids` into a dense `[len(ids), dim]`
    /// buffer; padded slots are zero-filled. `is_remote(id)` classifies
    /// rows for locality accounting (vanilla engine: rows owned by other
    /// machines must cross the network).
    pub fn gather(
        &self,
        ty: usize,
        ids: &[NodeId],
        out: &mut [f32],
        is_remote: impl Fn(NodeId) -> bool,
    ) -> Result<FetchStats> {
        ensure!(ty < self.tables.len(), "gather: type {ty} out of range");
        let d = self.dims[ty];
        ensure!(
            out.len() == ids.len() * d,
            "gather: buffer {} != {} rows x dim {d} for type {ty}",
            out.len(),
            ids.len()
        );
        let mut stats = FetchStats::default();
        for (i, &id) in ids.iter().enumerate() {
            let dstrow = &mut out[i * d..(i + 1) * d];
            if id == PAD {
                dstrow.fill(0.0);
                continue;
            }
            self.read_row(ty, id, dstrow)?;
            stats.rows += 1;
            stats.bytes += (d * 4) as u64;
            if is_remote(id) {
                stats.remote_rows += 1;
                stats.remote_bytes += (d * 4) as u64;
            }
        }
        Ok(stats)
    }

    /// Gather the **distinct** rows of a batch frontier into a dense
    /// `[len(ids), dim]` staging buffer — each row read exactly once, so
    /// the returned [`FetchStats`] (and the remote/locality accounting
    /// derived from it) price unique rows only. `ids` must be sorted
    /// distinct non-[`PAD`] ids, as produced by
    /// [`Frontier::unique`](crate::sampling::Frontier); padded block
    /// literals are then reconstructed by [`scatter_rows`].
    pub fn gather_unique(
        &self,
        ty: usize,
        ids: &[NodeId],
        out: &mut [f32],
        is_remote: impl Fn(NodeId) -> bool,
    ) -> Result<FetchStats> {
        debug_assert!(
            ids.windows(2).all(|w| w[0] < w[1]) && !ids.contains(&PAD),
            "gather_unique expects sorted distinct non-PAD ids"
        );
        self.gather(ty, ids, out, is_remote)
    }

    /// Mutable access to a learnable table (sparse Adam update path).
    pub fn learnable_mut(
        &mut self,
        ty: usize,
    ) -> Option<(&mut Vec<f32>, &mut Vec<f32>, &mut Vec<f32>)> {
        match &mut self.tables[ty] {
            Table::Learnable {
                weight,
                adam_m,
                adam_v,
            } => Some((weight, adam_m, adam_v)),
            _ => None,
        }
    }

    /// Bytes held by learnable tables incl. optimizer state (cache §6
    /// sizing and Fig. 4's update-cost accounting).
    pub fn learnable_bytes(&self, ty: usize) -> u64 {
        match &self.tables[ty] {
            Table::Learnable { weight, .. } => (weight.len() * 4 * 3) as u64,
            _ => 0,
        }
    }

    /// Overwrite one learnable row's weights (the [`StoreDelta`]
    /// replication path — Adam moments stay local to the updating
    /// process, since marshals only ever read weights). Errors on a
    /// read-only type or an out-of-range id.
    pub fn write_row(&mut self, ty: usize, id: NodeId, vals: &[f32]) -> Result<()> {
        ensure!(ty < self.tables.len(), "write_row: type {ty} out of range");
        ensure!(
            (id as usize) < self.counts[ty],
            "write_row: id {id} out of range for type {ty} ({} rows)",
            self.counts[ty]
        );
        let d = self.dims[ty];
        ensure!(
            vals.len() == d,
            "write_row: {} values != dim {d} for type {ty}",
            vals.len()
        );
        match &mut self.tables[ty] {
            Table::Learnable { weight, .. } => {
                let base = id as usize * d;
                weight[base..base + d].copy_from_slice(vals);
                Ok(())
            }
            Table::Lazy { .. } => {
                bail!("write_row: type {ty} is read-only (lazy features are never updated)")
            }
        }
    }

    /// Export every learnable table's full resumable state — weights
    /// **and** Adam moments, `(type, weight, m, v)` sorted by type.
    /// Checkpoints carry the moments because a resumed sparse-Adam step
    /// must reproduce the fault-free trajectory bit-for-bit; the
    /// [`StoreDelta`] replication path deliberately does not (worker
    /// marshals only ever read weights).
    pub fn export_learnable(&self) -> Vec<LearnableState> {
        self.tables
            .iter()
            .enumerate()
            .filter_map(|(ty, t)| match t {
                Table::Learnable { weight, adam_m, adam_v } => Some(LearnableState {
                    ty,
                    weight: weight.clone(),
                    m: adam_m.clone(),
                    v: adam_v.clone(),
                }),
                Table::Lazy { .. } => None,
            })
            .collect()
    }

    /// Replace the learnable tables with a previously exported state
    /// (checkpoint restore). Every learnable type of this store must be
    /// present with exactly its `count x dim` elements; lazy types are
    /// seed-derived and never checkpointed. Errors name the offending
    /// type — a mismatch means the checkpoint came from a different
    /// graph/config than this session's.
    pub fn restore_learnable(&mut self, state: &[LearnableState]) -> Result<()> {
        for st in state {
            let ty = st.ty;
            ensure!(
                ty < self.tables.len(),
                "checkpointed learnable type {ty} out of range ({} types)",
                self.tables.len()
            );
            let n = self.counts[ty] * self.dims[ty];
            ensure!(
                st.weight.len() == n && st.m.len() == n && st.v.len() == n,
                "checkpointed learnable type {ty}: {} weights / {} m / {} v, \
                 but this graph holds {n} elements ({} rows x dim {})",
                st.weight.len(),
                st.m.len(),
                st.v.len(),
                self.counts[ty],
                self.dims[ty]
            );
            match &mut self.tables[ty] {
                Table::Learnable { weight, adam_m, adam_v } => {
                    weight.copy_from_slice(&st.weight);
                    adam_m.copy_from_slice(&st.m);
                    adam_v.copy_from_slice(&st.v);
                }
                Table::Lazy { .. } => {
                    bail!("checkpointed learnable type {ty} is lazy (read-only) in this config")
                }
            }
        }
        let restored: std::collections::BTreeSet<usize> = state.iter().map(|s| s.ty).collect();
        for ty in 0..self.tables.len() {
            ensure!(
                !self.is_learnable(ty) || restored.contains(&ty),
                "learnable type {ty} missing from the checkpoint"
            );
        }
        Ok(())
    }
}

/// One learnable table's full resumable state (weights + Adam moments),
/// exported at epoch boundaries into checkpoints (see [`crate::ckpt`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LearnableState {
    pub ty: usize,
    pub weight: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

/// The learnable rows one update stage changed, with their
/// **post-update** weight values: what the TCP leader broadcasts so
/// every worker process's KV store replays its writes exactly. One
/// shared store makes this a no-op (the in-process runtimes never
/// construct one); across processes the per-lane FIFO of the transport
/// delivers each delta before any batch released after the update it
/// came from, which is what keeps marshals byte-identical to the
/// shared-store schedule.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StoreDelta {
    /// `(type, sorted distinct ids, row-major weights)` per learnable
    /// type touched, sorted by type — canonical for the wire codec.
    pub rows: Vec<(usize, Vec<NodeId>, Vec<f32>)>,
}

impl StoreDelta {
    /// Read back the post-update weights of every touched `(type, ids)`
    /// group. Non-learnable types and [`PAD`] slots are skipped,
    /// duplicate ids collapse, and groups of one type merge — the
    /// result is canonical regardless of how the update stage
    /// enumerated its writes.
    pub fn capture<'a>(
        store: &FeatureStore,
        touched: impl IntoIterator<Item = (usize, &'a [NodeId])>,
    ) -> Result<StoreDelta> {
        let mut by_ty: std::collections::BTreeMap<usize, Vec<NodeId>> =
            std::collections::BTreeMap::new();
        for (ty, ids) in touched {
            if !store.is_learnable(ty) {
                continue;
            }
            by_ty
                .entry(ty)
                .or_default()
                .extend(ids.iter().copied().filter(|&id| id != PAD));
        }
        let mut rows = Vec::with_capacity(by_ty.len());
        for (ty, mut ids) in by_ty {
            ids.sort_unstable();
            ids.dedup();
            if ids.is_empty() {
                continue;
            }
            let d = store.dim(ty);
            let mut vals = vec![0.0f32; ids.len() * d];
            for (i, &id) in ids.iter().enumerate() {
                store.read_row(ty, id, &mut vals[i * d..(i + 1) * d])?;
            }
            rows.push((ty, ids, vals));
        }
        Ok(StoreDelta { rows })
    }

    /// Replay the delta into this process's store.
    pub fn apply(&self, store: &mut FeatureStore) -> Result<()> {
        for (ty, ids, vals) in &self.rows {
            let d = store.dim(*ty);
            ensure!(
                vals.len() == ids.len() * d,
                "store delta for type {ty}: {} values != {} rows x dim {d}",
                vals.len(),
                ids.len()
            );
            for (i, &id) in ids.iter().enumerate() {
                store.write_row(*ty, id, &vals[i * d..(i + 1) * d])?;
            }
        }
        Ok(())
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Scatter staged unique rows into a padded block buffer:
/// `out[slot] = staging[inv[slot]]` with zeros for
/// [`NO_ROW`](crate::sampling::NO_ROW) (padded) slots. This is the
/// in-memory half of the staging-then-scatter gather: the staging buffer
/// was filled once per distinct id by [`FeatureStore::gather_unique`],
/// so duplicated slots cost a memcpy, not a re-fetch.
pub fn scatter_rows(staging: &[f32], inv: &[u32], dim: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), inv.len() * dim);
    for (slot, &u) in inv.iter().enumerate() {
        let dst = &mut out[slot * dim..(slot + 1) * dim];
        if u == crate::sampling::NO_ROW {
            dst.fill(0.0);
        } else {
            let base = u as usize * dim;
            dst.copy_from_slice(&staging[base..base + dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};

    fn store() -> (HetGraph, FeatureStore) {
        let g = generate(Preset::Mag, 1e-4, &GenParams::default());
        let s = FeatureStore::new(&g, 11);
        (g, s)
    }

    #[test]
    fn lazy_rows_deterministic() {
        let (_, s) = store();
        let mut a = vec![0.0; s.dim(0)];
        let mut b = vec![0.0; s.dim(0)];
        s.read_row(0, 5, &mut a).unwrap();
        s.read_row(0, 5, &mut b).unwrap();
        assert_eq!(a, b);
        s.read_row(0, 6, &mut b).unwrap();
        assert_ne!(a, b);
        assert!(s.read_row(99, 0, &mut b).is_err());
        assert!(s.read_row(0, u32::MAX - 1, &mut b).is_err());
    }

    #[test]
    fn learnable_tables_initialized() {
        let (g, s) = store();
        assert!(s.is_learnable(1));
        assert!(!s.is_learnable(0));
        let d = s.dim(1);
        let mut row = vec![0.0; d];
        s.read_row(1, 0, &mut row).unwrap();
        assert!(row.iter().any(|&x| x != 0.0));
        assert_eq!(
            s.learnable_bytes(1),
            (g.schema.node_types[1].count * d * 4 * 3) as u64
        );
    }

    #[test]
    fn write_row_updates_learnable_weights_only() {
        let (_, mut s) = store();
        let d = s.dim(1);
        let newvals = vec![0.5f32; d];
        s.write_row(1, 3, &newvals).unwrap();
        let mut back = vec![0.0; d];
        s.read_row(1, 3, &mut back).unwrap();
        assert_eq!(back, newvals);
        assert!(s.write_row(0, 0, &vec![0.0; s.dim(0)]).is_err(), "lazy is read-only");
        assert!(s.write_row(1, u32::MAX - 1, &newvals).is_err());
        assert!(s.write_row(1, 0, &[0.0]).is_err(), "dim mismatch");
    }

    #[test]
    fn store_delta_replays_updates_into_a_second_store() {
        let (g, mut a) = store();
        let mut b = FeatureStore::new(&g, 11); // same seed: identical init
        let d = a.dim(1);
        // "Update" rows 2 and 5 in store a only.
        a.write_row(1, 2, &vec![1.25; d]).unwrap();
        a.write_row(1, 5, &vec![-0.75; d]).unwrap();
        // Capture with duplicates, PAD noise, a read-only type and
        // split groups: the delta must canonicalize all of it.
        let ids1: Vec<NodeId> = vec![5, 2, 2, PAD];
        let ids2: Vec<NodeId> = vec![5];
        let ids_ro: Vec<NodeId> = vec![0];
        let delta = StoreDelta::capture(
            &a,
            [(1usize, ids1.as_slice()), (1, ids2.as_slice()), (0, ids_ro.as_slice())],
        )
        .unwrap();
        assert_eq!(delta.rows.len(), 1, "one learnable type touched");
        assert_eq!(delta.rows[0].1, vec![2, 5], "sorted distinct ids");
        assert!(!delta.is_empty());
        delta.apply(&mut b).unwrap();
        let (mut ra, mut rb) = (vec![0.0; d], vec![0.0; d]);
        for id in [2u32, 5] {
            a.read_row(1, id, &mut ra).unwrap();
            b.read_row(1, id, &mut rb).unwrap();
            assert_eq!(ra, rb, "row {id} must replicate exactly");
        }
        // Untouched rows still agree (same seeded init).
        a.read_row(1, 7, &mut ra).unwrap();
        b.read_row(1, 7, &mut rb).unwrap();
        assert_eq!(ra, rb);
        // A mis-sized delta is rejected.
        let bad = StoreDelta { rows: vec![(1, vec![2], vec![0.0; d + 1])] };
        assert!(bad.apply(&mut b).is_err());
    }

    #[test]
    fn gather_pads_and_counts() {
        let (_, s) = store();
        let d = s.dim(0);
        let ids = [1u32, PAD, 3, 7];
        let mut out = vec![1.0f32; ids.len() * d];
        let stats = s.gather(0, &ids, &mut out, |id| id == 7).unwrap();
        assert_eq!(stats.rows, 3);
        assert_eq!(stats.remote_rows, 1);
        assert_eq!(stats.bytes, (3 * d * 4) as u64);
        assert_eq!(stats.remote_bytes, (d * 4) as u64);
        assert!(out[d..2 * d].iter().all(|&x| x == 0.0), "pad row not zeroed");
        assert!(out[..d].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn gather_unique_then_scatter_matches_direct_gather() {
        let (_, s) = store();
        let d = s.dim(0);
        // Padded slot list with heavy duplication.
        let slots = [3u32, 7, PAD, 3, 9, 7, 3, PAD];
        let unique = [3u32, 7, 9];
        let inv = [0u32, 1, crate::sampling::NO_ROW, 0, 2, 1, 0, crate::sampling::NO_ROW];

        let mut direct = vec![1.0f32; slots.len() * d];
        let direct_stats = s.gather(0, &slots, &mut direct, |id| id == 9).unwrap();

        let mut staging = vec![0.0f32; unique.len() * d];
        let unique_stats = s.gather_unique(0, &unique, &mut staging, |id| id == 9).unwrap();
        let mut scattered = vec![1.0f32; slots.len() * d];
        scatter_rows(&staging, &inv, d, &mut scattered);

        assert_eq!(direct, scattered, "scatter must be byte-identical");
        assert_eq!(direct_stats.rows, 6, "direct pays every occurrence");
        assert_eq!(unique_stats.rows, 3, "unique pays each row once");
        assert_eq!(unique_stats.remote_rows, 1);
        assert_eq!(unique_stats.bytes, (3 * d * 4) as u64);
    }

    #[test]
    fn fetch_stats_merge() {
        let mut a = FetchStats { rows: 1, bytes: 4, remote_rows: 0, remote_bytes: 0 };
        a.merge(FetchStats { rows: 2, bytes: 8, remote_rows: 1, remote_bytes: 4 });
        assert_eq!(a.rows, 3);
        assert_eq!(a.remote_bytes, 4);
    }

    #[test]
    fn target_features_correlate_with_labels() {
        // Same label ⇒ same boosted coordinate pattern (cosine similarity
        // higher than across labels, on average).
        let (g, s) = store();
        let d = s.dim(0);
        let mut by_label: std::collections::HashMap<u16, Vec<Vec<f32>>> = Default::default();
        for id in 0..40u32 {
            let mut row = vec![0.0; d];
            s.read_row(0, id, &mut row).unwrap();
            by_label.entry(g.labels[id as usize] % 7).or_default().push(row);
        }
        // Not a strict statistical test — just checks the label hint is wired.
        assert!(by_label.len() > 1);
    }
}
