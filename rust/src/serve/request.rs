//! The serving request model: a deterministic, seed-derived stream of
//! (target node, arrival time, latency budget) triples, or the same
//! shape loaded from a trace file.
//!
//! Synthetic streams model the workload the north star describes —
//! heavy online traffic over a skewed popularity distribution: targets
//! are drawn Zipf(α) over a seed-shuffled ranking of the training
//! targets (so *which* nodes are hot is itself seed-derived), and
//! arrivals follow a Poisson process at the requested QPS
//! (exponential interarrivals from the same seeded RNG). Everything
//! downstream — microbatch composition, cache hits, served bytes — is
//! a pure function of `(config seed, stream knobs)`.

use anyhow::{ensure, Context, Result};

use crate::hetgraph::{HetGraph, NodeId};
use crate::util::rng::{Rng, Zipf};

/// One inference request: embed `target`, arriving at `arrival_us` on
/// the stream clock, due by `deadline_us` (absolute, not a budget).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub id: usize,
    pub target: NodeId,
    pub arrival_us: u64,
    pub deadline_us: u64,
}

impl Request {
    /// The request's latency budget (deadline − arrival).
    pub fn budget_us(&self) -> u64 {
        self.deadline_us.saturating_sub(self.arrival_us)
    }
}

/// Knobs of the synthetic stream (CLI defaults in `heta serve`).
#[derive(Debug, Clone)]
pub struct StreamOpts {
    /// Total requests to generate.
    pub requests: usize,
    /// Mean offered load (Poisson arrivals).
    pub qps: f64,
    /// Per-request latency budget.
    pub deadline_ms: f64,
    /// Popularity skew over the target pool (α of Zipf).
    pub zipf_alpha: f64,
    /// Stream seed — derive from the config seed so a config pins its
    /// serving workload the way it pins its training batches.
    pub seed: u64,
}

/// Generate the deterministic synthetic stream: Zipf-popular targets
/// from the graph's training set, Poisson arrivals at `qps`. Sorted by
/// arrival; ids are positions in that order.
pub fn synthetic_stream(g: &HetGraph, opts: &StreamOpts) -> Result<Vec<Request>> {
    ensure!(opts.requests > 0, "a serving run needs at least one request");
    ensure!(
        opts.qps > 0.0 && opts.qps.is_finite(),
        "--qps must be a positive rate, got {}",
        opts.qps
    );
    ensure!(
        opts.deadline_ms > 0.0 && opts.deadline_ms.is_finite(),
        "--deadline-ms must be a positive budget, got {}",
        opts.deadline_ms
    );
    let mut pool = g.train_nodes();
    ensure!(!pool.is_empty(), "the graph has no training targets to serve");
    let mut rng = Rng::new(opts.seed);
    // The Zipf rank→node map is a seeded shuffle: rank 0 (the hottest)
    // is an arbitrary-but-reproducible target, not always node 0.
    rng.shuffle(&mut pool);
    let zipf = Zipf::new(pool.len(), opts.zipf_alpha);
    let mean_gap_us = 1e6 / opts.qps;
    let budget_us = (opts.deadline_ms * 1e3).ceil() as u64;
    let mut arrival = 0f64;
    let mut reqs = Vec::with_capacity(opts.requests);
    for id in 0..opts.requests {
        // Exponential interarrival; clamp the log away from u = 0.
        arrival += -(1.0 - rng.f64()).max(1e-12).ln() * mean_gap_us;
        let arrival_us = arrival as u64;
        reqs.push(Request {
            id,
            target: pool[zipf.sample(&mut rng)],
            arrival_us,
            deadline_us: arrival_us + budget_us,
        });
    }
    Ok(reqs)
}

/// Load a request trace: one request per non-empty, non-`#` line, as
/// `target_id [arrival_us]` (whitespace-separated). A missing arrival
/// inherits the previous line's (burst semantics); arrivals must be
/// non-decreasing. Every request gets the same `deadline_ms` budget.
pub fn trace_stream(path: &str, deadline_ms: f64, num_targets: usize) -> Result<Vec<Request>> {
    ensure!(
        deadline_ms > 0.0 && deadline_ms.is_finite(),
        "--deadline-ms must be a positive budget, got {deadline_ms}"
    );
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading the request trace {path}"))?;
    let budget_us = (deadline_ms * 1e3).ceil() as u64;
    let mut reqs: Vec<Request> = Vec::new();
    let mut last_arrival = 0u64;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut fields = line.split_whitespace();
        let target: NodeId = fields
            .next()
            .unwrap_or_default()
            .parse()
            .with_context(|| format!("{path}:{}: expected a target node id", lineno + 1))?;
        ensure!(
            (target as usize) < num_targets,
            "{path}:{}: target {target} outside the {num_targets}-node target type",
            lineno + 1
        );
        let arrival_us = match fields.next() {
            Some(f) => f
                .parse()
                .with_context(|| format!("{path}:{}: bad arrival_us '{f}'", lineno + 1))?,
            None => last_arrival,
        };
        ensure!(
            arrival_us >= last_arrival,
            "{path}:{}: arrivals must be non-decreasing ({arrival_us} < {last_arrival})",
            lineno + 1
        );
        last_arrival = arrival_us;
        reqs.push(Request {
            id: reqs.len(),
            target,
            arrival_us,
            deadline_us: arrival_us + budget_us,
        });
    }
    ensure!(!reqs.is_empty(), "{path}: the trace names no requests");
    Ok(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};

    fn graph() -> HetGraph {
        generate(Preset::Mag, 1e-4, &GenParams::default())
    }

    fn opts(seed: u64) -> StreamOpts {
        StreamOpts { requests: 200, qps: 500.0, deadline_ms: 40.0, zipf_alpha: 1.1, seed }
    }

    #[test]
    fn synthetic_stream_is_deterministic_and_ordered() {
        let g = graph();
        let a = synthetic_stream(&g, &opts(7)).unwrap();
        let b = synthetic_stream(&g, &opts(7)).unwrap();
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        assert!(a.iter().all(|r| r.budget_us() == 40_000));
        let c = synthetic_stream(&g, &opts(8)).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn synthetic_stream_is_skewed() {
        let g = graph();
        let reqs = synthetic_stream(
            &g,
            &StreamOpts { requests: 2000, ..opts(3) },
        )
        .unwrap();
        let mut counts = std::collections::HashMap::new();
        for r in &reqs {
            *counts.entry(r.target).or_insert(0usize) += 1;
        }
        // Zipf(1.1) over hundreds of targets: the hottest target must
        // dominate the mean occupancy by a wide margin.
        let hottest = counts.values().copied().max().unwrap();
        let mean = reqs.len() / counts.len();
        assert!(hottest >= 5 * mean.max(1), "hottest {hottest} vs mean {mean}");
    }

    #[test]
    fn trace_stream_parses_and_validates() {
        let dir = std::env::temp_dir().join(format!("heta-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trace.txt");
        std::fs::write(&p, "# a burst then a straggler\n3\n5 0\n7 2500\n").unwrap();
        let reqs = trace_stream(p.to_str().unwrap(), 10.0, 100).unwrap();
        assert_eq!(reqs.len(), 3);
        assert_eq!(reqs[0].target, 3);
        assert_eq!(reqs[1].arrival_us, 0);
        assert_eq!(reqs[2].arrival_us, 2500);
        assert_eq!(reqs[2].deadline_us, 12_500);

        let bad = dir.join("bad.txt");
        std::fs::write(&bad, "5 100\n6 50\n").unwrap();
        assert!(trace_stream(bad.to_str().unwrap(), 10.0, 100).is_err());
        std::fs::write(&bad, "999\n").unwrap();
        assert!(trace_stream(bad.to_str().unwrap(), 10.0, 100).is_err());
    }
}
