//! The serving mode (`heta serve`): deadline-driven microbatched
//! inference over the existing exec layer — forward-only, no backward,
//! no updates.
//!
//! The pieces, each its own submodule:
//!
//! * [`request`] — the deterministic request stream: Zipf-popular
//!   targets, Poisson arrivals, per-request latency budgets (or the
//!   same shape loaded from a trace file).
//! * [`batcher`] — the deadline-driven microbatcher: a batch closes
//!   when the *oldest* pending request's budget would otherwise be
//!   breached, not at a fixed size. Waiting is simulated on the stream
//!   clock; compute is real and its measured service time feeds back
//!   into the close rule.
//! * [`cache`] — the embedding-reuse cache, keyed on (target, param
//!   version, store generation) and flushed whole on any stamp change,
//!   so a served embedding is always byte-identical to a fresh forward.
//!
//! This module owns the engine that ties them to the exec layer.
//! Serving reuses the *training* worker-forward decomposition
//! ([`BatchPlan::forward_only`] — `worker_fwd_p{p}` per partition,
//! summed partials, no leader/backward artifacts) on **both** engines:
//! the vanilla fused train-step artifact has no per-target embedding
//! output, so the baseline serves through the same decomposition and
//! the engine choice controls only the feature-cache policy. A serving
//! "embedding" is the pair of layer partial sums the RAF fold produces
//! for a target's row.
//!
//! **Splice sampling.** Training samples key their RNG on the global
//! slot index, so a target's neighborhood depends on its batch
//! position — useless for caching. Serving samples each target as its
//! own single-target tree (`sample_tree(&[t], 0, serve_seed, ..)`) and
//! splices the per-target blocks into one padded batch tree: block `i`
//! of every metatree vertex is target `i`'s block, because vertex
//! sizes are linear in the batch (`sizes_b[v] = b · sizes_1[v]`) and
//! child slots of a parent block land in the child's same block. A
//! target's embedding is then a pure function of `(target, serve_seed,
//! params, store)` — cacheable bit-for-bit, independent of microbatch
//! composition. `tests/test_serve.rs` pins both properties.
//!
//! Over TCP the protocol is two messages: the leader broadcasts the
//! deduplicated padded chunk, workers return their partial sums, and
//! the leader composes responses — per batch per worker the wire
//! carries `2·[B,H]` floats up and the target ids down, independent of
//! fan-out, exactly the training forward's Θ(|targets|) guarantee.

pub mod batcher;
pub mod cache;
pub mod request;

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::cache::{FeatureCache, Policy, ServeLedger, TypeProfile};
use crate::cluster::collective::{Hub, Port, RoundTag};
use crate::cluster::mailbox::{slice_bytes, Wire};
use crate::config::{partition_edge_filter, Config, RuntimeKind};
use crate::coordinator::{Session, SystemKind};
use crate::exec::{BatchArena, BatchPlan, EpochWorld, ExecContext, ExecGate, ParamsView};
use crate::hetgraph::{HetGraph, MetaTree, NodeId};
use crate::kvstore::FetchStats;
use crate::net::codec::{ByteReader, ByteWriter, WireCodec};
use crate::net::{Backend, Role, WireTraffic};
use crate::partition::meta::meta_partition;
use crate::partition::MetaPartition;
use crate::sampling::{presample_hotness, sample_tree, vertex_sizes, Frontier, TreeSample, PAD};
use crate::util::add_assign;
use crate::util::stats::Samples;

pub use batcher::{BatcherOpts, TimelineReport};
pub use cache::{Embed, EmbedCache, Stamp};
pub use request::{synthetic_stream, trace_stream, Request, StreamOpts};

/// Serving knobs (CLI flags of `heta serve`).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Synthetic-stream length (ignored with a trace).
    pub requests: usize,
    /// Synthetic offered load (Poisson arrivals).
    pub qps: f64,
    /// Per-request latency budget.
    pub deadline_ms: f64,
    /// Synthetic popularity skew.
    pub zipf_alpha: f64,
    /// Request trace file (`target_id [arrival_us]` per line) instead
    /// of the synthetic stream.
    pub trace_path: Option<String>,
    /// Embedding-reuse cache on (`--no-reuse` clears it — the A/B
    /// baseline arm).
    pub reuse: bool,
    /// Cross-request frontier fetch dedup on (`--no-dedup-fetch`).
    pub dedup_fetch: bool,
    /// Embedding-cache capacity (entries).
    pub embed_cap: usize,
    /// Initial batcher service-time estimate; `0` derives `deadline/2`.
    pub service_bound_ms: f64,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            requests: 256,
            qps: 200.0,
            deadline_ms: 50.0,
            zipf_alpha: 1.1,
            trace_path: None,
            reuse: true,
            dedup_fetch: true,
            embed_cap: 4096,
            service_bound_ms: 0.0,
        }
    }
}

/// The serving seed: fixed per config, decoupled from the training
/// batch seeds so a target's served neighborhood never depends on
/// epoch or batch index.
pub fn serve_seed(cfg: &Config) -> u64 {
    cfg.train.seed ^ 0x5345_5256 // "SERV"
}

/// Outcome of one serving run (the leader's view; TCP worker ranks
/// return an empty report carrying only their wire counters).
#[derive(Debug, Default)]
pub struct ServeReport {
    pub served: usize,
    pub batches: usize,
    pub deadline_misses: usize,
    pub max_batch: usize,
    /// Per-request latency (stream-clock arrival → completion).
    pub latencies_ms: Samples,
    pub ledger: ServeLedger,
    /// Served embeddings in request order — the byte-identity evidence
    /// the tests and the bench A/B compare.
    pub embeds: Vec<Embed>,
    pub qps: f64,
    /// Real socket traffic (zero for the channel backend).
    pub wire: WireTraffic,
}

impl ServeReport {
    pub fn p50_ms(&self) -> f64 {
        self.latencies_ms.p50()
    }

    pub fn p99_ms(&self) -> f64 {
        self.latencies_ms.p99()
    }

    /// Human-readable summary. The `key=value` tokens (`p50_ms=`,
    /// `p99_ms=`, `qps=`, `deadline_misses=`) are what CI's serve-smoke
    /// step parses — keep them stable.
    pub fn print(&self, label: &str) {
        println!("== serve: {label} ==");
        println!(
            "  requests={} batches={} max_batch={} deadline_misses={}",
            self.served, self.batches, self.max_batch, self.deadline_misses
        );
        println!(
            "  p50_ms={:.3} p99_ms={:.3} qps={:.1}",
            self.p50_ms(),
            self.p99_ms(),
            self.qps
        );
        println!(
            "  embed: hits={} misses={} invalidations={} hit_rate={:.3}",
            self.ledger.embed_hits,
            self.ledger.embed_misses,
            self.ledger.embed_invalidations,
            self.ledger.hit_rate()
        );
        println!(
            "  fetch: rows={} bytes={} rows_per_request={:.2} batch_dups={} computed={}",
            self.ledger.fetched_rows,
            self.ledger.fetched_bytes,
            self.ledger.rows_per_request(),
            self.ledger.batch_dups,
            self.ledger.computed_targets
        );
        println!(
            "  wire: sent={} recv={}",
            crate::util::fmt_bytes(self.wire.real_sent),
            crate::util::fmt_bytes(self.wire.real_recv)
        );
    }
}

/// The serving engine: the forward-only slice of the training engines'
/// state (per-partition contexts, frontiers, arenas) plus the serving
/// additions (embedding cache, store generation). Both [`SystemKind`]
/// engines serve through the same meta-partitioned worker-forward
/// decomposition; the engine choice selects only the feature-cache
/// policy (Heta: the config's policy; the vanilla label: none).
pub struct ServeEngine {
    pub mp: MetaPartition,
    plan: BatchPlan,
    contexts: Vec<ExecContext>,
    frontiers: Vec<Frontier>,
    arenas: Vec<BatchArena>,
    /// The embedding-reuse cache; counters are cumulative across runs
    /// (each run's report ledgers the deltas).
    pub embed: EmbedCache,
    serve_seed: u64,
    /// Feature-store generation: bumped by [`note_store_update`]
    /// whenever a learnable-feature update lands, invalidating the
    /// embedding cache through the stamp.
    ///
    /// [`note_store_update`]: ServeEngine::note_store_update
    store_gen: u64,
    dedup_fetch: bool,
    gate: Option<ExecGate>,
}

impl ServeEngine {
    pub fn new(sess: &mut Session, system: SystemKind, opts: &ServeOpts) -> Result<ServeEngine> {
        let cfg = &sess.cfg;
        let policy = match system {
            SystemKind::Heta => cfg.train.cache_policy,
            _ => Policy::None,
        };
        let (mp, _) = meta_partition(&sess.g, cfg.train.num_partitions, cfg.model.layers, None);
        // Same cache construction as training (presampled hotness,
        // per-partition budget over the types the partition holds), so
        // serve-time feature hit rates are comparable to Fig. 12's.
        let hotness = presample_hotness(
            &sess.g,
            &sess.tree,
            &cfg.model.fanouts,
            cfg.train.batch_size,
            2,
            cfg.train.seed ^ 0x807,
        );
        let gpus = cfg.train.gpus_per_machine.max(1);
        // Role-gated construction, exactly like training: a TCP process
        // plays one rank and only that rank's context gets an eager
        // PJRT client (the leader composes, it never executes worker
        // artifacts). Channel serving plays every rank in-process.
        let role = match &sess.net {
            Backend::Tcp(node) => Some(node.role()),
            Backend::Channel => None,
        };
        let mut contexts = Vec::with_capacity(mp.num_parts);
        for part in 0..mp.num_parts {
            let present = mp.types_in_part(&sess.g, part);
            let profiles: Vec<TypeProfile> = sess
                .g
                .schema
                .node_types
                .iter()
                .map(|t| TypeProfile {
                    name: t.name.clone(),
                    count: t.count,
                    feat_dim: t.feat_dim,
                    learnable: t.learnable,
                })
                .collect();
            let hot: Vec<Vec<u32>> = hotness
                .iter()
                .enumerate()
                .map(|(ty, h)| {
                    if present.contains(&ty) {
                        h.clone()
                    } else {
                        vec![0; h.len()]
                    }
                })
                .collect();
            let cache = FeatureCache::build(
                policy,
                &profiles,
                &hot,
                &cfg.cost,
                cfg.train.cache_bytes_per_gpu * cfg.train.gpus_per_machine as u64,
                cfg.train.gpus_per_machine,
            );
            let eager = match role {
                None => true,
                Some(Role::Worker(w)) => w == part,
                Some(Role::Leader) => false,
            };
            contexts.push(if eager {
                ExecContext::new(
                    part,
                    part % gpus,
                    &sess.artifacts_dir,
                    Arc::clone(&sess.manifest),
                    Some(cache),
                )?
            } else {
                ExecContext::deferred(
                    part,
                    part % gpus,
                    &sess.artifacts_dir,
                    Arc::clone(&sess.manifest),
                    Some(cache),
                )
            });
        }
        let plan = BatchPlan::forward_only(&sess.manifest, mp.num_parts)?;
        let art_names: Vec<String> = plan.workers.iter().map(|w| w.fwd_art.clone()).collect();
        sess.params
            .ensure_artifacts(&sess.manifest, art_names.iter().map(|s| s.as_str()));
        let frontiers = vec![Frontier::default(); mp.num_parts];
        let arenas = (0..mp.num_parts).map(|_| BatchArena::new()).collect();
        let gate = cfg.train.shared_session.then(ExecGate::new);
        let cap = if opts.reuse { opts.embed_cap.max(1) } else { 0 };
        Ok(ServeEngine {
            mp,
            plan,
            contexts,
            frontiers,
            arenas,
            embed: EmbedCache::new(cap),
            serve_seed: serve_seed(cfg),
            store_gen: 0,
            dedup_fetch: opts.dedup_fetch,
            gate,
        })
    }

    /// A learnable-feature update landed in the KV store (a training
    /// step's `StoreDelta`, a replication frame): bump the store
    /// generation so the embedding cache's stamp invalidates.
    pub fn note_store_update(&mut self) {
        self.store_gen += 1;
    }

    /// Serve the request stream in-process (the channel backend; every
    /// partition's forward runs on this thread in partition order, so
    /// the fold matches the TCP gather's worker-id order exactly).
    pub fn run_channel(
        &mut self,
        sess: &Session,
        reqs: &[Request],
        opts: &ServeOpts,
    ) -> Result<ServeReport> {
        let cfg = sess.cfg.clone();
        let b = cfg.train.batch_size;
        let h = cfg.model.hidden;
        let ServeEngine {
            mp,
            plan,
            contexts,
            frontiers,
            arenas,
            embed,
            serve_seed,
            store_gen,
            dedup_fetch,
            gate,
        } = self;
        let parts = mp.num_parts;
        let world = EpochWorld {
            cfg: &cfg,
            g: &sess.g,
            tree: &sess.tree,
            store: &sess.store,
            gate: gate.as_ref(),
            epoch_t0: Instant::now(),
        };
        let (hits0, miss0, inv0) = (embed.hits, embed.misses, embed.invalidations);
        let mut ledger = ServeLedger::default();
        let mut embeds_out: Vec<Embed> = Vec::with_capacity(reqs.len());
        let bopts = BatcherOpts { capacity: b, service_bound_us: service_bound_us(opts) };
        let timeline = batcher::run(reqs, &bopts, |batch| {
            let t0 = Instant::now();
            let targets: Vec<NodeId> = batch.iter().map(|r| r.target).collect();
            let stamp = (sess.params.version(), *store_gen);
            let served = serve_batch_with(embed, stamp, b, h, &targets, |chunk| {
                let mut partials = [vec![0f32; b * h], vec![0f32; b * h]];
                let mut fetch = FetchStats::default();
                for p in 0..parts {
                    let (p1, p2, stats) = worker_forward(
                        plan,
                        mp,
                        &mut contexts[p],
                        &mut frontiers[p],
                        &mut arenas[p],
                        &world,
                        ParamsView::Owner(&sess.params),
                        *serve_seed,
                        *dedup_fetch,
                        p,
                        chunk,
                    )?;
                    ensure!(
                        p1.len() == b * h && p2.len() == b * h,
                        "partition {p}: partial shape ({}, {}) != {}",
                        p1.len(),
                        p2.len(),
                        b * h
                    );
                    add_assign(&mut partials[0], &p1);
                    add_assign(&mut partials[1], &p2);
                    fetch.merge(stats);
                }
                Ok((partials, fetch))
            })?;
            absorb_batch(&mut ledger, batch.len(), &served);
            embeds_out.extend(served.embeds);
            Ok(t0.elapsed().as_micros().max(1) as u64)
        })?;
        ledger.embed_hits = embed.hits - hits0;
        ledger.embed_misses = embed.misses - miss0;
        ledger.embed_invalidations = embed.invalidations - inv0;
        Ok(finish(timeline, ledger, embeds_out, WireTraffic::default()))
    }
}

/// Fold one served batch into the run ledger.
fn absorb_batch(ledger: &mut ServeLedger, requests: usize, served: &BatchServed) {
    ledger.requests += requests as u64;
    ledger.batches += 1;
    ledger.computed_targets += served.computed as u64;
    ledger.batch_dups += served.dups as u64;
    ledger.fetched_rows += served.stats.rows;
    ledger.fetched_bytes += served.stats.bytes;
}

fn service_bound_us(opts: &ServeOpts) -> u64 {
    let ms = if opts.service_bound_ms > 0.0 {
        opts.service_bound_ms
    } else {
        opts.deadline_ms / 2.0
    };
    (ms * 1e3).max(1.0) as u64
}

/// Per-target splice sampling (module docs): sample each non-[`PAD`]
/// target as its own single-target tree under `seed` and splice block
/// `i` of every vertex from target `i`'s blocks. Padded targets leave
/// their blocks all-[`PAD`] — exactly what a padded root slot produces.
fn splice_sample(
    g: &HetGraph,
    tree: &MetaTree,
    fanouts: &[usize],
    chunk: &[NodeId],
    seed: u64,
    filter: &impl Fn(usize) -> bool,
) -> TreeSample {
    let b = chunk.len();
    let sizes_b = vertex_sizes(tree, fanouts, b);
    let sizes_1 = vertex_sizes(tree, fanouts, 1);
    let mut ids: Vec<Vec<NodeId>> = sizes_b.iter().map(|&s| vec![PAD; s]).collect();
    for (i, &t) in chunk.iter().enumerate() {
        if t == PAD {
            continue;
        }
        let one = sample_tree(g, tree, fanouts, &[t], 0, seed, filter);
        for (v, block) in one.ids.iter().enumerate() {
            let m = sizes_1[v];
            ids[v][i * m..(i + 1) * m].copy_from_slice(block);
        }
    }
    TreeSample { ids, fanouts: fanouts.to_vec() }
}

/// One partition's forward over the deduplicated padded chunk: splice
/// sample, optional frontier dedup, `worker_fwd_p{p}` — the training
/// forward stage minus backward bookkeeping.
#[allow(clippy::too_many_arguments)]
fn worker_forward(
    plan: &BatchPlan,
    mp: &MetaPartition,
    ctx: &mut ExecContext,
    frontier: &mut Frontier,
    arena: &mut BatchArena,
    world: &EpochWorld<'_>,
    params: ParamsView<'_>,
    seed: u64,
    dedup: bool,
    p: usize,
    chunk: &[NodeId],
) -> Result<(Vec<f32>, Vec<f32>, FetchStats)> {
    let wp = &plan.workers[p];
    let filter = partition_edge_filter(world.tree, mp, p);
    let sample = splice_sample(world.g, world.tree, &world.cfg.model.fanouts, chunk, seed, &filter);
    if dedup {
        let ntypes = world.g.schema.node_types.len();
        frontier.rebuild(world.tree, &sample, ntypes, wp.needs_root);
    }
    let fr = dedup.then_some(&*frontier);
    // sample_s = 0: serving charges real wall time through the batcher,
    // not the modeled stage clock.
    let fwd = wp.raf_forward(ctx, world, params, &sample, fr, chunk, 0.0, arena)?;
    Ok((fwd.p1, fwd.p2, fwd.stats))
}

/// What serving one microbatch produced.
pub struct BatchServed {
    /// One embedding per request, in the batch's request order.
    pub embeds: Vec<Embed>,
    /// KV fetch accounting of the compute call (zero on an all-hit batch).
    pub stats: FetchStats,
    /// Targets that actually went through the forward plan.
    pub computed: usize,
    /// Requests deduplicated away inside this batch.
    pub dups: usize,
}

/// Serve one microbatch through the embedding cache: dedup targets
/// within the batch, look up survivors under `stamp`, run `compute`
/// once over the padded chunk of misses (skipped entirely on an
/// all-hit batch), insert fresh embeddings, and compose one response
/// per request. `compute` returns the summed `[2][capacity·h]`
/// partials plus fetch accounting.
fn serve_batch_with(
    embed: &mut EmbedCache,
    stamp: Stamp,
    capacity: usize,
    h: usize,
    targets: &[NodeId],
    compute: impl FnOnce(&[NodeId]) -> Result<([Vec<f32>; 2], FetchStats)>,
) -> Result<BatchServed> {
    embed.ensure_stamp(stamp);
    let mut have: HashMap<NodeId, Embed> = HashMap::new();
    let mut seen: HashSet<NodeId> = HashSet::new();
    let mut fresh: Vec<NodeId> = Vec::new();
    let mut dups = 0usize;
    for &t in targets {
        ensure!(t != PAD, "the request stream contains a PAD target");
        if !seen.insert(t) {
            dups += 1;
            continue;
        }
        if let Some(e) = embed.get(t) {
            have.insert(t, e.clone());
        } else {
            fresh.push(t);
        }
    }
    ensure!(
        fresh.len() <= capacity,
        "{} distinct uncached targets exceed the artifact batch capacity {capacity}",
        fresh.len()
    );
    let mut stats = FetchStats::default();
    if !fresh.is_empty() {
        let mut chunk = fresh.clone();
        chunk.resize(capacity, PAD);
        let (partials, fetch) = compute(&chunk)?;
        ensure!(
            partials[0].len() == capacity * h && partials[1].len() == capacity * h,
            "computed partials have shape ({}, {}), expected {}",
            partials[0].len(),
            partials[1].len(),
            capacity * h
        );
        for (i, &t) in fresh.iter().enumerate() {
            let e: Embed = (
                partials[0][i * h..(i + 1) * h].to_vec(),
                partials[1][i * h..(i + 1) * h].to_vec(),
            );
            embed.put(t, e.clone());
            have.insert(t, e);
        }
        stats = fetch;
    }
    let embeds = targets
        .iter()
        .map(|t| {
            have.get(t)
                .cloned()
                .ok_or_else(|| anyhow!("target {t} missing from the served batch"))
        })
        .collect::<Result<Vec<Embed>>>()?;
    Ok(BatchServed { embeds, stats, computed: fresh.len(), dups })
}

/// Build the run's report and publish the flight-recorder view of it.
fn finish(
    timeline: TimelineReport,
    ledger: ServeLedger,
    embeds: Vec<Embed>,
    wire: WireTraffic,
) -> ServeReport {
    // `serve.latency_ms` / `serve.deadline_miss_total` / `serve.qps`
    // tick live, per batch, inside `batcher::run` (PR 10) so mid-run
    // scrapes see them — only the run-end ledger families land here.
    crate::obs::counter_add("serve.requests", timeline.served as u64);
    crate::obs::counter_add("serve.embed_hits", ledger.embed_hits);
    crate::obs::counter_add("serve.embed_misses", ledger.embed_misses);
    crate::obs::counter_add("serve.embed_invalidations", ledger.embed_invalidations);
    let rep = ServeReport {
        served: timeline.served,
        batches: timeline.batches,
        deadline_misses: timeline.misses,
        max_batch: timeline.max_batch,
        qps: timeline.qps(),
        latencies_ms: timeline.latencies_ms,
        ledger,
        embeds,
        wire,
    };
    crate::obs::record_serve_summary(rep.p50_ms(), rep.p99_ms(), rep.qps);
    rep
}

// ---- the TCP serving protocol ----

/// Worker → leader: one partition's partial sums for a serve batch.
#[derive(Debug, PartialEq)]
enum ServeUp {
    Fwd {
        bi: usize,
        p1: Vec<f32>,
        p2: Vec<f32>,
        stats: FetchStats,
    },
    /// Best-effort death notice (same role as training's): aborts the
    /// leader's gather with the worker's own diagnosis instead of a
    /// bare hangup.
    Failed { bi: usize, msg: String },
}

/// Leader → worker: the deduplicated padded chunk to forward, or the
/// end of the stream.
#[derive(Clone, Debug, PartialEq)]
enum ServeDown {
    Batch { bi: usize, chunk: Vec<NodeId> },
    Done,
}

fn serve_up_tag(u: &ServeUp) -> RoundTag {
    match u {
        ServeUp::Fwd { bi, .. } => RoundTag::Round(*bi as u64),
        ServeUp::Failed { bi, msg } => RoundTag::abort_for(*bi, msg),
    }
}

impl Wire for ServeUp {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] partials — the modeled response traffic.
            ServeUp::Fwd { p1, p2, .. } => slice_bytes(p1) + slice_bytes(p2),
            ServeUp::Failed { .. } => 0,
        }
    }
}

impl Wire for ServeDown {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The target ids to embed — the modeled request traffic.
            ServeDown::Batch { chunk, .. } => 4 * chunk.len() as u64,
            ServeDown::Done => 0,
        }
    }
}

impl WireCodec for ServeUp {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            ServeUp::Fwd { bi, p1, p2, stats } => {
                w.u8(0);
                w.usize(*bi);
                w.f32s(p1);
                w.f32s(p2);
                stats.encode(w);
            }
            ServeUp::Failed { bi, msg } => {
                w.u8(1);
                w.usize(*bi);
                w.str(msg);
            }
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<ServeUp> {
        match r.u8()? {
            0 => {
                let bi = r.usize()?;
                let p1 = r.f32s()?;
                let p2 = r.f32s()?;
                let stats = FetchStats::decode(r)?;
                Ok(ServeUp::Fwd { bi, p1, p2, stats })
            }
            1 => {
                let bi = r.usize()?;
                let msg = r.str()?;
                Ok(ServeUp::Failed { bi, msg })
            }
            t => bail!("unknown serve worker-message tag {t}"),
        }
    }
}

impl WireCodec for ServeDown {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            ServeDown::Batch { bi, chunk } => {
                w.u8(0);
                w.usize(*bi);
                w.u32s(chunk);
            }
            ServeDown::Done => w.u8(1),
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<ServeDown> {
        match r.u8()? {
            0 => {
                let bi = r.usize()?;
                let chunk = r.u32s()?;
                Ok(ServeDown::Batch { bi, chunk })
            }
            1 => Ok(ServeDown::Done),
            t => bail!("unknown serve leader-message tag {t}"),
        }
    }
}

/// This process's typed socket lanes for the serving protocol.
type ServeLanes = crate::cluster::Lanes<ServeUp, ServeDown>;

/// Serve the stream over a multi-process TCP star: this process plays
/// exactly the rank its lanes were opened for. The leader runs the
/// batcher and the embedding cache, broadcasting only batches with at
/// least one uncached target; workers forward chunks until `Done`.
fn run_tcp(
    eng: &mut ServeEngine,
    sess: &Session,
    reqs: &[Request],
    opts: &ServeOpts,
    lanes: &ServeLanes,
) -> Result<ServeReport> {
    let cfg = sess.cfg.clone();
    let b = cfg.train.batch_size;
    let h = cfg.model.hidden;
    let wire0 = lanes.traffic();
    let ServeEngine {
        mp,
        plan,
        contexts,
        frontiers,
        arenas,
        embed,
        serve_seed,
        store_gen,
        dedup_fetch,
        gate,
    } = eng;
    let parts = mp.num_parts;
    let world = EpochWorld {
        cfg: &cfg,
        g: &sess.g,
        tree: &sess.tree,
        store: &sess.store,
        gate: gate.as_ref(),
        epoch_t0: Instant::now(),
    };
    match lanes.role {
        Role::Leader => {
            let mut hub = Hub::from_endpoints(&lanes.up, &lanes.down, parts);
            let bhub = Hub::from_endpoints(&lanes.bar_up, &lanes.bar_down, parts);
            bhub.barrier().context("serve: opening barrier")?;
            let (hits0, miss0, inv0) = (embed.hits, embed.misses, embed.invalidations);
            let mut ledger = ServeLedger::default();
            let mut embeds_out: Vec<Embed> = Vec::with_capacity(reqs.len());
            let mut next_bi = 0usize;
            let bopts = BatcherOpts { capacity: b, service_bound_us: service_bound_us(opts) };
            let run = batcher::run(reqs, &bopts, |batch| {
                let t0 = Instant::now();
                let targets: Vec<NodeId> = batch.iter().map(|r| r.target).collect();
                let stamp = (sess.params.version(), *store_gen);
                let served = serve_batch_with(embed, stamp, b, h, &targets, |chunk| {
                    let this = next_bi;
                    next_bi += 1;
                    hub.broadcast(ServeDown::Batch { bi: this, chunk: chunk.to_vec() })?;
                    let ups = hub.gather_round(this as u64, serve_up_tag).with_context(|| {
                        format!("serve batch {this}: collecting forward partials")
                    })?;
                    let mut partials = [vec![0f32; b * h], vec![0f32; b * h]];
                    let mut fetch = FetchStats::default();
                    for (w, up) in ups.into_iter().enumerate() {
                        match up {
                            ServeUp::Fwd { bi: ubi, p1, p2, stats } => {
                                ensure!(
                                    ubi == this,
                                    "protocol error: batch {ubi} partials in serve batch \
                                     {this}'s round"
                                );
                                ensure!(
                                    p1.len() == b * h && p2.len() == b * h,
                                    "worker {w}: partial shape ({}, {}) != {}",
                                    p1.len(),
                                    p2.len(),
                                    b * h
                                );
                                add_assign(&mut partials[0], &p1);
                                add_assign(&mut partials[1], &p2);
                                fetch.merge(stats);
                            }
                            ServeUp::Failed { bi: fbi, msg } => bail!(
                                "batch {fbi} death notice escaped gather_round's abort path \
                                 (protocol bug): {msg}"
                            ),
                        }
                    }
                    Ok((partials, fetch))
                })?;
                absorb_batch(&mut ledger, batch.len(), &served);
                embeds_out.extend(served.embeds);
                Ok(t0.elapsed().as_micros().max(1) as u64)
            });
            // Release the workers whether the run succeeded or not —
            // on error they would otherwise block in recv forever.
            let _ = hub.broadcast(ServeDown::Done);
            let timeline = run?;
            ledger.embed_hits = embed.hits - hits0;
            ledger.embed_misses = embed.misses - miss0;
            ledger.embed_invalidations = embed.invalidations - inv0;
            let mut rep = finish(timeline, ledger, embeds_out, WireTraffic::default());
            rep.wire = lanes.traffic().since(&wire0);
            Ok(rep)
        }
        Role::Worker(w) => {
            let port = Port::from_endpoints(&lanes.up, &lanes.down, parts);
            let bport = Port::from_endpoints(&lanes.bar_up, &lanes.bar_down, parts);
            bport.barrier().context("serve: opening barrier")?;
            let ctx = contexts
                .get_mut(w)
                .ok_or_else(|| anyhow!("worker rank {w} outside the {parts}-partition plan"))?;
            loop {
                match port.recv()? {
                    ServeDown::Batch { bi, chunk } => {
                        // Every serving rank derives bit-identical
                        // parameters from the config seed (deterministic
                        // init, version 0, no updates), so workers read
                        // their own store — no snapshot broadcast.
                        let fwd = worker_forward(
                            plan,
                            mp,
                            ctx,
                            &mut frontiers[w],
                            &mut arenas[w],
                            &world,
                            ParamsView::Owner(&sess.params),
                            *serve_seed,
                            *dedup_fetch,
                            w,
                            &chunk,
                        );
                        match fwd {
                            Ok((p1, p2, stats)) => {
                                port.send(ServeUp::Fwd { bi, p1, p2, stats })?
                            }
                            Err(e) => {
                                let _ = port.send(ServeUp::Failed {
                                    bi,
                                    msg: format!("{e:#}"),
                                });
                                return Err(e.context(format!("serve worker {w}, batch {bi}")));
                            }
                        }
                    }
                    ServeDown::Done => break,
                }
            }
            let mut rep = ServeReport::default();
            rep.wire = lanes.traffic().since(&wire0);
            Ok(rep)
        }
    }
}

/// Build the request stream a config + opts describe (synthetic unless
/// a trace file is named).
pub fn build_stream(sess: &Session, opts: &ServeOpts) -> Result<Vec<Request>> {
    match &opts.trace_path {
        Some(path) => {
            let n = sess.g.schema.node_types[sess.g.schema.target].count;
            trace_stream(path, opts.deadline_ms, n)
        }
        None => synthetic_stream(
            &sess.g,
            &StreamOpts {
                requests: opts.requests,
                qps: opts.qps,
                deadline_ms: opts.deadline_ms,
                zipf_alpha: opts.zipf_alpha,
                seed: sess.cfg.train.seed ^ 0x5354_5245, // "STRE"
            },
        ),
    }
}

/// CLI entry point: build a session + serving engine and drive the
/// request stream over the given transport backend. With `Backend::Tcp`
/// this process plays exactly one rank (the leader batches and serves,
/// workers forward); the channel backend plays every rank in-process.
pub fn run_serve(
    cfg: &Config,
    artifacts_dir: &str,
    system: SystemKind,
    opts: &ServeOpts,
    net: Backend,
) -> Result<ServeReport> {
    let mut cfg = cfg.clone();
    if matches!(net, Backend::Tcp(_)) {
        // The socket star only exists under the cluster runtime.
        cfg.train.runtime = RuntimeKind::Cluster;
    }
    let cfg = &cfg;
    let mut sess = Session::new(cfg, artifacts_dir)?;
    sess.net = net;
    let mut eng = ServeEngine::new(&mut sess, system, opts)?;
    // Only the rank that runs the batcher needs the stream; TCP worker
    // ranks receive their work over the wire.
    let reqs = if sess.net.is_tcp_worker() {
        Vec::new()
    } else {
        build_stream(&sess, opts)?
    };
    let lanes = match &sess.net {
        Backend::Tcp(node) => Some(ServeLanes::open(node, eng.mp.num_parts)?),
        Backend::Channel => None,
    };
    match &lanes {
        Some(lanes) => run_tcp(&mut eng, &sess, &reqs, opts, lanes),
        None => eng.run_channel(&sess, &reqs, opts),
    }
}

/// Serve over a loopback TCP star: one OS thread per rank, each with
/// its own [`Session`] (its own stores and contexts), connected through
/// real sockets on an ephemeral `127.0.0.1` port. Returns the leader's
/// report; worker reports (wire counters only) are discarded. The
/// TCP half of `tests/test_serve.rs` and CI's serve-smoke step.
pub fn run_loopback_tcp_serve(
    cfg: &Config,
    artifacts_dir: &str,
    system: SystemKind,
    opts: &ServeOpts,
) -> Result<ServeReport> {
    let mut cfg = cfg.clone();
    cfg.train.runtime = RuntimeKind::Cluster;
    let cfg = &cfg;
    let parts = cfg.train.num_partitions;
    let listener = std::net::TcpListener::bind("127.0.0.1:0")
        .map_err(|e| anyhow!("binding a loopback listener: {e}"))?;
    let addr = listener
        .local_addr()
        .map_err(|e| anyhow!("reading the loopback address: {e}"))?
        .to_string();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..parts)
            .map(|w| {
                let addr = addr.clone();
                s.spawn(move || -> Result<()> {
                    let node =
                        crate::net::tcp::dial(&addr, w, parts, crate::net::tcp::DIAL_TIMEOUT)?;
                    run_serve(cfg, artifacts_dir, system, opts, Backend::Tcp(node))?;
                    Ok(())
                })
            })
            .collect();
        let led = (|| -> Result<ServeReport> {
            let node = crate::net::tcp::accept_workers(listener, parts)?;
            run_serve(cfg, artifacts_dir, system, opts, Backend::Tcp(node))
        })();
        let mut worker_err: Option<anyhow::Error> = None;
        for (w, h) in handles.into_iter().enumerate() {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e.context(format!("loopback worker rank {w}")));
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("loopback worker rank {w} panicked"));
                    }
                }
            }
        }
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};
    use crate::net::codec::{decode_message, encode_message};

    #[test]
    fn splice_matches_per_target_blocks() {
        let g = generate(Preset::Mag, 1e-4, &GenParams::default());
        let tree = MetaTree::build(&g.schema, 2);
        let fanouts = vec![3, 2];
        let targets = g.train_nodes();
        assert!(targets.len() >= 3);
        let chunk = [targets[0], targets[1], PAD, targets[2]];
        let seed = 0xC0FFEE;
        let combined = splice_sample(&g, &tree, &fanouts, &chunk, seed, &|_| true);
        let sizes_1 = vertex_sizes(&tree, &fanouts, 1);
        let sizes_b = vertex_sizes(&tree, &fanouts, chunk.len());
        for (v, &m) in sizes_1.iter().enumerate() {
            // Vertex sizes are linear in the batch — the invariant the
            // whole splice layout rests on.
            assert_eq!(sizes_b[v], chunk.len() * m);
        }
        for (i, &t) in chunk.iter().enumerate() {
            if t == PAD {
                for (v, &m) in sizes_1.iter().enumerate() {
                    assert!(
                        combined.ids[v][i * m..(i + 1) * m].iter().all(|&id| id == PAD),
                        "padded target's vertex-{v} block must stay PAD"
                    );
                }
                continue;
            }
            // Block i of every vertex is exactly the single-target tree
            // of target i — position-independent, hence cacheable.
            let one = sample_tree(&g, &tree, &fanouts, &[t], 0, seed, |_| true);
            for (v, &m) in sizes_1.iter().enumerate() {
                assert_eq!(
                    &combined.ids[v][i * m..(i + 1) * m],
                    &one.ids[v][..],
                    "vertex {v}, block {i}"
                );
            }
        }
    }

    #[test]
    fn serve_batch_caches_dedups_and_composes() {
        let mut embed = EmbedCache::new(8);
        let h = 2;
        // Batch 1: [7, 7, 9] — one in-batch dup, two computed.
        let mut calls = 0usize;
        let served = serve_batch_with(&mut embed, (0, 0), 4, h, &[7, 7, 9], |chunk| {
            calls += 1;
            assert_eq!(chunk, &[7, 9, PAD, PAD]);
            let mut p1 = vec![0f32; 4 * h];
            let mut p2 = vec![0f32; 4 * h];
            for (i, &t) in chunk.iter().enumerate() {
                if t == PAD {
                    continue;
                }
                p1[i * h..(i + 1) * h].fill(t as f32);
                p2[i * h..(i + 1) * h].fill(-(t as f32));
            }
            Ok(([p1, p2], FetchStats { rows: 6, bytes: 48, ..Default::default() }))
        })
        .unwrap();
        assert_eq!((calls, served.computed, served.dups), (1, 2, 1));
        assert_eq!(served.embeds.len(), 3);
        assert_eq!(served.embeds[0], (vec![7.0; 2], vec![-7.0; 2]));
        assert_eq!(served.embeds[1], served.embeds[0]);
        assert_eq!(served.embeds[2], (vec![9.0; 2], vec![-9.0; 2]));
        assert_eq!(served.stats.rows, 6);
        // Batch 2: all hits — compute must not run at all.
        let served = serve_batch_with(&mut embed, (0, 0), 4, h, &[9, 7], |_| {
            panic!("an all-hit batch must skip compute")
        })
        .unwrap();
        assert_eq!((served.computed, served.dups), (0, 0));
        assert_eq!(served.embeds[1], (vec![7.0; 2], vec![-7.0; 2]));
        assert_eq!(served.stats.rows, 0);
        // Stamp change: everything recomputes.
        let served = serve_batch_with(&mut embed, (1, 0), 4, h, &[7], |chunk| {
            assert_eq!(chunk[0], 7);
            Ok(([vec![1.0; 4 * h], vec![2.0; 4 * h]], FetchStats::default()))
        })
        .unwrap();
        assert_eq!(served.computed, 1);
        assert_eq!(embed.invalidations, 1);
    }

    #[test]
    fn serve_protocol_round_trips() {
        let ups = [
            ServeUp::Fwd {
                bi: 3,
                p1: vec![1.0, -2.0, 0.5],
                p2: vec![0.25],
                stats: FetchStats { rows: 5, bytes: 80, remote_rows: 1, remote_bytes: 16 },
            },
            ServeUp::Failed { bi: 9, msg: "worker 1: artifact missing".into() },
        ];
        for m in &ups {
            let bytes = encode_message(m);
            let back: ServeUp = decode_message(&bytes).unwrap();
            assert_eq!(&back, m);
        }
        let downs = [
            ServeDown::Batch { bi: 1, chunk: vec![1, 2, PAD] },
            ServeDown::Done,
        ];
        for m in &downs {
            let bytes = encode_message(m);
            let back: ServeDown = decode_message(&bytes).unwrap();
            assert_eq!(&back, m);
        }
        // Modeled wire accounting: partials and target ids count,
        // control frames don't.
        assert_eq!(ups[0].wire_bytes(), 4 * 4);
        assert_eq!(ups[1].wire_bytes(), 0);
        assert_eq!(downs[0].wire_bytes(), 3 * 4);
        assert_eq!(ServeDown::Done.wire_bytes(), 0);
    }
}
