//! The embedding-reuse cache: served embeddings for hot targets, keyed
//! on (target id, parameter version, feature-store generation).
//!
//! Serving samples each target with a **fixed per-target seed** (see
//! [`super::ServeEngine`]), so a target's embedding is a pure function
//! of `(target, params, store)` — cacheable bit-for-bit. The validity
//! stamp makes the invalidation rule exact: any parameter update
//! (`ParamStore::step` bumps the version) or learnable-feature update
//! (`StoreDelta` application bumps the serve loop's store generation)
//! changes the stamp, and [`EmbedCache::ensure_stamp`] flushes every
//! entry — a served embedding is always byte-identical to a fresh
//! forward at the current parameters.
//!
//! Eviction is FIFO at capacity: deterministic (no clocks, no
//! randomness), so a serving run's hit sequence is reproducible from
//! its request stream alone.

use std::collections::{HashMap, VecDeque};

use crate::hetgraph::NodeId;

/// One cached embedding: the two layer-partial sums the forward fold
/// produces for the target's row (the RAF serving response).
pub type Embed = (Vec<f32>, Vec<f32>);

/// Validity stamp: (parameter-store version, feature-store generation).
pub type Stamp = (u64, u64);

#[derive(Debug, Default)]
pub struct EmbedCache {
    cap: usize,
    stamp: Option<Stamp>,
    map: HashMap<NodeId, Embed>,
    fifo: VecDeque<NodeId>,
    pub hits: u64,
    pub misses: u64,
    /// Stamp changes that dropped live entries.
    pub invalidations: u64,
}

impl EmbedCache {
    /// `cap = 0` disables caching (every lookup misses, puts are
    /// dropped) — the no-reuse baseline arm.
    pub fn new(cap: usize) -> EmbedCache {
        EmbedCache { cap, ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Align the cache to the current (param version, store generation).
    /// A stamp change flushes everything: entries were computed against
    /// other weights and may no longer be byte-identical to a fresh
    /// forward.
    pub fn ensure_stamp(&mut self, stamp: Stamp) {
        if self.stamp == Some(stamp) {
            return;
        }
        if !self.map.is_empty() {
            self.invalidations += 1;
            self.map.clear();
            self.fifo.clear();
        }
        self.stamp = Some(stamp);
    }

    /// Look up a target under the current stamp, counting hit/miss.
    pub fn get(&mut self, target: NodeId) -> Option<&Embed> {
        match self.map.get(&target) {
            Some(e) => {
                self.hits += 1;
                Some(e)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a freshly computed embedding, evicting FIFO at capacity.
    /// Re-inserting a resident target refreshes the value without
    /// growing the FIFO (its original queue position stands).
    pub fn put(&mut self, target: NodeId, embed: Embed) {
        if self.cap == 0 {
            return;
        }
        if self.map.insert(target, embed).is_some() {
            return;
        }
        self.fifo.push_back(target);
        while self.map.len() > self.cap {
            if let Some(old) = self.fifo.pop_front() {
                self.map.remove(&old);
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(v: f32) -> Embed {
        (vec![v], vec![v + 0.5])
    }

    #[test]
    fn hit_after_put_miss_before() {
        let mut c = EmbedCache::new(4);
        c.ensure_stamp((1, 0));
        assert!(c.get(7).is_none());
        c.put(7, e(1.0));
        assert_eq!(c.get(7), Some(&e(1.0)));
        assert_eq!((c.hits, c.misses), (1, 1));
    }

    #[test]
    fn stamp_change_flushes_and_counts() {
        let mut c = EmbedCache::new(4);
        c.ensure_stamp((1, 0));
        c.put(7, e(1.0));
        c.ensure_stamp((1, 0)); // unchanged: no flush
        assert_eq!(c.len(), 1);
        c.ensure_stamp((2, 0)); // param step landed
        assert!(c.is_empty());
        assert_eq!(c.invalidations, 1);
        c.put(7, e(2.0));
        c.ensure_stamp((2, 1)); // store delta landed
        assert!(c.is_empty());
        assert_eq!(c.invalidations, 2);
        // Flushing an already-empty cache is not an invalidation.
        c.ensure_stamp((3, 1));
        assert_eq!(c.invalidations, 2);
    }

    #[test]
    fn fifo_eviction_is_insertion_ordered() {
        let mut c = EmbedCache::new(2);
        c.ensure_stamp((0, 0));
        c.put(1, e(1.0));
        c.put(2, e(2.0));
        c.put(1, e(1.5)); // refresh, not re-enqueue
        c.put(3, e(3.0)); // evicts 1 (oldest insertion)
        assert!(c.get(1).is_none());
        assert_eq!(c.get(2), Some(&e(2.0)));
        assert_eq!(c.get(3), Some(&e(3.0)));
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = EmbedCache::new(0);
        c.ensure_stamp((0, 0));
        c.put(1, e(1.0));
        assert!(c.get(1).is_none());
        assert_eq!(c.len(), 0);
    }
}
