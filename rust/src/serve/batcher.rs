//! Deadline-driven microbatching: a batch closes when the *oldest*
//! pending request's latency budget would otherwise be breached, not at
//! a fixed size.
//!
//! The batcher runs a virtual timeline over the stream clock (the
//! arrival timestamps the request stream carries) and charges each
//! batch the *measured* service time its executor reports, so waiting
//! is simulated deterministically while compute is real. The close
//! rule per batch, with `bound` the service-time estimate:
//!
//! ```text
//! t_close = max(now, oldest.deadline − bound)
//! ```
//!
//! — the latest start for which the oldest request can still make its
//! deadline. Later arrivals are admitted up to `t_close` (or until the
//! batch hits the artifact's capacity, in which case it starts the
//! moment the capacity-th request has arrived — waiting longer could
//! only hurt). `bound` is adaptive: it ratchets up to the largest
//! service time observed, so one slow warmup batch widens the safety
//! margin of every later close decision.
//!
//! Deadline property (pinned by `tests/test_serve.rs`): if every
//! batch's service time stays ≤ the initial `bound`, capacity never
//! binds, and every budget is ≥ 2·bound, then **no request misses its
//! deadline** — batch k finishes exactly at its oldest deadline in the
//! worst case, and any request it did not admit arrived after
//! `deadline_k − bound`, leaving its own close point in the future.

use anyhow::{ensure, Result};

use crate::util::stats::Samples;

use super::request::Request;

/// Batcher knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherOpts {
    /// Maximum requests per batch — the compiled artifact's batch size.
    pub capacity: usize,
    /// Initial service-time estimate (one batch, arrival→done) used by
    /// the close rule before any batch has run. Ratchets up to the max
    /// observed service time.
    pub service_bound_us: u64,
}

/// Timeline outcome of one batcher run.
#[derive(Debug, Default)]
pub struct TimelineReport {
    pub served: usize,
    pub batches: usize,
    /// Requests whose completion exceeded their deadline.
    pub misses: usize,
    /// Per-request latency (arrival → batch completion), milliseconds.
    pub latencies_ms: Samples,
    /// Stream-clock span from first arrival to last completion.
    pub makespan_us: u64,
    /// Largest batch the close rule assembled.
    pub max_batch: usize,
}

impl TimelineReport {
    /// Sustained throughput over the makespan.
    pub fn qps(&self) -> f64 {
        if self.makespan_us == 0 {
            return f64::NAN;
        }
        self.served as f64 / (self.makespan_us as f64 / 1e6)
    }
}

/// Drive the whole request stream through deadline-closed batches.
/// `reqs` must be sorted by arrival (the request streams guarantee it).
/// `exec` runs one batch and returns its measured service time in µs;
/// its error aborts the run.
pub fn run(
    reqs: &[Request],
    opts: &BatcherOpts,
    mut exec: impl FnMut(&[Request]) -> Result<u64>,
) -> Result<TimelineReport> {
    ensure!(opts.capacity > 0, "batcher capacity must be positive");
    ensure!(
        reqs.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us),
        "the request stream must be sorted by arrival"
    );
    let mut rep = TimelineReport::default();
    let mut bound = opts.service_bound_us.max(1);
    let mut now = 0u64;
    let mut i = 0usize;
    let mut last_done = reqs.first().map(|r| r.arrival_us).unwrap_or(0);
    let t0 = last_done;
    while i < reqs.len() {
        let oldest = &reqs[i];
        now = now.max(oldest.arrival_us);
        let t_close = now.max(oldest.deadline_us.saturating_sub(bound));
        // Admit arrivals through the close point, capacity-capped.
        let mut j = i;
        while j < reqs.len() && j - i < opts.capacity && reqs[j].arrival_us <= t_close {
            j += 1;
        }
        // A full batch starts the instant its last request arrived —
        // holding it to t_close would only add waiting.
        let t_start = if j - i == opts.capacity {
            now.max(reqs[j - 1].arrival_us)
        } else {
            t_close
        };
        let batch = &reqs[i..j];
        let service = exec(batch)?;
        let t_done = t_start + service;
        for r in batch {
            let lat_ms = (t_done - r.arrival_us) as f64 / 1e3;
            rep.latencies_ms.push(lat_ms);
            // Live SLO families (PR 10): ticked per batch so a
            // mid-run /metrics scrape sees the latency distribution
            // and miss count as they grow, not at run end. Gated
            // internally on the recorder switch — zero work untraced.
            crate::obs::hist_observe("serve.latency_ms", lat_ms);
            if t_done > r.deadline_us {
                rep.misses += 1;
                crate::obs::counter_add("serve.deadline_miss_total", 1);
            }
        }
        rep.served += batch.len();
        rep.batches += 1;
        rep.max_batch = rep.max_batch.max(batch.len());
        bound = bound.max(service);
        now = t_done; // single-lane executor: the next batch queues behind
        last_done = t_done;
        i = j;
        // Running throughput so far — a live gauge, not a high-water.
        let span_us = last_done.saturating_sub(t0);
        if span_us > 0 {
            crate::obs::gauge_set("serve.qps", rep.served as f64 / (span_us as f64 / 1e6));
        }
    }
    rep.makespan_us = last_done.saturating_sub(t0);
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, arrival_us: u64, budget_us: u64) -> Request {
        Request { id, target: id as u32, arrival_us, deadline_us: arrival_us + budget_us }
    }

    #[test]
    fn closes_on_oldest_deadline_not_size() {
        // Two requests 1 ms apart, 10 ms budgets, 2 ms service: the
        // batcher must hold the first until deadline − bound = 8 ms and
        // admit the second — one batch, not two.
        let reqs = vec![req(0, 0, 10_000), req(1, 1_000, 10_000)];
        let mut sizes = Vec::new();
        let rep = run(
            &reqs,
            &BatcherOpts { capacity: 8, service_bound_us: 2_000 },
            |b| {
                sizes.push(b.len());
                Ok(2_000)
            },
        )
        .unwrap();
        assert_eq!(sizes, vec![2]);
        assert_eq!(rep.misses, 0);
        // Batch closed at 8 ms, done at 10 ms: the oldest rides its
        // deadline exactly, the newer one finishes 9 ms after arriving.
        assert!((rep.latencies_ms.max() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn full_batch_starts_early() {
        // Capacity 2 with three back-to-back arrivals: the first batch
        // must start when request 1 arrives (0.1 ms), not wait for the
        // close point at 9 ms.
        let reqs = vec![req(0, 0, 10_000), req(1, 100, 10_000), req(2, 200, 10_000)];
        let rep = run(
            &reqs,
            &BatcherOpts { capacity: 2, service_bound_us: 1_000 },
            |_| Ok(1_000),
        )
        .unwrap();
        assert_eq!(rep.batches, 2);
        assert_eq!(rep.misses, 0);
        // First batch: starts at 100 (when request 1 lands), done at
        // 1100 → request 1's latency is the 1.0 ms minimum.
        assert!((rep.latencies_ms.min() - 1.0).abs() < 1e-9, "{}", rep.latencies_ms.min());
    }

    #[test]
    fn overload_reports_misses_honestly() {
        // Service (5 ms) exceeds every budget (2 ms): every request
        // must be counted as a miss, none silently dropped.
        let reqs: Vec<Request> = (0..10).map(|i| req(i, i as u64 * 100, 2_000)).collect();
        let rep = run(
            &reqs,
            &BatcherOpts { capacity: 4, service_bound_us: 5_000 },
            |_| Ok(5_000),
        )
        .unwrap();
        assert_eq!(rep.served, 10);
        assert_eq!(rep.misses, 10);
        assert!(rep.qps() > 0.0);
    }

    #[test]
    fn bound_ratchets_up() {
        // Every batch takes 4× the initial estimate. The warmup
        // request misses (its batch closed 1 ms before its deadline on
        // the optimistic bound, then ran 4 ms), but the ratcheted
        // bound closes request 1's batch 4 ms early — it finishes
        // exactly on its deadline. A stale bound would close at
        // deadline − 1 ms and miss both.
        let reqs = vec![req(0, 0, 20_000), req(1, 30_000, 5_000)];
        let rep = run(
            &reqs,
            &BatcherOpts { capacity: 8, service_bound_us: 1_000 },
            |_| Ok(4_000),
        )
        .unwrap();
        assert_eq!(rep.batches, 2);
        assert_eq!(rep.misses, 1, "only the warmup batch may miss");
    }
}
