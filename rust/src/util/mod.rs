//! Self-contained utility substrates.
//!
//! The build environment is offline and the usual ecosystem crates
//! (serde/serde_json, rand, clap, proptest, criterion) are unavailable, so
//! this module provides purpose-built replacements: a JSON parser/emitter,
//! a SplitMix64/xoshiro256++ PRNG with distribution helpers, summary
//! statistics, a CLI flag parser, a property-testing harness, and a
//! criterion-style benchmark harness (used by `cargo bench` through
//! `harness = false` bench targets).

pub mod json;
pub mod rng;
pub mod stats;
pub mod cli;
pub mod proptest;
pub mod bench;

/// Format a byte count with binary-prefix units (e.g. `1.50 MiB`).
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration given in seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 60.0 {
        format!("{:.1} min", s / 60.0)
    } else if s >= 1.0 {
        format!("{:.3} s", s)
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Sum two equal-length f32 vectors in place (the float-reduction
/// primitive every engine uses; accumulation *order* is the determinism
/// contract, so callers always fold in (worker, output) order).
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// Scale a vector in place.
pub fn scale(a: &mut [f32], s: f32) {
    for x in a.iter_mut() {
        *x *= s;
    }
}

/// Artifact gate shared by every artifact-dependent test and bench:
/// `true` when `artifacts/<cfg>/manifest.json` exists, else prints one
/// actionable skip message naming the `make artifacts` path and returns
/// `false`. (Cargo runs tests and benches with cwd = the package root,
/// where `configs/` and `artifacts/` are linked.)
pub fn artifacts_ready(cfg_name: &str) -> bool {
    let path = format!("artifacts/{cfg_name}/manifest.json");
    if std::path::Path::new(&path).exists() {
        return true;
    }
    eprintln!(
        "skipping: {path} missing — run `make artifacts` at the repo root \
         (lowers configs/{cfg_name}.json via python/compile/aot.py; needs python + jax)"
    );
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_assign_and_scale() {
        let mut a = vec![1.0, 2.0];
        add_assign(&mut a, &[0.5, 0.5]);
        assert_eq!(a, vec![1.5, 2.5]);
        scale(&mut a, 2.0);
        assert_eq!(a, vec![3.0, 5.0]);
    }

    #[test]
    fn artifact_gate_reports_missing_dirs() {
        assert!(!artifacts_ready("no-such-config-name"));
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(1536), "1.50 KiB");
        assert_eq!(fmt_bytes(92_300_000), "88.02 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(120.0), "2.0 min");
        assert_eq!(fmt_secs(1.5), "1.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
        assert_eq!(fmt_secs(5e-9), "5.0 ns");
    }
}
