//! Deterministic PRNG substrate (SplitMix64 seeding + xoshiro256++ core)
//! with the distribution helpers the rest of the system needs: uniform
//! ranges, Gaussian, Zipf (power-law degrees / access skew), shuffling,
//! and weighted choice. All generators are seeded, so every dataset,
//! partition, and experiment in this repository is reproducible.

/// xoshiro256++ PRNG seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-worker / per-relation RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 128-bit multiply keeps bias below 2^-64 for any realistic n.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), order arbitrary.
    /// Uses Floyd's algorithm so it is O(k) even for huge n.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        self.sample_distinct_into(n, k, &mut out);
        out
    }

    /// [`sample_distinct`](Self::sample_distinct) into a caller-owned
    /// buffer so the sampling hot loop (one call per parent slot) reuses
    /// scratch instead of allocating. Byte-identical picks: the linear
    /// membership scan over `out` sees exactly the set Floyd's algorithm
    /// tracks, and fanouts are small enough that the scan beats hashing.
    pub fn sample_distinct_into(&mut self, n: usize, k: usize, out: &mut Vec<usize>) {
        debug_assert!(k <= n);
        out.clear();
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if out.contains(&t) { j } else { t };
            out.push(pick);
        }
    }

    /// Weighted index choice proportional to `weights` (must be non-negative,
    /// not all zero).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(α) sampler over ranks {0, …, n−1} using precomputed CDF inversion
/// via binary search. Rank 0 is the hottest item. Used for power-law
/// degree generation and skewed access patterns.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let x = rng.f64();
        // `total_cmp`, not `partial_cmp().unwrap()`: a degenerate alpha
        // (NaN/Inf) yields NaN CDF entries, and the sampler must keep
        // returning *some* in-range rank instead of panicking whatever
        // consumes the stream. NaN compares greater than every real x
        // under the total order, so the search still lands in range.
        match self.cdf.binary_search_by(|c| c.total_cmp(&x)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Expected unnormalized weight of rank k (for analytic hotness).
    pub fn weight(&self, k: usize) -> f64 {
        if k == 0 {
            self.cdf[0]
        } else {
            self.cdf[k] - self.cdf[k - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Rng::new(2);
        let mean: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..20_000).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_into_matches_floyd_with_hashset() {
        // The scratch-reusing variant must reproduce the original
        // HashSet-tracked Floyd picks bit-for-bit (sampling determinism
        // is the substrate of the Prop. 1 equivalence tests).
        for seed in 0..20u64 {
            let mut a = Rng::new(seed);
            let mut b = Rng::new(seed);
            let (n, k) = (50 + seed as usize, 7);
            let mut into = Vec::new();
            a.sample_distinct_into(n, k, &mut into);
            let mut chosen = std::collections::HashSet::new();
            let mut reference = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = b.below(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                reference.push(pick);
            }
            assert_eq!(into, reference, "seed {seed}");
        }
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let s = r.sample_distinct(100, 17);
            assert_eq!(s.len(), 17);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 17);
            assert!(s.iter().all(|&x| x < 100));
        }
    }

    #[test]
    fn zipf_is_skewed_and_valid() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::new(6);
        let mut counts = vec![0usize; 1000];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Rank 0 should dominate rank 500 heavily under a power law.
        assert!(counts[0] > 20 * counts[500].max(1) / 2, "not skewed: {} vs {}", counts[0], counts[500]);
        assert!(counts[0] > counts[100]);
    }

    #[test]
    fn zipf_nan_cdf_does_not_panic() {
        // alpha = NaN poisons every CDF entry; the old
        // partial_cmp().unwrap() comparator panicked inside
        // binary_search_by. The sampler must instead keep returning
        // in-range ranks (NaN > x under the total order, so the search
        // resolves to rank 0).
        let z = Zipf::new(16, f64::NAN);
        assert!(z.cdf.iter().all(|c| c.is_nan()));
        let mut r = Rng::new(11);
        for _ in 0..100 {
            assert!(z.sample(&mut r) < 16);
        }
        // A degenerate-but-finite CDF (alpha = inf puts all mass on
        // rank 0) must also stay in range.
        let z = Zipf::new(16, f64::INFINITY);
        let mut r = Rng::new(12);
        for _ in 0..100 {
            assert!(z.sample(&mut r) < 16);
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(9);
        let w = [0.0, 9.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[1] > counts[2] * 5);
    }
}
