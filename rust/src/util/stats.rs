//! Summary statistics over f64 samples: mean, stddev, min/max, and
//! percentile estimation. Used by the bench harness, the metrics module,
//! and the miss-penalty profiler.

/// Online-collected sample set with summary queries.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64)
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    /// The raw samples, in push order.
    pub fn values(&self) -> &[f64] {
        &self.xs
    }

    /// Percentile via linear interpolation between order statistics
    /// (`q` in [0, 100]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.xs.clone();
        // `total_cmp`, not `partial_cmp().unwrap()`: one NaN sample (a
        // diverged loss, a 0/0 rate) must report as NaN, not panic the
        // bench/report path mid-run. NaNs sort last under the IEEE 754
        // total order, so they only surface in the top percentiles.
        sorted.sort_by(|a, b| a.total_cmp(b));
        let rank = q / 100.0 * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Relative imbalance of a set of loads: max/mean. 1.0 = perfectly
/// balanced. Used to evaluate partition balance (paper §5).
pub fn imbalance(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return f64::NAN;
    }
    let mean = loads.iter().sum::<f64>() / loads.len() as f64;
    if mean == 0.0 {
        return 1.0;
    }
    loads.iter().cloned().fold(f64::NEG_INFINITY, f64::max) / mean
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let mut s = Samples::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.sum(), 10.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Samples::new();
        for x in 0..=100 {
            s.push(x as f64);
        }
        assert_eq!(s.p50(), 50.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!((s.p99() - 99.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // A diverged run pushes one NaN loss; the percentile sort must
        // not panic (the old partial_cmp().unwrap() did) and must keep
        // real order statistics usable below the NaN tail.
        let mut s = Samples::new();
        for x in [3.0, f64::NAN, 1.0, 2.0] {
            s.push(x);
        }
        assert_eq!(s.percentile(0.0), 1.0);
        assert!((s.p50() - 2.5).abs() < 1e-12); // 1,2,3,NaN → midpoint of 2 and 3
        assert!(s.percentile(100.0).is_nan()); // NaN sorts last under total_cmp
        let mut all_nan = Samples::new();
        all_nan.push(f64::NAN);
        assert!(all_nan.p99().is_nan());
    }

    #[test]
    fn empty_is_nan() {
        let s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn imbalance_metric() {
        assert!((imbalance(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((imbalance(&[3.0, 1.0]) - 1.5).abs() < 1e-12);
    }
}
