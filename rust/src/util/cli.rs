//! Tiny CLI argument parser: `--key value`, `--flag`, and positional
//! arguments. Replaces `clap` (unavailable offline). Every binary in this
//! repo (launcher, examples, benches) parses through this.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable). `--key=value` and
    /// `--key value` are both accepted; `--flag` followed by another
    /// option or nothing is a boolean flag.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let argv: Vec<String> = it.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    args.options
                        .insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // Note: `--key value` is greedy, so boolean flags must use the
        // trailing position or `--flag=`-free placement after positionals.
        let a = parse(&["run", "--config", "x.json", "--parts=4", "data", "--verbose"]);
        assert_eq!(a.positional, vec!["run", "data"]);
        assert_eq!(a.get("config"), Some("x.json"));
        assert_eq!(a.get_usize("parts", 1), 4);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("r", 0.5), 0.5);
        assert!(!a.has_flag("v"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.has_flag("fast"));
    }
}
