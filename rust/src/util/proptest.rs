//! Property-testing harness (replacement for the unavailable `proptest`
//! crate). Runs a property over many seeded random cases; on failure it
//! reports the seed and case index so the exact input can be replayed
//! deterministically. Coordinator invariants (routing, batching,
//! partition properties) are checked through this.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // HETA_PROPTEST_CASES / HETA_PROPTEST_SEED allow widening or
        // replaying runs without recompiling.
        let cases = std::env::var("HETA_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("HETA_PROPTEST_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0x48455441); // "HETA"
        Config { cases, seed }
    }
}

/// Run `prop` over `cfg.cases` random cases. The property receives a
/// per-case RNG and the case index; it returns `Err(msg)` to fail.
pub fn run_with(cfg: Config, name: &str, mut prop: impl FnMut(&mut Rng, usize) -> Result<(), String>) {
    let mut master = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = master.fork(case as u64);
        if let Err(msg) = prop(&mut case_rng, case) {
            panic!(
                "property '{name}' failed at case {case} (seed={}, replay with \
                 HETA_PROPTEST_SEED={} HETA_PROPTEST_CASES={}): {msg}",
                cfg.seed,
                cfg.seed,
                case + 1
            );
        }
    }
}

/// Run with the default configuration.
pub fn run(name: &str, prop: impl FnMut(&mut Rng, usize) -> Result<(), String>) {
    run_with(Config::default(), name, prop)
}

/// Generator: randomly interleave several ordered lanes into one
/// `(lane, item)` schedule, preserving each lane's internal order —
/// exactly the space of arrival orders a FIFO-per-lane transport can
/// produce. Concurrency properties (mailbox lane ordering, round
/// gathers under a staleness window) are checked against schedules
/// drawn from this.
pub fn interleave<T>(rng: &mut Rng, lanes: Vec<Vec<T>>) -> Vec<(usize, T)> {
    let total: usize = lanes.iter().map(|l| l.len()).sum();
    let mut iters: Vec<std::vec::IntoIter<T>> = lanes.into_iter().map(|l| l.into_iter()).collect();
    let mut out = Vec::with_capacity(total);
    while out.len() < total {
        let nonempty: Vec<usize> = iters
            .iter()
            .enumerate()
            .filter(|(_, it)| it.len() > 0)
            .map(|(i, _)| i)
            .collect();
        let lane = nonempty[rng.below(nonempty.len())];
        let item = iters[lane].next().expect("nonempty lane");
        out.push((lane, item));
    }
    out
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_with(
            Config { cases: 10, seed: 1 },
            "count",
            |_rng, _case| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        run_with(Config { cases: 5, seed: 2 }, "fails", |rng, _| {
            let x = rng.below(10);
            if x < 10 {
                Err(format!("x={x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn interleave_preserves_lane_order_and_loses_nothing() {
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let lanes = vec![vec![0, 1, 2], vec![10, 11], vec![], vec![20, 21, 22, 23]];
            let sched = interleave(&mut rng, lanes.clone());
            assert_eq!(sched.len(), 9);
            let mut seen: Vec<Vec<i32>> = vec![Vec::new(); lanes.len()];
            for (lane, item) in sched {
                seen[lane].push(item);
            }
            assert_eq!(seen, lanes, "every lane must replay in order");
        }
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first = Vec::new();
        run_with(Config { cases: 5, seed: 3 }, "a", |rng, _| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        run_with(Config { cases: 5, seed: 3 }, "b", |rng, _| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
