//! Property-testing harness (replacement for the unavailable `proptest`
//! crate). Runs a property over many seeded random cases; on failure it
//! reports the seed and case index so the exact input can be replayed
//! deterministically. Coordinator invariants (routing, batching,
//! partition properties) are checked through this.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // HETA_PROPTEST_CASES / HETA_PROPTEST_SEED allow widening or
        // replaying runs without recompiling.
        let cases = std::env::var("HETA_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("HETA_PROPTEST_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0x48455441); // "HETA"
        Config { cases, seed }
    }
}

/// Run `prop` over `cfg.cases` random cases. The property receives a
/// per-case RNG and the case index; it returns `Err(msg)` to fail.
pub fn run_with(cfg: Config, name: &str, mut prop: impl FnMut(&mut Rng, usize) -> Result<(), String>) {
    let mut master = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut case_rng = master.fork(case as u64);
        if let Err(msg) = prop(&mut case_rng, case) {
            panic!(
                "property '{name}' failed at case {case} (seed={}, replay with \
                 HETA_PROPTEST_SEED={} HETA_PROPTEST_CASES={}): {msg}",
                cfg.seed,
                cfg.seed,
                case + 1
            );
        }
    }
}

/// Run with the default configuration.
pub fn run(name: &str, prop: impl FnMut(&mut Rng, usize) -> Result<(), String>) {
    run_with(Config::default(), name, prop)
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_with(
            Config { cases: 10, seed: 1 },
            "count",
            |_rng, _case| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        run_with(Config { cases: 5, seed: 2 }, "fails", |rng, _| {
            let x = rng.below(10);
            if x < 10 {
                Err(format!("x={x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first = Vec::new();
        run_with(Config { cases: 5, seed: 3 }, "a", |rng, _| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second = Vec::new();
        run_with(Config { cases: 5, seed: 3 }, "b", |rng, _| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
