//! Minimal JSON parser and emitter.
//!
//! JSON is the interchange format between the Rust coordinator and the
//! Python compile path: `configs/*.json` drive both the dataset/model
//! construction (Rust) and the AOT artifact shapes (`python/compile/aot.py`).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so emission is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error produced by [`parse`], carrying a byte offset and message.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; returns `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// `get` with a required-field error for config parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        match self {
            Json::Obj(o) => o
                .get(key)
                .ok_or_else(|| anyhow::anyhow!("missing required JSON field '{key}'")),
            _ => anyhow::bail!("expected JSON object while looking for field '{key}'"),
        }
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write_json(self, f)
    }
}

fn write_json(v: &Json, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match v {
        Json::Null => write!(f, "null"),
        Json::Bool(b) => write!(f, "{b}"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Json::Str(s) => write_escaped(s, f),
        Json::Arr(a) => {
            write!(f, "[")?;
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_json(x, f)?;
            }
            write!(f, "]")
        }
        Json::Obj(o) => {
            write!(f, "{{")?;
            for (i, (k, x)) in o.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write_escaped(k, f)?;
                write!(f, ":")?;
                write_json(x, f)?;
            }
            write!(f, "}}")
        }
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse a JSON document from a string.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON value"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x\ny"));
        assert_eq!(*v.get("c"), Json::Null);
        assert_eq!(*v.get("missing"), Json::Null);
    }

    #[test]
    fn emits_deterministically() {
        let v = parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn scientific_notation() {
        assert_eq!(parse("2.4e8").unwrap().as_f64(), Some(2.4e8));
        assert_eq!(parse("-1e-3").unwrap().as_f64(), Some(-1e-3));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""Ab""#).unwrap().as_str(), Some("Ab"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn req_reports_missing_fields() {
        let v = parse(r#"{"a":1}"#).unwrap();
        assert!(v.req("a").is_ok());
        let e = v.req("nope").unwrap_err().to_string();
        assert!(e.contains("nope"));
    }
}
