//! Criterion-style benchmark harness (the `criterion` crate is
//! unavailable offline). Bench targets are declared with
//! `harness = false` in `Cargo.toml`; each is a plain binary that builds
//! a [`Bench`] runner, registers closures, and prints a stable,
//! greppable report. `cargo bench` therefore works end to end.

use std::time::Instant;

use super::stats::Samples;

/// One benchmark measurement: warmup, then timed iterations with
/// per-iteration samples.
pub struct Bench {
    name: String,
    warmup_iters: usize,
    min_iters: usize,
    max_seconds: f64,
    filter: Option<String>,
}

/// Result row of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub stddev_s: f64,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // `cargo bench -- <filter>` passes the filter as a positional arg.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        println!("== bench suite: {suite} ==");
        Bench {
            name: suite.to_string(),
            warmup_iters: 3,
            min_iters: 10,
            max_seconds: 2.0,
            filter,
        }
    }

    pub fn with_iters(mut self, warmup: usize, min_iters: usize) -> Self {
        self.warmup_iters = warmup;
        self.min_iters = min_iters;
        self
    }

    pub fn with_budget(mut self, seconds: f64) -> Self {
        self.max_seconds = seconds;
        self
    }

    /// Time `f`, which performs one full iteration per call. Returns the
    /// result row (also printed).
    pub fn run(&self, name: &str, mut f: impl FnMut()) -> Option<BenchResult> {
        if let Some(filt) = &self.filter {
            if !name.contains(filt.as_str()) {
                return None;
            }
        }
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Samples::new();
        let start = Instant::now();
        let mut iters = 0;
        while iters < self.min_iters || start.elapsed().as_secs_f64() < self.max_seconds {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
            iters += 1;
            if iters >= self.min_iters && start.elapsed().as_secs_f64() >= self.max_seconds {
                break;
            }
            if iters >= 10_000 {
                break;
            }
        }
        let r = BenchResult {
            name: format!("{}/{}", self.name, name),
            iters,
            mean_s: samples.mean(),
            p50_s: samples.p50(),
            stddev_s: samples.stddev(),
        };
        println!(
            "bench {:<52} {:>10}/iter (p50 {:>10}, sd {:>9}, n={})",
            r.name,
            super::fmt_secs(r.mean_s),
            super::fmt_secs(r.p50_s),
            super::fmt_secs(r.stddev_s),
            r.iters
        );
        Some(r)
    }
}

/// Print a labelled metric row (for benches that report model-derived
/// numbers — bytes, hit rates, simulated seconds — rather than wallclock).
pub fn report(metric: &str, value: impl std::fmt::Display) {
    println!("metric {metric:<58} {value}");
}

/// Print a table with a header; used by the figure/table reproduction
/// benches so the output matches the paper's rows/series.
pub fn table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n--- {title} ---");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!(
        "{}",
        fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Black-box hint to keep the optimizer from eliding benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bench::new("selftest").with_iters(1, 3).with_budget(0.01);
        let r = b.run("noop", || {
            black_box(1 + 1);
        });
        // The default-arg filter may swallow runs under `cargo test` only if
        // a positional arg matches; in-test there is none matching "noop"
        // unless no filter is present, in which case we must get a result.
        if let Some(r) = r {
            assert!(r.iters >= 3);
            assert!(r.mean_s >= 0.0);
        }
    }

    #[test]
    fn table_renders() {
        table(
            "t",
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }
}
