//! `heta` — launcher CLI for the Heta reproduction.
//!
//! Subcommands:
//!   plan       --config <file> --out <plan.json>   emit the AOT artifact plan
//!   partition  --config <file> [--method m]        run + report a partitioning
//!   train      --config <file> --engine raf|vanilla [--epochs n]
//!   info       --config <file>                     dataset/schema summary
//!
//! `plan` is the build-time half of the Rust↔Python contract: it computes
//! the metatree, meta-partitioning and padded block shapes that
//! `python/compile/aot.py` lowers into HLO artifacts.

use anyhow::{bail, Context, Result};
use heta::config::{build_plan, Config};
use heta::partition::{edgecut, meta::meta_partition, metis_like, quality};
use heta::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "plan" => cmd_plan(&args),
        "partition" => cmd_partition(&args),
        "train" => cmd_train(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: heta <plan|partition|train|info> --config configs/<name>.json [options]\n\
                 \n\
                 plan       --out <plan.json>      emit AOT artifact plan\n\
                 partition  [--method meta|random|metis|bytype] [--parts p]\n\
                 train      --engine raf|vanilla [--epochs n] [--artifacts dir]\n\
                 \x20          [--runtime sequential|cluster] [--no-pipeline]\n\
                 \x20          [--no-dedup-fetch] [--shared-session] [--staleness N]\n\
                 info"
            );
            Ok(())
        }
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let path = args
        .get("config")
        .context("--config <file> is required")?;
    Config::load(path)
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out = args.get("out").context("--out <plan.json> is required")?;
    let g = cfg.build_graph();
    let (mp, tree) = meta_partition(&g, cfg.train.num_partitions, cfg.model.layers, None);
    let plan = build_plan(&cfg, &g, &tree, &mp);
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, plan.to_string())?;
    println!(
        "plan '{}': {} tree edges, {} partitions -> {}",
        cfg.name,
        tree.edges.len(),
        cfg.train.num_partitions,
        out
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let g = cfg.build_graph();
    let parts = args.get_usize("parts", cfg.train.num_partitions);
    let method = args.get_or("method", "meta");
    match method.as_str() {
        "meta" => {
            let (mp, tree) = meta_partition(&g, parts, cfg.model.layers, None);
            println!(
                "meta-partitioning: {} sub-metatrees over {} partitions in {}",
                tree.sub_metatrees().len(),
                parts,
                heta::util::fmt_secs(mp.elapsed_s)
            );
            for p in 0..parts {
                println!(
                    "  partition {p}: {} relations, load {}, topo {}",
                    mp.rels_per_part[p].len(),
                    mp.part_load(&g, p),
                    heta::util::fmt_bytes(mp.part_topology_bytes(&g, p))
                );
            }
        }
        m @ ("random" | "metis" | "bytype") => {
            let p = match m {
                "random" => edgecut::random(&g, parts, cfg.train.seed),
                "metis" => metis_like::metis_like(&g, parts, cfg.train.seed),
                _ => edgecut::by_type(&g, parts, cfg.train.seed),
            };
            let cut = quality::edge_cut(&g, &p);
            let bounds = quality::boundary_nodes(&g, &p);
            println!(
                "{}: time {}, peak mem {}, edge cut {} ({:.1}%), max boundary {}",
                p.method,
                heta::util::fmt_secs(p.elapsed_s),
                heta::util::fmt_bytes(p.peak_mem_bytes),
                cut,
                cut as f64 / g.num_edges() as f64 * 100.0,
                bounds.iter().max().unwrap()
            );
        }
        other => bail!("unknown method {other}"),
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(rt) = args.get("runtime") {
        cfg.train.runtime = heta::config::RuntimeKind::parse(rt)
            .with_context(|| format!("unknown runtime '{rt}' (sequential|cluster)"))?;
    }
    if args.has_flag("no-pipeline") {
        cfg.train.pipeline = false;
    }
    if args.has_flag("no-dedup-fetch") {
        cfg.train.dedup_fetch = false;
    }
    if args.has_flag("shared-session") {
        // Escape hatch: serialize artifact execution on one token,
        // reproducing the pre-exec-layer shared-session behavior.
        cfg.train.shared_session = true;
    }
    if let Some(s) = args.get("staleness") {
        // Bounded-staleness window of the async 1F1B pipeline: 0 is the
        // synchronous protocol, k keeps up to k extra batches in flight
        // (cluster runtime only; see TrainConfig::staleness).
        cfg.train.staleness = s
            .parse()
            .with_context(|| format!("--staleness expects a non-negative integer, got '{s}'"))?;
        if cfg.train.staleness > 0 && !cfg.train.dedup_fetch {
            bail!("--staleness requires the dedup gather (drop --no-dedup-fetch)");
        }
    }
    let engine = args.get_or("engine", "raf");
    let epochs = args.get_usize("epochs", 1);
    let artifacts = args.get_or("artifacts", &format!("artifacts/{}", cfg.name));
    let report = heta::coordinator::run_training(&cfg, &artifacts, &engine, epochs)?;
    report.print(&format!(
        "{}/{}/{}",
        cfg.name,
        engine,
        cfg.train.runtime.name()
    ));
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let g = cfg.build_graph();
    println!("dataset {} (preset {}, scale {})", cfg.name, g.schema.name, cfg.dataset.scale);
    println!(
        "  {} nodes / {} node types, {} edges / {} relations, {} classes",
        g.num_nodes(),
        g.schema.node_types.len(),
        g.num_edges(),
        g.schema.relations.len(),
        g.schema.num_classes
    );
    for (i, t) in g.schema.node_types.iter().enumerate() {
        println!(
            "  type {i} {:<10} count {:<8} dim {:<5} {}",
            t.name,
            t.count,
            t.feat_dim,
            if t.learnable { "learnable" } else { "featured" }
        );
    }
    println!(
        "  storage (fp16 features): {}",
        heta::util::fmt_bytes(g.storage_bytes(2))
    );
    Ok(())
}
