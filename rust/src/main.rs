//! `heta` — launcher CLI for the Heta reproduction.
//!
//! Subcommands:
//!   plan       --config <file> --out <plan.json>   emit the AOT artifact plan
//!   partition  --config <file> [--method m]        run + report a partitioning
//!   train      --config <file> --engine raf|vanilla [--epochs n]
//!   launch     --config <file> [-n K]              spawn a local K-worker TCP cluster
//!   info       --config <file>                     dataset/schema summary
//!
//! `plan` is the build-time half of the Rust↔Python contract: it computes
//! the metatree, meta-partitioning and padded block shapes that
//! `python/compile/aot.py` lowers into HLO artifacts.
//!
//! `train --transport tcp --rank R --peers host:port` runs **one rank**
//! of a multi-process cluster (rank 0 is the leader and listens on the
//! first peers entry; ranks 1..=K are the partition workers and dial
//! in). `launch` is the local convenience wrapper: it spawns rank 0
//! plus K workers of the same binary on a loopback port and reaps them.

use anyhow::{bail, ensure, Context, Result};
use heta::config::{build_plan, Config, RuntimeKind, TransportKind};
use heta::partition::{edgecut, meta::meta_partition, metis_like, quality};
use heta::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "plan" => cmd_plan(&args),
        "partition" => cmd_partition(&args),
        "train" => cmd_train(&args),
        "launch" => cmd_launch(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: heta <plan|partition|train|launch|info> --config <cfg.json> [options]\n\
                 \n\
                 plan       --out <plan.json>      emit AOT artifact plan\n\
                 partition  [--method meta|random|metis|bytype] [--parts p]\n\
                 train      --engine raf|vanilla [--epochs n] [--artifacts dir]\n\
                 \x20          [--runtime sequential|cluster] [--no-pipeline]\n\
                 \x20          [--no-dedup-fetch] [--shared-session] [--staleness N]\n\
                 \x20          [--transport channel|tcp --rank R --peers host:port[,...]]\n\
                 \x20          [--trace [out.json]] [--log-level error|warn|info|debug]\n\
                 launch     [-n K] [--port P] + train options: spawn leader + K\n\
                 \x20          worker processes over loopback TCP and reap them\n\
                 info"
            );
            Ok(())
        }
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let path = args
        .get("config")
        .context("--config <file> is required")?;
    Config::load(path)
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out = args.get("out").context("--out <plan.json> is required")?;
    let g = cfg.build_graph();
    let (mp, tree) = meta_partition(&g, cfg.train.num_partitions, cfg.model.layers, None);
    let plan = build_plan(&cfg, &g, &tree, &mp);
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, plan.to_string())?;
    println!(
        "plan '{}': {} tree edges, {} partitions -> {}",
        cfg.name,
        tree.edges.len(),
        cfg.train.num_partitions,
        out
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let g = cfg.build_graph();
    let parts = args.get_usize("parts", cfg.train.num_partitions);
    let method = args.get_or("method", "meta");
    match method.as_str() {
        "meta" => {
            let (mp, tree) = meta_partition(&g, parts, cfg.model.layers, None);
            println!(
                "meta-partitioning: {} sub-metatrees over {} partitions in {}",
                tree.sub_metatrees().len(),
                parts,
                heta::util::fmt_secs(mp.elapsed_s)
            );
            for p in 0..parts {
                println!(
                    "  partition {p}: {} relations, load {}, topo {}",
                    mp.rels_per_part[p].len(),
                    mp.part_load(&g, p),
                    heta::util::fmt_bytes(mp.part_topology_bytes(&g, p))
                );
            }
        }
        m @ ("random" | "metis" | "bytype") => {
            let p = match m {
                "random" => edgecut::random(&g, parts, cfg.train.seed),
                "metis" => metis_like::metis_like(&g, parts, cfg.train.seed),
                _ => edgecut::by_type(&g, parts, cfg.train.seed),
            };
            let cut = quality::edge_cut(&g, &p);
            let bounds = quality::boundary_nodes(&g, &p);
            println!(
                "{}: time {}, peak mem {}, edge cut {} ({:.1}%), max boundary {}",
                p.method,
                heta::util::fmt_secs(p.elapsed_s),
                heta::util::fmt_bytes(p.peak_mem_bytes),
                cut,
                cut as f64 / g.num_edges() as f64 * 100.0,
                bounds.iter().max().unwrap()
            );
        }
        other => bail!("unknown method {other}"),
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(rt) = args.get("runtime") {
        cfg.train.runtime = heta::config::RuntimeKind::parse(rt)
            .with_context(|| format!("unknown runtime '{rt}' (sequential|cluster)"))?;
    }
    if args.has_flag("no-pipeline") {
        cfg.train.pipeline = false;
    }
    if args.has_flag("no-dedup-fetch") {
        cfg.train.dedup_fetch = false;
    }
    if args.has_flag("shared-session") {
        // Escape hatch: serialize artifact execution on one token,
        // reproducing the pre-exec-layer shared-session behavior.
        cfg.train.shared_session = true;
    }
    if let Some(s) = args.get("staleness") {
        // Bounded-staleness window of the async 1F1B pipeline: 0 is the
        // synchronous protocol, k keeps up to k extra batches in flight
        // (cluster runtime only; see TrainConfig::staleness).
        cfg.train.staleness = s
            .parse()
            .with_context(|| format!("--staleness expects a non-negative integer, got '{s}'"))?;
        if cfg.train.staleness > 0 && !cfg.train.dedup_fetch {
            bail!("--staleness requires the dedup gather (drop --no-dedup-fetch)");
        }
    }
    if let Some(t) = args.get("transport") {
        cfg.train.transport = TransportKind::parse(t)
            .with_context(|| format!("unknown transport '{t}' (channel|tcp)"))?;
    }
    let level = args.get_or("log-level", "info");
    heta::obs::set_log_level(
        heta::obs::LogLevel::parse(&level)
            .with_context(|| format!("unknown log level '{level}' (error|warn|info|debug)"))?,
    );
    // `--trace out.json` names the Chrome-trace file; a bare `--trace`
    // picks a default. Either form flips `train.trace` on for this rank
    // (workers record and ship their buffers; only the leader exports).
    let trace_path = args
        .get("trace")
        .map(str::to_string)
        .or_else(|| args.has_flag("trace").then(|| format!("TRACE_{}.json", cfg.name)));
    if trace_path.is_some() {
        cfg.train.trace = true;
    }
    let backend = match cfg.train.transport {
        TransportKind::Channel => heta::net::Backend::Channel,
        TransportKind::Tcp => {
            // One process per rank: this invocation plays exactly one.
            if cfg.train.runtime != RuntimeKind::Cluster {
                if args.get("runtime").is_some() {
                    bail!("--transport tcp needs --runtime cluster");
                }
                cfg.train.runtime = RuntimeKind::Cluster;
            }
            let parts = cfg.train.num_partitions;
            let rank: usize = args
                .get("rank")
                .context("--transport tcp needs --rank R (0 = leader, 1..=K = workers)")?
                .parse()
                .context("--rank expects a non-negative integer")?;
            ensure!(
                rank <= parts,
                "--rank {rank} outside this {parts}-partition cluster (0 = leader, 1..={parts})"
            );
            let peers = args
                .get("peers")
                .context("--transport tcp needs --peers host:port[,...] (first entry = leader)")?;
            let leader_addr = peers
                .split(',')
                .next()
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .context("--peers must name the leader's host:port first")?;
            heta::obs::set_log_rank(rank as i64);
            let node = if rank == 0 {
                heta::log!(Info, "leader: listening on {leader_addr} for {parts} workers");
                heta::net::tcp::listen(leader_addr, parts)?
            } else {
                heta::net::tcp::dial(leader_addr, rank - 1, parts, heta::net::tcp::DIAL_TIMEOUT)?
            };
            heta::net::Backend::Tcp(node)
        }
    };
    let engine = args.get_or("engine", "raf");
    let epochs = args.get_usize("epochs", 1);
    let artifacts = args.get_or("artifacts", &format!("artifacts/{}", cfg.name));
    let worker_rank = backend.is_tcp_worker();
    let report =
        heta::coordinator::run_training_with(&cfg, &artifacts, &engine, epochs, backend)?;
    if worker_rank {
        // Worker ranks own no trajectory (their reports carry wire
        // traffic only); the leader prints the real summary.
        heta::log!(
            Info,
            "[{}/{}] worker rank done: {} epochs, wire {} sent / {} received",
            cfg.name,
            engine,
            epochs,
            heta::util::fmt_bytes(report.wire.real_sent),
            heta::util::fmt_bytes(report.wire.real_recv),
        );
    } else {
        report.print(&format!(
            "{}/{}/{}/{}",
            cfg.name,
            engine,
            cfg.train.runtime.name(),
            cfg.train.transport.name(),
        ));
        if let Some(path) = &trace_path {
            heta::obs::export_chrome(&report.obs, path)?;
            heta::log!(Info, "trace written to {path} (open in Perfetto or chrome://tracing)");
        }
    }
    Ok(())
}

/// Spawn a local TCP cluster of this very binary — one leader plus `K`
/// worker processes on a loopback port — forward the training flags to
/// every rank, and reap them. The multi-machine path is the same
/// `train --transport tcp` invocation with real hostnames.
fn cmd_launch(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let parts = cfg.train.num_partitions;
    // `-n K`: single-dash flags land in positionals; accept `--n K` too.
    let n = args
        .get("n")
        .map(|v| v.parse::<usize>().context("-n expects a worker count"))
        .transpose()?
        .or_else(|| {
            let pos = &args.positional;
            pos.iter()
                .position(|a| a == "-n")
                .and_then(|i| pos.get(i + 1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(parts);
    ensure!(
        n == parts,
        "launch -n {n} but the config trains {parts} partitions — set \
         train.num_partitions = {n} (every rank derives its role from the config)"
    );
    let port = match args.get_usize("port", 0) {
        0 => 20000 + (std::process::id() as usize % 20000), // avoid collisions between runs
        p => p,
    };
    let addr = format!("127.0.0.1:{port}");
    let exe = std::env::current_exe().context("resolving the heta binary path")?;

    let mut forwarded: Vec<String> = vec![
        "train".into(),
        "--transport".into(),
        "tcp".into(),
        "--runtime".into(),
        "cluster".into(),
        "--peers".into(),
        addr.clone(),
    ];
    for key in ["config", "engine", "epochs", "artifacts", "staleness", "trace", "log-level"] {
        if let Some(v) = args.get(key) {
            forwarded.push(format!("--{key}"));
            forwarded.push(v.to_string());
        }
    }
    for flag in ["no-pipeline", "no-dedup-fetch", "shared-session", "trace"] {
        if args.has_flag(flag) {
            forwarded.push(format!("--{flag}"));
        }
    }
    if let Some(lvl) = args.get("log-level") {
        heta::obs::set_log_level(
            heta::obs::LogLevel::parse(lvl)
                .with_context(|| format!("unknown log level '{lvl}' (error|warn|info|debug)"))?,
        );
    }

    heta::log!(Info, "launch: {} ranks (leader + {n} workers) on {addr}", n + 1);
    let mut children = Vec::with_capacity(n + 1);
    for rank in 0..=n {
        let child = std::process::Command::new(&exe)
            .args(&forwarded)
            .arg("--rank")
            .arg(rank.to_string())
            .spawn()
            .with_context(|| format!("spawning rank {rank}"))?;
        heta::log!(Info, "launch: rank {rank} -> pid {}", child.id());
        children.push((rank, child));
    }
    // Reap every rank. A crashed worker unblocks the others through the
    // transport's hangup-as-error semantics, so plain waits suffice.
    let mut failed: Vec<usize> = Vec::new();
    for (rank, mut child) in children {
        let status = child
            .wait()
            .with_context(|| format!("waiting on rank {rank}"))?;
        if !status.success() {
            heta::log!(Error, "launch: rank {rank} exited with {status}");
            failed.push(rank);
        }
    }
    if !failed.is_empty() {
        bail!("launch: rank(s) {failed:?} failed — see their output above");
    }
    heta::log!(Info, "launch: all {} ranks exited cleanly", n + 1);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let g = cfg.build_graph();
    println!("dataset {} (preset {}, scale {})", cfg.name, g.schema.name, cfg.dataset.scale);
    println!(
        "  {} nodes / {} node types, {} edges / {} relations, {} classes",
        g.num_nodes(),
        g.schema.node_types.len(),
        g.num_edges(),
        g.schema.relations.len(),
        g.schema.num_classes
    );
    for (i, t) in g.schema.node_types.iter().enumerate() {
        println!(
            "  type {i} {:<10} count {:<8} dim {:<5} {}",
            t.name,
            t.count,
            t.feat_dim,
            if t.learnable { "learnable" } else { "featured" }
        );
    }
    println!(
        "  storage (fp16 features): {}",
        heta::util::fmt_bytes(g.storage_bytes(2))
    );
    Ok(())
}
