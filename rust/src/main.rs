//! `heta` — launcher CLI for the Heta reproduction.
//!
//! Subcommands:
//!   plan       --config <file> --out <plan.json>   emit the AOT artifact plan
//!   partition  --config <file> [--method m]        run + report a partitioning
//!   train      --config <file> --engine raf|vanilla [--epochs n]
//!   serve      --config <file> [--engine raf|vanilla] [--qps Q]    deadline-driven serving
//!   launch     --config <file> [-n K]              spawn a local K-worker TCP cluster
//!   analyze    TRACE.json [--baseline T.json]      trace analytics (stalls, critical path)
//!   bench-gate --current B.json --baseline B.json  perf-regression gate
//!   info       --config <file>                     dataset/schema summary
//!
//! `plan` is the build-time half of the Rust↔Python contract: it computes
//! the metatree, meta-partitioning and padded block shapes that
//! `python/compile/aot.py` lowers into HLO artifacts.
//!
//! `train --transport tcp --rank R --peers host:port` runs **one rank**
//! of a multi-process cluster (rank 0 is the leader and listens on the
//! first peers entry; ranks 1..=K are the partition workers and dial
//! in). `launch` is the local convenience wrapper: it spawns rank 0
//! plus K workers of the same binary on a loopback port and reaps them.

use anyhow::{bail, ensure, Context, Result};
use heta::config::{build_plan, Config, RuntimeKind, TransportKind};
use heta::partition::{edgecut, meta::meta_partition, metis_like, quality};
use heta::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    match cmd {
        "plan" => cmd_plan(&args),
        "partition" => cmd_partition(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "launch" => cmd_launch(&args),
        "analyze" => cmd_analyze(&args),
        "bench-gate" => cmd_bench_gate(&args),
        "info" => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: heta <plan|partition|train|serve|launch|analyze|bench-gate|info> \
                 --config <cfg.json> [options]\n\
                 \n\
                 plan       --out <plan.json>      emit AOT artifact plan\n\
                 partition  [--method meta|random|metis|bytype] [--parts p]\n\
                 train      --engine raf|vanilla [--epochs n] [--artifacts dir]\n\
                 \x20          [--runtime sequential|cluster] [--no-pipeline]\n\
                 \x20          [--no-dedup-fetch] [--shared-session] [--staleness N]\n\
                 \x20          [--transport channel|tcp --rank R --peers host:port[,...]]\n\
                 \x20          [--wire-snapshots full|diff] [--wire-exchange star|mesh]\n\
                 \x20          [--checkpoint-dir dir] [--resume]\n\
                 \x20          [--hb-interval-ms N] [--hb-timeout-ms N]\n\
                 \x20          [--fail rank:batch:kind[:epoch]]  (kind: exit|stall|\n\
                 \x20          drop-conn|corrupt-frame; rank 1..=K)\n\
                 \x20          [--trace [out.json]] [--log-level error|warn|info|debug]\n\
                 \x20          [--log-format human|json] [--metrics-addr host:port]\n\
                 serve      [--engine raf|vanilla] [--requests N] [--qps Q]\n\
                 \x20          [--deadline-ms D] [--zipf A] [--request-trace file]\n\
                 \x20          [--no-reuse] [--no-dedup-fetch] [--embed-cache N]\n\
                 \x20          [--service-bound-ms B] [--artifacts dir] [--loopback]\n\
                 \x20          [--transport tcp --rank R --peers host:port[,...]]\n\
                 \x20          [--log-level error|warn|info|debug]\n\
                 \x20          [--log-format human|json] [--metrics-addr host:port]\n\
                 launch     [-n K] [--port P] [--max-restarts R] + train options:\n\
                 \x20          spawn leader + K worker processes over loopback TCP,\n\
                 \x20          reap them, and (with --checkpoint-dir) respawn the\n\
                 \x20          cluster with --resume after a rank dies\n\
                 \x20          [--hosts h0,h1,...] place rank i on hosts[i mod len]\n\
                 \x20          (leader on hosts[0]; non-local hosts spawn via ssh)\n\
                 \x20          [--spawn-shell cmd] shell that execs each spawn line\n\
                 \x20          (default '/bin/sh -c'; try 'echo' for a dry run)\n\
                 \x20          [--metrics-addr host:port] rank r serves on port+r\n\
                 analyze    TRACE.json [--baseline OTHER.json] [--tolerance T]\n\
                 \x20          [--json]: per-rank/per-lane stall rollups, top stalls,\n\
                 \x20          critical path; with --baseline, exits 1 on regression\n\
                 bench-gate --current BENCH_x.json --baseline baselines/BENCH_x.json\n\
                 \x20          [--tolerance 0.15]: directional perf gate, exits 1\n\
                 \x20          when any matched metric regresses past the tolerance\n\
                 info"
            );
            Ok(())
        }
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let path = args
        .get("config")
        .context("--config <file> is required")?;
    Config::load(path)
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out = args.get("out").context("--out <plan.json> is required")?;
    let g = cfg.build_graph();
    let (mp, tree) = meta_partition(&g, cfg.train.num_partitions, cfg.model.layers, None);
    let plan = build_plan(&cfg, &g, &tree, &mp);
    if let Some(dir) = std::path::Path::new(out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(out, plan.to_string())?;
    println!(
        "plan '{}': {} tree edges, {} partitions -> {}",
        cfg.name,
        tree.edges.len(),
        cfg.train.num_partitions,
        out
    );
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let g = cfg.build_graph();
    let parts = args.get_usize("parts", cfg.train.num_partitions);
    let method = args.get_or("method", "meta");
    match method.as_str() {
        "meta" => {
            let (mp, tree) = meta_partition(&g, parts, cfg.model.layers, None);
            println!(
                "meta-partitioning: {} sub-metatrees over {} partitions in {}",
                tree.sub_metatrees().len(),
                parts,
                heta::util::fmt_secs(mp.elapsed_s)
            );
            for p in 0..parts {
                println!(
                    "  partition {p}: {} relations, load {}, topo {}",
                    mp.rels_per_part[p].len(),
                    mp.part_load(&g, p),
                    heta::util::fmt_bytes(mp.part_topology_bytes(&g, p))
                );
            }
        }
        m @ ("random" | "metis" | "bytype") => {
            let p = match m {
                "random" => edgecut::random(&g, parts, cfg.train.seed),
                "metis" => metis_like::metis_like(&g, parts, cfg.train.seed),
                _ => edgecut::by_type(&g, parts, cfg.train.seed),
            };
            let cut = quality::edge_cut(&g, &p);
            let bounds = quality::boundary_nodes(&g, &p);
            println!(
                "{}: time {}, peak mem {}, edge cut {} ({:.1}%), max boundary {}",
                p.method,
                heta::util::fmt_secs(p.elapsed_s),
                heta::util::fmt_bytes(p.peak_mem_bytes),
                cut,
                cut as f64 / g.num_edges() as f64 * 100.0,
                bounds.iter().max().unwrap()
            );
        }
        other => bail!("unknown method {other}"),
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    if let Some(rt) = args.get("runtime") {
        cfg.train.runtime = heta::config::RuntimeKind::parse(rt)
            .with_context(|| format!("unknown runtime '{rt}' (sequential|cluster)"))?;
    }
    if args.has_flag("no-pipeline") {
        cfg.train.pipeline = false;
    }
    if args.has_flag("no-dedup-fetch") {
        cfg.train.dedup_fetch = false;
    }
    if args.has_flag("shared-session") {
        // Escape hatch: serialize artifact execution on one token,
        // reproducing the pre-exec-layer shared-session behavior.
        cfg.train.shared_session = true;
    }
    if let Some(s) = args.get("staleness") {
        // Bounded-staleness window of the async 1F1B pipeline: 0 is the
        // synchronous protocol, k keeps up to k extra batches in flight
        // (cluster runtime only; see TrainConfig::staleness).
        cfg.train.staleness = s
            .parse()
            .with_context(|| format!("--staleness expects a non-negative integer, got '{s}'"))?;
        if cfg.train.staleness > 0 && !cfg.train.dedup_fetch {
            bail!("--staleness requires the dedup gather (drop --no-dedup-fetch)");
        }
    }
    if let Some(t) = args.get("transport") {
        cfg.train.transport = TransportKind::parse(t)
            .with_context(|| format!("unknown transport '{t}' (channel|tcp)"))?;
    }
    if let Some(s) = args.get("wire-snapshots") {
        cfg.train.wire_snapshots = heta::config::WireSnapshots::parse(s)
            .with_context(|| format!("unknown wire-snapshots '{s}' (full|diff)"))?;
    }
    if let Some(s) = args.get("wire-exchange") {
        cfg.train.wire_exchange = heta::config::WireExchange::parse(s)
            .with_context(|| format!("unknown wire-exchange '{s}' (star|mesh)"))?;
    }
    if let Some(s) = args.get("fail") {
        // Deterministic fault injection: every rank receives the same
        // spec and only the rank it names fires (see FaultSpec).
        cfg.train.fail = Some(heta::config::FaultSpec::parse(s)?);
    }
    if let Some(v) = args.get("hb-interval-ms") {
        cfg.train.hb_interval_ms = v
            .parse()
            .with_context(|| format!("--hb-interval-ms expects milliseconds, got '{v}'"))?;
    }
    if let Some(v) = args.get("hb-timeout-ms") {
        cfg.train.hb_timeout_ms = v
            .parse()
            .with_context(|| format!("--hb-timeout-ms expects milliseconds, got '{v}'"))?;
    }
    let ckpt = args.get("checkpoint-dir").map(|d| heta::coordinator::CkptOpts {
        dir: d.to_string(),
        resume: args.has_flag("resume"),
    });
    if args.has_flag("resume") && ckpt.is_none() {
        bail!("--resume needs --checkpoint-dir <dir> to resume from");
    }
    let level = args.get_or("log-level", "info");
    heta::obs::set_log_level(
        heta::obs::LogLevel::parse(&level)
            .with_context(|| format!("unknown log level '{level}' (error|warn|info|debug)"))?,
    );
    if let Some(f) = args.get("log-format") {
        heta::obs::set_log_format(
            heta::obs::LogFormat::parse(f)
                .with_context(|| format!("unknown log format '{f}' (human|json)"))?,
        );
    }
    // `--metrics-addr host:port` arms this rank's live telemetry plane
    // (/metrics, /healthz, /buildinfo on a detached thread). Armed
    // *before* the transport handshake so the heartbeat monitor's
    // per-peer liveness taps register with /healthz.
    if let Some(addr) = args.get("metrics-addr") {
        let rank: i64 = match cfg.train.transport {
            TransportKind::Tcp => args
                .get("rank")
                .context("--metrics-addr over --transport tcp needs --rank to label the scrape")?
                .parse()
                .context("--rank expects a non-negative integer")?,
            TransportKind::Channel => 0,
        };
        let role = if rank == 0 { "leader" } else { "worker" };
        heta::obs::http::start(addr, rank, role)?;
    }
    // `--trace out.json` names the Chrome-trace file; a bare `--trace`
    // picks a default. Either form flips `train.trace` on for this rank
    // (workers record and ship their buffers; only the leader exports).
    let trace_path = args
        .get("trace")
        .map(str::to_string)
        .or_else(|| args.has_flag("trace").then(|| format!("TRACE_{}.json", cfg.name)));
    if trace_path.is_some() {
        cfg.train.trace = true;
    }
    let backend = match cfg.train.transport {
        TransportKind::Channel => heta::net::Backend::Channel,
        TransportKind::Tcp => {
            // One process per rank: this invocation plays exactly one.
            if cfg.train.runtime != RuntimeKind::Cluster {
                if args.get("runtime").is_some() {
                    bail!("--transport tcp needs --runtime cluster");
                }
                cfg.train.runtime = RuntimeKind::Cluster;
            }
            let parts = cfg.train.num_partitions;
            let rank: usize = args
                .get("rank")
                .context("--transport tcp needs --rank R (0 = leader, 1..=K = workers)")?
                .parse()
                .context("--rank expects a non-negative integer")?;
            ensure!(
                rank <= parts,
                "--rank {rank} outside this {parts}-partition cluster (0 = leader, 1..={parts})"
            );
            let peers = args
                .get("peers")
                .context("--transport tcp needs --peers host:port[,...] (first entry = leader)")?;
            let leader_addr = peers
                .split(',')
                .next()
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .context("--peers must name the leader's host:port first")?;
            heta::obs::set_log_rank(rank as i64);
            let hb = heta::net::tcp::HbCfg::from_train(&cfg.train);
            // A mesh config changes the star handshake on *both* sides
            // (the leader brokers the worker↔worker table), so every
            // rank must pick the matching entry point from its config.
            let mesh = cfg.train.wire_exchange.is_mesh();
            let node = if rank == 0 {
                heta::log!(
                    Info,
                    "leader: listening on {leader_addr} for {parts} workers ({} exchange)",
                    cfg.train.wire_exchange.name()
                );
                if mesh {
                    heta::net::tcp::listen_mesh_with(leader_addr, parts, hb)?
                } else {
                    heta::net::tcp::listen_with(leader_addr, parts, hb)?
                }
            } else {
                let dial = if mesh {
                    heta::net::tcp::dial_mesh_with
                } else {
                    heta::net::tcp::dial_with
                };
                dial(leader_addr, rank - 1, parts, heta::net::tcp::DIAL_TIMEOUT, hb)?
            };
            heta::net::Backend::Tcp(node)
        }
    };
    let engine = args.get_or("engine", "raf");
    let epochs = args.get_usize("epochs", 1);
    let artifacts = args.get_or("artifacts", &format!("artifacts/{}", cfg.name));
    let worker_rank = backend.is_tcp_worker();
    let report = heta::coordinator::run_training_ckpt(
        &cfg,
        &artifacts,
        &engine,
        epochs,
        backend,
        ckpt.as_ref(),
    )?;
    if worker_rank {
        // Worker ranks own no trajectory (their reports carry wire
        // traffic only); the leader prints the real summary.
        heta::log!(
            Info,
            "[{}/{}] worker rank done: {} epochs, wire {} sent / {} received \
             (mesh lane {} sent / {} received)",
            cfg.name,
            engine,
            epochs,
            heta::util::fmt_bytes(report.wire.real_sent),
            heta::util::fmt_bytes(report.wire.real_recv),
            heta::util::fmt_bytes(report.wire.mesh_sent),
            heta::util::fmt_bytes(report.wire.mesh_recv),
        );
    } else {
        report.print(&format!(
            "{}/{}/{}/{}",
            cfg.name,
            engine,
            cfg.train.runtime.name(),
            cfg.train.transport.name(),
        ));
        if let Some(path) = &trace_path {
            heta::obs::export_chrome(&report.obs, path)?;
            heta::log!(Info, "trace written to {path} (open in Perfetto or chrome://tracing)");
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let level = args.get_or("log-level", "info");
    heta::obs::set_log_level(
        heta::obs::LogLevel::parse(&level)
            .with_context(|| format!("unknown log level '{level}' (error|warn|info|debug)"))?,
    );
    if let Some(f) = args.get("log-format") {
        heta::obs::set_log_format(
            heta::obs::LogFormat::parse(f)
                .with_context(|| format!("unknown log format '{f}' (human|json)"))?,
        );
    }
    let engine = args.get_or("engine", "raf");
    let system = heta::coordinator::SystemKind::parse(&engine)
        .with_context(|| format!("unknown engine '{engine}' (raf|vanilla)"))?;
    let artifacts = args.get_or("artifacts", &format!("artifacts/{}", cfg.name));
    let opts = heta::serve::ServeOpts {
        requests: args.get_usize("requests", 256),
        qps: args.get_f64("qps", 200.0),
        deadline_ms: args.get_f64("deadline-ms", 50.0),
        zipf_alpha: args.get_f64("zipf", 1.1),
        trace_path: args.get("request-trace").map(str::to_string),
        reuse: !args.has_flag("no-reuse"),
        dedup_fetch: !args.has_flag("no-dedup-fetch"),
        embed_cap: args.get_usize("embed-cache", 4096),
        service_bound_ms: args.get_f64("service-bound-ms", 0.0),
    };
    ensure!(
        opts.deadline_ms > 0.0 && opts.qps > 0.0,
        "--deadline-ms and --qps must be positive"
    );
    // Same telemetry plane as `train`: armed before any transport or
    // loopback machinery so the serve SLO families (`serve.latency_ms`,
    // `serve.deadline_miss_total`, `serve.qps`) tick live from the
    // batcher and a mid-run scrape sees them grow.
    if let Some(addr) = args.get("metrics-addr") {
        let rank: i64 = match args.get("transport") {
            Some("tcp") => args
                .get("rank")
                .context("--metrics-addr over --transport tcp needs --rank to label the scrape")?
                .parse()
                .context("--rank expects a non-negative integer")?,
            _ => 0,
        };
        let role = if rank == 0 { "leader" } else { "worker" };
        heta::obs::http::start(addr, rank, role)?;
    }
    if args.has_flag("loopback") {
        // One process, one OS thread per rank, real sockets on an
        // ephemeral loopback port — the CI smoke path.
        let rep = heta::serve::run_loopback_tcp_serve(&cfg, &artifacts, system, &opts)?;
        rep.print(&format!("{}/{}/loopback-tcp", cfg.name, engine));
        return Ok(());
    }
    let backend = match args.get("transport") {
        None | Some("channel") => heta::net::Backend::Channel,
        Some("tcp") => {
            // One process per rank, exactly like `train --transport tcp`
            // (the serving star has no mesh lane — responses are
            // leader-composed).
            let parts = cfg.train.num_partitions;
            let rank: usize = args
                .get("rank")
                .context("--transport tcp needs --rank R (0 = leader, 1..=K = workers)")?
                .parse()
                .context("--rank expects a non-negative integer")?;
            ensure!(
                rank <= parts,
                "--rank {rank} outside this {parts}-partition cluster (0 = leader, 1..={parts})"
            );
            let peers = args
                .get("peers")
                .context("--transport tcp needs --peers host:port[,...] (first entry = leader)")?;
            let leader_addr = peers
                .split(',')
                .next()
                .map(str::trim)
                .filter(|a| !a.is_empty())
                .context("--peers must name the leader's host:port first")?;
            heta::obs::set_log_rank(rank as i64);
            let node = if rank == 0 {
                heta::log!(Info, "serve leader: listening on {leader_addr} for {parts} workers");
                heta::net::tcp::listen(leader_addr, parts)?
            } else {
                heta::net::tcp::dial(leader_addr, rank - 1, parts, heta::net::tcp::DIAL_TIMEOUT)?
            };
            heta::net::Backend::Tcp(node)
        }
        Some(other) => bail!("unknown transport '{other}' (channel|tcp)"),
    };
    let worker_rank = backend.is_tcp_worker();
    let rep = heta::serve::run_serve(&cfg, &artifacts, system, &opts, backend)?;
    if worker_rank {
        heta::log!(
            Info,
            "[{}/{}] serve worker rank done: wire {} sent / {} received",
            cfg.name,
            engine,
            heta::util::fmt_bytes(rep.wire.real_sent),
            heta::util::fmt_bytes(rep.wire.real_recv),
        );
    } else {
        rep.print(&format!("{}/{}", cfg.name, engine));
    }
    Ok(())
}

/// How long surviving ranks get to unwind on their own after the first
/// rank of an attempt fails, before the launcher kills them. Normally
/// hangup-as-error and the heartbeat timeout tear the cluster down in
/// well under this; the kill is the backstop that keeps `heta launch`
/// from ever hanging on a wedged rank.
const REAP_GRACE: std::time::Duration = std::time::Duration::from_secs(15);

/// Poll every child until all have exited; returns the ranks that
/// exited nonzero (sorted). On the first failure the survivors get
/// [`REAP_GRACE`] to unwind through the transport's hangup-as-error
/// semantics, then whatever is left is killed and counted failed.
fn reap_cluster(children: &mut [(usize, std::process::Child)]) -> Result<Vec<usize>> {
    let mut failed: Vec<usize> = Vec::new();
    let mut done = vec![false; children.len()];
    let mut live = children.len();
    let mut first_failure: Option<std::time::Instant> = None;
    while live > 0 {
        for (i, (rank, child)) in children.iter_mut().enumerate() {
            if done[i] {
                continue;
            }
            let polled = child
                .try_wait()
                .with_context(|| format!("waiting on rank {rank}"))?;
            if let Some(status) = polled {
                done[i] = true;
                live -= 1;
                if !status.success() {
                    heta::log!(Error, "launch: rank {rank} exited with {status}");
                    failed.push(*rank);
                    first_failure.get_or_insert_with(std::time::Instant::now);
                }
            }
        }
        if live == 0 {
            break;
        }
        if let Some(t0) = first_failure {
            if t0.elapsed() > REAP_GRACE {
                for (i, (rank, child)) in children.iter_mut().enumerate() {
                    if done[i] {
                        continue;
                    }
                    heta::log!(
                        Error,
                        "launch: rank {rank} still running {}s after the first failure — killing it",
                        REAP_GRACE.as_secs()
                    );
                    let _ = child.kill();
                    let _ = child.wait();
                    done[i] = true;
                    live -= 1;
                    failed.push(*rank);
                }
                break;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    failed.sort_unstable();
    Ok(failed)
}

/// Hosts that mean "this machine" for `--hosts` placement: they spawn
/// through the local spawn shell instead of an `ssh` prefix.
fn is_local_host(host: &str) -> bool {
    matches!(host, "local" | "localhost" | "127.0.0.1" | "::1")
}

/// Single-quote `arg` for a POSIX shell (and for the remote side of an
/// `ssh host '<line>'` hop), escaping embedded single quotes. Plain
/// words — the common case: paths, numbers, flag names — pass through
/// unquoted so the printed spawn line stays readable.
fn shell_quote(arg: &str) -> String {
    let plain = !arg.is_empty()
        && arg
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || "_-./:=,+@%".contains(c));
    if plain {
        arg.to_string()
    } else {
        format!("'{}'", arg.replace('\'', r"'\''"))
    }
}

/// Spawn a local TCP cluster of this very binary — one leader plus `K`
/// worker processes on a loopback port — forward the training flags to
/// every rank, and reap them. The multi-machine path is the same
/// `train --transport tcp` invocation with real hostnames.
///
/// With `--checkpoint-dir`, the launcher is also the recovery
/// supervisor: when any rank dies, the remaining ranks are reaped
/// (killed past a grace window), and the whole cluster is respawned
/// with `--resume` — and without `--fail`, so an injected fault fires
/// exactly once — resuming from the last epoch-boundary checkpoint.
/// `--max-restarts R` caps the respawns (default 2).
fn cmd_launch(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let parts = cfg.train.num_partitions;
    // `-n K`: single-dash flags land in positionals; accept `--n K` too.
    let n = args
        .get("n")
        .map(|v| v.parse::<usize>().context("-n expects a worker count"))
        .transpose()?
        .or_else(|| {
            let pos = &args.positional;
            pos.iter()
                .position(|a| a == "-n")
                .and_then(|i| pos.get(i + 1))
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(parts);
    ensure!(
        n == parts,
        "launch -n {n} but the config trains {parts} partitions — set \
         train.num_partitions = {n} (every rank derives its role from the config)"
    );
    let base_port = match args.get_usize("port", 0) {
        0 => 20000 + (std::process::id() as usize % 20000), // avoid collisions between runs
        p => p,
    };
    let exe = std::env::current_exe().context("resolving the heta binary path")?;

    let mut forwarded: Vec<String> = vec![
        "train".into(),
        "--transport".into(),
        "tcp".into(),
        "--runtime".into(),
        "cluster".into(),
    ];
    for key in [
        "config",
        "engine",
        "epochs",
        "artifacts",
        "staleness",
        "trace",
        "log-level",
        "log-format",
        "checkpoint-dir",
        "hb-interval-ms",
        "hb-timeout-ms",
        "wire-snapshots",
        "wire-exchange",
    ] {
        if let Some(v) = args.get(key) {
            forwarded.push(format!("--{key}"));
            forwarded.push(v.to_string());
        }
    }
    for flag in ["no-pipeline", "no-dedup-fetch", "shared-session", "trace"] {
        if args.has_flag(flag) {
            forwarded.push(format!("--{flag}"));
        }
    }
    if let Some(lvl) = args.get("log-level") {
        heta::obs::set_log_level(
            heta::obs::LogLevel::parse(lvl)
                .with_context(|| format!("unknown log level '{lvl}' (error|warn|info|debug)"))?,
        );
    }
    let fail_spec = args.get("fail").map(str::to_string);
    if let Some(s) = &fail_spec {
        // Validate here so a typo fails the launcher, not K+1 children.
        heta::config::FaultSpec::parse(s)?;
    }
    // `--metrics-addr host:port`: every rank is its own process and
    // needs its own listener, so rank r scrapes on port + r.
    let metrics_addr: Option<(String, u16)> = args
        .get("metrics-addr")
        .map(|a| -> Result<(String, u16)> {
            let (host, port) = a
                .rsplit_once(':')
                .context("--metrics-addr expects host:port")?;
            let port: u16 = port
                .parse()
                .with_context(|| format!("--metrics-addr port must be numeric, got '{port}'"))?;
            ensure!(
                (port as usize) + n <= u16::MAX as usize,
                "--metrics-addr port {port} + {n} worker ranks overflows the port space"
            );
            Ok((host.to_string(), port))
        })
        .transpose()?;
    // `--hosts h0,h1,...`: place rank i on hosts[i % len] (the leader,
    // rank 0, always lands on hosts[0], which every rank dials). Local
    // entries spawn through `--spawn-shell`; anything else gets an
    // `ssh <host>` prefix. This is the multi-machine stub: the spawn
    // line is printed before it runs, and `--spawn-shell echo` turns
    // the whole launch into a dry run you can paste onto real machines.
    let hosts: Option<Vec<String>> = args.get("hosts").map(|h| {
        h.split(',').map(str::trim).filter(|s| !s.is_empty()).map(str::to_string).collect()
    });
    if let Some(hs) = &hosts {
        ensure!(!hs.is_empty(), "--hosts needs at least one host");
    }
    let spawn_shell = args.get_or("spawn-shell", "/bin/sh -c");
    ensure!(
        !spawn_shell.trim().is_empty(),
        "--spawn-shell must name a program (default '/bin/sh -c')"
    );
    let recovery = args.get("checkpoint-dir").is_some();
    ensure!(
        fail_spec.is_none() || recovery,
        "--fail without --checkpoint-dir would kill the cluster with no way back — \
         add --checkpoint-dir <dir> so the launcher can recover it"
    );
    let max_attempts = if recovery { args.get_usize("max-restarts", 2) + 1 } else { 1 };

    for attempt in 1..=max_attempts {
        // A fresh port per attempt: the previous leader's accepted
        // connections linger in TIME_WAIT on the old port, and the
        // respawned leader must bind immediately.
        let leader_host = hosts.as_ref().map(|h| h[0].as_str()).unwrap_or("127.0.0.1");
        let addr = format!("{leader_host}:{}", base_port + attempt - 1);
        let mut argv = forwarded.clone();
        argv.push("--peers".into());
        argv.push(addr.clone());
        if attempt == 1 {
            if args.has_flag("resume") {
                argv.push("--resume".into());
            }
            if let Some(s) = &fail_spec {
                argv.push("--fail".into());
                argv.push(s.clone());
            }
        } else {
            // Respawn resumes from the checkpoint and drops the fault
            // spec — an injected fault fires exactly once per launch.
            argv.push("--resume".into());
        }
        heta::log!(
            Info,
            "launch: attempt {attempt}/{max_attempts}: {} ranks (leader + {n} workers) on {addr}",
            n + 1
        );
        let mut children = Vec::with_capacity(n + 1);
        for rank in 0..=n {
            let mut rank_args = vec!["--rank".to_string(), rank.to_string()];
            if let Some((host, port)) = &metrics_addr {
                rank_args.push("--metrics-addr".into());
                rank_args.push(format!("{host}:{}", port + rank as u16));
            }
            let child = if let Some(hs) = &hosts {
                let host = hs[rank % hs.len()].as_str();
                let mut line = shell_quote(&exe.to_string_lossy());
                for a in argv.iter().chain(rank_args.iter()) {
                    line.push(' ');
                    line.push_str(&shell_quote(a));
                }
                let cmd = if is_local_host(host) {
                    line
                } else {
                    format!("ssh {host} {}", shell_quote(&line))
                };
                let mut words = spawn_shell.split_whitespace();
                let prog = words.next().context("--spawn-shell must name a program")?;
                heta::log!(Info, "launch: rank {rank} on {host}: {spawn_shell} {cmd}");
                std::process::Command::new(prog)
                    .args(words)
                    .arg(&cmd)
                    .spawn()
                    .with_context(|| format!("spawning rank {rank} on {host} via {spawn_shell}"))?
            } else {
                std::process::Command::new(&exe)
                    .args(&argv)
                    .args(&rank_args)
                    .spawn()
                    .with_context(|| format!("spawning rank {rank}"))?
            };
            heta::log!(Info, "launch: rank {rank} -> pid {}", child.id());
            children.push((rank, child));
        }
        let failed = reap_cluster(&mut children)?;
        if failed.is_empty() {
            heta::log!(Info, "launch: all {} ranks exited cleanly", n + 1);
            return Ok(());
        }
        if attempt == max_attempts {
            bail!("launch: rank(s) {failed:?} failed — see their output above");
        }
        let backoff = restart_backoff_ms(attempt);
        heta::log!(
            Warn,
            "launch: rank(s) {failed:?} failed; respawning with --resume in {backoff} ms"
        );
        std::thread::sleep(std::time::Duration::from_millis(backoff));
    }
    bail!("launch: no attempts were made (max-restarts underflow)")
}

/// Restart backoff for launch attempt `attempt` (1-based): exponential
/// from 250 ms, capped at [`MAX_RESTART_BACKOFF_MS`]. The cap also keeps
/// the doubling well-defined for huge `--max-restarts` values — a bare
/// `250 << (attempt - 1)` overflows the shift at attempt 65 (a debug
/// panic, UB-adjacent wrap in release), so saturate once the exponent
/// alone would clear the cap.
const MAX_RESTART_BACKOFF_MS: u64 = 30_000;

fn restart_backoff_ms(attempt: usize) -> u64 {
    debug_assert!(attempt >= 1);
    let exp = attempt.saturating_sub(1);
    if exp >= 7 {
        // 250 << 7 = 32_000 already exceeds the cap; larger exponents
        // would overflow the shift entirely.
        return MAX_RESTART_BACKOFF_MS;
    }
    (250u64 << exp).min(MAX_RESTART_BACKOFF_MS)
}

/// `heta analyze TRACE.json [--baseline OTHER.json] [--tolerance T]
/// [--json]` — offline analytics over a `--trace` export: per-rank and
/// per-lane stall rollups, the top-N longest stalls, and the per-batch
/// critical path. With `--baseline`, regressions past the tolerance
/// (default 15%, with a 1 ms absolute floor) exit nonzero so the
/// command can gate CI.
fn cmd_analyze(args: &Args) -> Result<()> {
    let path = args.positional.get(1).context(
        "usage: heta analyze TRACE.json [--baseline OTHER.json] [--tolerance T] [--json]",
    )?;
    let cur = heta::obs::analyze::analyze_file(path)?;
    ensure!(
        cur.events > 0,
        "{path} holds no complete events — was the run traced (--trace)?"
    );
    if args.has_flag("json") {
        println!("{}", heta::obs::analyze::render_json(&cur));
    } else {
        print!("{}", heta::obs::analyze::render_text(&cur));
    }
    if let Some(base_path) = args.get("baseline") {
        let base = heta::obs::analyze::analyze_file(base_path)?;
        let tol = args.get_f64("tolerance", 0.15);
        let regs = heta::obs::analyze::diff(&cur, &base, tol);
        if regs.is_empty() {
            println!("baseline {base_path}: no regressions past {:.0}%", tol * 100.0);
        } else {
            for r in &regs {
                println!(
                    "REGRESSION rank {} {}: {:.2} ms -> {:.2} ms ({:.2}x baseline)",
                    r.rank,
                    r.kind,
                    r.base_ms,
                    r.cur_ms,
                    r.ratio()
                );
            }
            bail!(
                "analyze: {} rank/kind cell(s) regressed past {:.0}% vs {base_path}",
                regs.len(),
                tol * 100.0
            );
        }
    }
    Ok(())
}

/// `heta bench-gate --current BENCH_x.json --baseline
/// baselines/BENCH_x.json [--tolerance 0.15]` — compare two bench
/// documents leaf-by-leaf with directional judgement (latencies must
/// not grow, rates must not shrink) and exit nonzero on any regression
/// past the tolerance. CI runs this against the committed baselines.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    let cur_path = args
        .get("current")
        .context("--current BENCH_x.json is required")?;
    let base_path = args
        .get("baseline")
        .context("--baseline baselines/BENCH_x.json is required")?;
    let tol = args.get_f64("tolerance", 0.15);
    let load = |p: &str| -> Result<heta::util::json::Json> {
        let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        heta::util::json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e:?}"))
    };
    let report = heta::obs::analyze::bench_gate(&load(cur_path)?, &load(base_path)?, tol)?;
    print!("{}", heta::obs::analyze::render_gate(&report, tol));
    ensure!(
        !report.rows.is_empty(),
        "bench-gate: no metric of {cur_path} matched {base_path} — wrong file pair?"
    );
    if !report.passed() {
        bail!(
            "bench-gate: {} metric(s) regressed past {:.0}% — see FAIL rows above",
            report.failures().len(),
            tol * 100.0
        );
    }
    heta::log!(
        Info,
        "bench-gate: {} metrics within {:.0}% of {base_path}",
        report.rows.len(),
        tol * 100.0
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let g = cfg.build_graph();
    println!("dataset {} (preset {}, scale {})", cfg.name, g.schema.name, cfg.dataset.scale);
    println!(
        "  {} nodes / {} node types, {} edges / {} relations, {} classes",
        g.num_nodes(),
        g.schema.node_types.len(),
        g.num_edges(),
        g.schema.relations.len(),
        g.schema.num_classes
    );
    for (i, t) in g.schema.node_types.iter().enumerate() {
        println!(
            "  type {i} {:<10} count {:<8} dim {:<5} {}",
            t.name,
            t.count,
            t.feat_dim,
            if t.learnable { "learnable" } else { "featured" }
        );
    }
    println!(
        "  storage (fp16 features): {}",
        heta::util::fmt_bytes(g.storage_bytes(2))
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn restart_backoff_schedule_is_capped() {
        // Doubling from 250 ms...
        assert_eq!(restart_backoff_ms(1), 250);
        assert_eq!(restart_backoff_ms(2), 500);
        assert_eq!(restart_backoff_ms(3), 1_000);
        assert_eq!(restart_backoff_ms(7), 16_000);
        // ...saturates at the cap instead of 32 s...
        assert_eq!(restart_backoff_ms(8), MAX_RESTART_BACKOFF_MS);
        // ...and stays there for the attempts that used to overflow the
        // shift (`250u64 << 64` panics in debug builds): --max-restarts
        // 100 must produce a finite, capped schedule.
        for attempt in [9, 64, 65, 100, usize::MAX] {
            assert_eq!(restart_backoff_ms(attempt), MAX_RESTART_BACKOFF_MS);
        }
        // Monotone non-decreasing end to end.
        let sched: Vec<u64> = (1..=80).map(restart_backoff_ms).collect();
        assert!(sched.windows(2).all(|w| w[0] <= w[1]));
    }
}
