//! Leader/worker collectives over the mailbox transport.
//!
//! [`star`] wires `n` worker ranks to one leader rank with a pair of
//! typed meshes (one per direction). The leader's [`Hub`] gathers one
//! contribution per worker — always reassembled in **worker-id order**,
//! never arrival order, which is what keeps floating-point reductions
//! byte-identical under arbitrary thread interleavings — and scatters
//! or broadcasts responses. A `((), ())` star doubles as the
//! leader/worker [`Hub::barrier`].
//!
//! Collectives move data only; the engines charge the modeled cost of
//! each collective through [`crate::comm::SimNet`] with the same calls
//! the sequential runtime makes (see the accounting contract in
//! [`super::mailbox`]).

use anyhow::{bail, ensure, Result};

use super::mailbox::Mailbox;

/// Leader endpoint of a star: receives `U`p messages, sends `D`own.
pub struct Hub<U, D> {
    up: Mailbox<U>,
    down: Mailbox<D>,
    workers: usize,
}

/// Worker endpoint of a star.
pub struct Port<U, D> {
    up: Mailbox<U>,
    down: Mailbox<D>,
    leader: usize,
}

/// Build a star of `workers` worker ranks plus one leader rank.
pub fn star<U: Send, D: Send>(workers: usize) -> (Hub<U, D>, Vec<Port<U, D>>) {
    let (up_hub, up_spokes) = Mailbox::<U>::star(workers);
    let (down_hub, down_spokes) = Mailbox::<D>::star(workers);
    let hub = Hub {
        up: up_hub,
        down: down_hub,
        workers,
    };
    let ports = up_spokes
        .into_iter()
        .zip(down_spokes)
        .map(|(u, d)| Port {
            up: u,
            down: d,
            leader: workers,
        })
        .collect();
    (hub, ports)
}

impl<U: Send, D: Send> Hub<U, D> {
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Collect exactly one contribution per worker, ordered by worker
    /// id. Errors on a hung-up, out-of-range or duplicate sender.
    pub fn gather(&self) -> Result<Vec<U>> {
        let mut slots: Vec<Option<U>> = (0..self.workers).map(|_| None).collect();
        for _ in 0..self.workers {
            let e = self.up.recv()?;
            ensure!(
                e.from < self.workers,
                "gather contribution from unexpected rank {}",
                e.from
            );
            ensure!(
                slots[e.from].is_none(),
                "duplicate gather contribution from worker {}",
                e.from
            );
            slots[e.from] = Some(e.payload);
        }
        let out: Vec<U> = slots.into_iter().flatten().collect();
        ensure!(out.len() == self.workers, "gather lost contributions");
        Ok(out)
    }

    /// Send `items[w]` to worker `w`.
    pub fn scatter(&self, items: Vec<D>) -> Result<()> {
        ensure!(
            items.len() == self.workers,
            "scatter of {} items across {} workers",
            items.len(),
            self.workers
        );
        for (w, item) in items.into_iter().enumerate() {
            self.down.send(w, item)?;
        }
        Ok(())
    }

    /// Send a copy of `item` to every worker.
    pub fn broadcast(&self, item: D) -> Result<()>
    where
        D: Clone,
    {
        for w in 0..self.workers {
            self.down.send(w, item.clone())?;
        }
        Ok(())
    }
}

impl<U: Send, D: Send> Port<U, D> {
    pub fn id(&self) -> usize {
        self.up.rank
    }

    /// Ship this worker's contribution to the leader.
    pub fn send(&self, payload: U) -> Result<()> {
        self.up.send(self.leader, payload)
    }

    /// Wait for the leader's scatter/broadcast item.
    pub fn recv(&self) -> Result<D> {
        let e = self.down.recv()?;
        if e.from != self.leader {
            bail!("worker {} received non-leader message from {}", self.id(), e.from);
        }
        Ok(e.payload)
    }
}

impl Hub<(), ()> {
    /// Leader half of the epoch barrier: wait for every worker, then
    /// release them all.
    pub fn barrier(&self) -> Result<()> {
        self.gather()?;
        self.broadcast(())
    }
}

impl Port<(), ()> {
    /// Worker half of the epoch barrier.
    pub fn barrier(&self) -> Result<()> {
        self.send(())?;
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_orders_by_worker_id() {
        let (hub, ports) = star::<usize, usize>(4);
        let handles: Vec<_> = ports
            .into_iter()
            .map(|p| {
                std::thread::spawn(move || -> Result<()> {
                    // Stagger sends so arrival order != worker order.
                    std::thread::sleep(std::time::Duration::from_millis(
                        (7 * (4 - p.id())) as u64,
                    ));
                    p.send(p.id() * 10)?;
                    let back = p.recv()?;
                    assert_eq!(back, p.id() + 100);
                    Ok(())
                })
            })
            .collect();
        let got = hub.gather().unwrap();
        assert_eq!(got, vec![0, 10, 20, 30]);
        hub.scatter(vec![100, 101, 102, 103]).unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn barrier_releases_all_workers() {
        let (hub, ports) = star::<(), ()>(3);
        let handles: Vec<_> = ports
            .into_iter()
            .map(|p| std::thread::spawn(move || p.barrier()))
            .collect();
        hub.barrier().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn dead_worker_surfaces_as_error() {
        let (hub, mut ports) = star::<u32, u32>(2);
        let p1 = ports.pop().unwrap();
        let p0 = ports.pop().unwrap();
        p0.send(5).unwrap();
        drop(p1); // worker 1 dies before contributing
        drop(p0);
        assert!(hub.gather().is_err());
    }

    #[test]
    fn dead_leader_unblocks_workers() {
        let (hub, ports) = star::<u32, u32>(1);
        drop(hub);
        assert!(ports[0].recv().is_err());
        assert!(ports[0].send(1).is_err());
    }

    #[test]
    fn broadcast_reaches_everyone() {
        let (hub, ports) = star::<u32, String>(2);
        hub.broadcast("go".to_string()).unwrap();
        for p in &ports {
            assert_eq!(p.recv().unwrap(), "go");
        }
    }
}
