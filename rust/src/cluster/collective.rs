//! Leader/worker collectives over the mailbox transport.
//!
//! [`star`] wires `n` worker ranks to one leader rank with a pair of
//! typed meshes (one per direction). The leader's [`Hub`] gathers one
//! contribution per worker — always reassembled in **worker-id order**,
//! never arrival order, which is what keeps floating-point reductions
//! byte-identical under arbitrary thread interleavings — and scatters
//! or broadcasts responses. A `((), ())` star doubles as the
//! leader/worker [`Hub::barrier`].
//!
//! [`Hub::gather_round`] is the batch-tagged gather the bounded-
//! staleness pipeline needs: with `train.staleness >= 1` a fast worker
//! may ship batch `i+k`'s forward results while the leader is still
//! collecting batch `i`'s, so contributions carry a **round tag** and
//! the hub parks out-of-round messages in a reorder buffer instead of
//! mistaking them for duplicates. Error paths keep the round (and the
//! engines add the batch index), so a worker dying mid-window names the
//! batch that was in flight instead of a bare channel hangup.
//!
//! Collectives move data only; the engines charge the modeled cost of
//! each collective through [`crate::comm::SimNet`] with the same calls
//! the sequential runtime makes (see the accounting contract in
//! [`super::mailbox`]).
//!
//! Since PR 5 the hub and port are generic over the
//! [`Transport`](super::mailbox::Transport) endpoints:
//! [`star`] wires the in-process channel star, while
//! [`Hub::from_endpoints`]/[`Port::from_endpoints`] wrap the TCP lanes
//! of a multi-process star ([`crate::net::tcp`]) around the identical
//! protocol code — same rounds, same worker-id-ordered reassembly,
//! same error wording.
//!
//! Every blocking receive here opens a [`crate::obs`] stall span
//! around the `recv` call itself: wire-wait for the data collectives,
//! barrier-wait for the `((), ())` barriers — so time a rank spends
//! blocked on a peer is attributed, not lost. Inert (no clock read)
//! unless the thread registered with the flight recorder.

use std::collections::BTreeMap;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::mailbox::{Mailbox, Transport};

/// Batch-cursor sentinel: "this worker died before touching any batch".
pub const NO_BATCH: usize = usize::MAX;

/// How [`Hub::gather_round`] should treat one received message.
pub enum RoundTag {
    /// A contribution to the given round (buffered if not the round
    /// being gathered).
    Round(u64),
    /// A failure notice: abort the gather immediately, threading the
    /// carried description (the engines put the batch index and root
    /// cause here) into the returned error.
    Abort(String),
}

impl RoundTag {
    /// Abort tag for a worker death notice, naming the batch that was
    /// in flight ([`NO_BATCH`] = died before its first batch). Shared
    /// by both engines so the wording the regression tests pin lives
    /// once.
    pub fn abort_for(bi: usize, msg: &str) -> RoundTag {
        RoundTag::Abort(if bi == NO_BATCH {
            format!("before its first batch: {msg}")
        } else {
            format!("batch {bi} was in flight: {msg}")
        })
    }
}

/// Run a cluster worker's body with panic containment and death
/// notification — the wrapper both engines previously copy-pasted.
/// `cur` is the worker's batch cursor (stores survive unwinding, so a
/// panic still names the batch in flight); on error or panic, `notify`
/// ships a best-effort death notice `(batch, root cause)` so the
/// leader's gather fails fast instead of blocking on a dead peer.
pub fn run_contained(
    worker: usize,
    cur: &AtomicUsize,
    body: impl FnOnce() -> Result<()>,
    notify: impl FnOnce(usize, String),
) -> Result<()> {
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(body));
    let r = caught.unwrap_or_else(|_| Err(anyhow!("worker {worker} panicked")));
    if let Err(e) = &r {
        notify(cur.load(Ordering::Relaxed), format!("{e:#}"));
    }
    r
}

/// Leader endpoint of a star: receives `U`p messages, sends `D`own.
///
/// Generic over the [`Transport`] endpoints (defaulting to in-process
/// mailboxes); [`Hub::from_endpoints`] wraps the TCP lanes of a
/// multi-process star around the same protocol code.
pub struct Hub<U, D, EU = Mailbox<U>, ED = Mailbox<D>> {
    up: EU,
    down: ED,
    workers: usize,
    /// Reorder buffer of [`Hub::gather_round`]: contributions that
    /// arrived for a round other than the one being gathered.
    parked: BTreeMap<u64, Vec<Option<U>>>,
    _down: PhantomData<fn() -> D>,
}

/// Worker endpoint of a star.
pub struct Port<U, D, EU = Mailbox<U>, ED = Mailbox<D>> {
    up: EU,
    down: ED,
    leader: usize,
    _types: PhantomData<fn() -> (U, D)>,
}

/// Build an in-process star of `workers` worker ranks plus one leader
/// rank over channel mailboxes.
pub fn star<U: Send, D: Send>(workers: usize) -> (Hub<U, D>, Vec<Port<U, D>>) {
    let (up_hub, up_spokes) = Mailbox::<U>::star(workers);
    let (down_hub, down_spokes) = Mailbox::<D>::star(workers);
    let hub = Hub::from_endpoints(up_hub, down_hub, workers);
    let ports = up_spokes
        .into_iter()
        .zip(down_spokes)
        .map(|(u, d)| Port::from_endpoints(u, d, workers))
        .collect();
    (hub, ports)
}

impl<U, D, EU: Transport<U>, ED: Transport<D>> Hub<U, D, EU, ED> {
    /// Wrap the leader side of a star around arbitrary transport
    /// endpoints (`up` receives worker contributions, `down` addresses
    /// workers `0..workers` directly).
    pub fn from_endpoints(up: EU, down: ED, workers: usize) -> Hub<U, D, EU, ED> {
        Hub {
            up,
            down,
            workers,
            parked: BTreeMap::new(),
            _down: PhantomData,
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Collect exactly one contribution per worker, ordered by worker
    /// id. Errors on a hung-up, out-of-range or duplicate sender.
    pub fn gather(&self) -> Result<Vec<U>> {
        self.gather_kind(crate::obs::KIND_WIRE_WAIT, 0, "gather.recv")
    }

    /// [`gather`](Hub::gather) with an explicit stall attribution: the
    /// barrier gathers the same way but its blocked time is
    /// barrier-wait, not wire-wait, and must not double as both.
    fn gather_kind(&self, kind: u8, lane: u8, name: &'static str) -> Result<Vec<U>> {
        let mut slots: Vec<Option<U>> = (0..self.workers).map(|_| None).collect();
        for _ in 0..self.workers {
            // Span strictly around the blocking receive — reassembly
            // below is the leader's own (compute) time.
            let e = {
                let _s = crate::obs::span(kind, lane, name);
                self.up.recv()
            }?;
            ensure!(
                e.from < self.workers,
                "gather contribution from unexpected rank {}",
                e.from
            );
            ensure!(
                slots[e.from].is_none(),
                "duplicate gather contribution from worker {}",
                e.from
            );
            slots[e.from] = Some(e.payload);
        }
        let out: Vec<U> = slots.into_iter().flatten().collect();
        ensure!(out.len() == self.workers, "gather lost contributions");
        Ok(out)
    }

    /// Collect exactly one contribution per worker **for `round`**,
    /// ordered by worker id. Messages tagged for other rounds are
    /// parked and handed out when their round is gathered — the window
    /// of a staleness pipeline delivers batch `i+k` forwards while
    /// batch `i` is still being collected. A [`RoundTag::Abort`]
    /// message (a worker's death notice) fails the gather immediately
    /// with the worker's own description; a hangup error names the
    /// round so the caller's batch context survives.
    pub fn gather_round(&mut self, round: u64, tag: impl Fn(&U) -> RoundTag) -> Result<Vec<U>> {
        loop {
            let complete = self
                .parked
                .get(&round)
                .is_some_and(|slots| slots.iter().all(|s| s.is_some()));
            if complete {
                let slots = self
                    .parked
                    .remove(&round)
                    .ok_or_else(|| anyhow!("round {round} vanished from the reorder buffer"))?;
                let out: Vec<U> = slots.into_iter().flatten().collect();
                ensure!(out.len() == self.workers, "round {round} gather lost contributions");
                return Ok(out);
            }
            let workers = self.workers;
            let e = {
                let _s = crate::obs::span(crate::obs::KIND_WIRE_WAIT, 0, "gather_round.recv");
                self.up.recv()
            }
            .with_context(|| format!("gathering round {round} (in-flight window)"))?;
            ensure!(
                e.from < workers,
                "round {round}: gather contribution from unexpected rank {}",
                e.from
            );
            match tag(&e.payload) {
                RoundTag::Abort(what) => {
                    let from = e.from;
                    bail!("worker {from} failed while the leader gathered round {round}: {what}")
                }
                RoundTag::Round(r) => {
                    let slots = self
                        .parked
                        .entry(r)
                        .or_insert_with(|| (0..workers).map(|_| None).collect());
                    ensure!(
                        slots[e.from].is_none(),
                        "duplicate round {r} contribution from worker {}",
                        e.from
                    );
                    slots[e.from] = Some(e.payload);
                }
            }
        }
    }

    /// Send `items[w]` to worker `w`.
    pub fn scatter(&self, items: Vec<D>) -> Result<()> {
        ensure!(
            items.len() == self.workers,
            "scatter of {} items across {} workers",
            items.len(),
            self.workers
        );
        for (w, item) in items.into_iter().enumerate() {
            self.down.send(w, item)?;
        }
        Ok(())
    }

    /// Send a copy of `item` to every worker. Routed through
    /// [`Transport::broadcast_encoded`], so the TCP star serializes the
    /// frame once and writes the same bytes to every connection; the
    /// in-process channels keep the clone-per-worker fallback.
    pub fn broadcast(&self, item: D) -> Result<()>
    where
        D: Clone,
    {
        self.down.broadcast_encoded(self.workers, &item)
    }
}

impl<U, D, EU: Transport<U>, ED: Transport<D>> Port<U, D, EU, ED> {
    /// Wrap the worker side of a star around arbitrary transport
    /// endpoints (`leader` is the hub's logical rank, conventionally
    /// the worker count).
    pub fn from_endpoints(up: EU, down: ED, leader: usize) -> Port<U, D, EU, ED> {
        Port {
            up,
            down,
            leader,
            _types: PhantomData,
        }
    }

    pub fn id(&self) -> usize {
        self.up.rank()
    }

    /// Ship this worker's contribution to the leader.
    pub fn send(&self, payload: U) -> Result<()> {
        self.up.send(self.leader, payload)
    }

    /// Wait for the leader's scatter/broadcast item.
    pub fn recv(&self) -> Result<D> {
        self.recv_kind(crate::obs::KIND_WIRE_WAIT, 1, "port.recv")
    }

    /// [`recv`](Port::recv) with an explicit stall attribution (the
    /// worker barrier blocks here too, as barrier-wait).
    fn recv_kind(&self, kind: u8, lane: u8, name: &'static str) -> Result<D> {
        let e = {
            let _s = crate::obs::span(kind, lane, name);
            self.down.recv()
        }?;
        if e.from != self.leader {
            bail!("worker {} received non-leader message from {}", self.id(), e.from);
        }
        Ok(e.payload)
    }

    /// Deterministic fault injection (`--fail rank:batch:kind[:epoch]`):
    /// both engines call this at the head of every batch, and when this
    /// worker/epoch/batch triple matches the spec, the named fault
    /// fires. `Exit` bails immediately; `DropConn` sabotages the
    /// transport then bails; `Stall` goes silent (heartbeats paused)
    /// and sleeps past the leader's timeout so *detection*, not a clean
    /// error, ends the epoch; `CorruptFrame` arms the transport to
    /// mangle the next outbound frame and keeps running — the receiver
    /// errors, not this worker. The spec's rank field is the launch
    /// rank (leader 0, workers 1..=K), so worker `w` matches
    /// `rank == w + 1`. Each (epoch, batch) passes a run exactly once,
    /// so a fault fires at most once per training attempt — and
    /// recovery relaunches without the spec entirely.
    pub fn maybe_fault(
        &self,
        train: &crate::config::TrainConfig,
        epoch: usize,
        bi: usize,
    ) -> Result<()> {
        let Some(f) = train.fail else {
            return Ok(());
        };
        if f.rank != self.id() + 1 || f.epoch != epoch || f.batch != bi {
            return Ok(());
        }
        let w = self.id();
        crate::log!(
            Warn,
            "fault injection: worker {w} firing `{}` at epoch {epoch}, batch {bi}",
            f.kind.name()
        );
        match f.kind {
            crate::config::FaultKind::CorruptFrame => {
                self.up.sabotage(f.kind);
                Ok(())
            }
            crate::config::FaultKind::Exit => {
                bail!("fault injection: worker {w} exited at epoch {epoch}, batch {bi}")
            }
            crate::config::FaultKind::DropConn => {
                self.up.sabotage(f.kind);
                bail!(
                    "fault injection: worker {w} dropped its connections at epoch {epoch}, \
                     batch {bi}"
                )
            }
            crate::config::FaultKind::Stall => {
                // Go silent first, then wedge well past the leader's
                // deadline: the epoch must end because the *leader*
                // declared this rank dead, not because it erred out.
                self.up.sabotage(f.kind);
                let wedge_ms = train.hb_timeout_ms * 2 + 4 * train.hb_interval_ms;
                std::thread::sleep(std::time::Duration::from_millis(wedge_ms));
                bail!(
                    "fault injection: worker {w} stalled past the {}ms heartbeat timeout \
                     at epoch {epoch}, batch {bi}",
                    train.hb_timeout_ms
                )
            }
        }
    }
}

impl<EU: Transport<()>, ED: Transport<()>> Hub<(), (), EU, ED> {
    /// Leader half of the epoch barrier: wait for every worker, then
    /// release them all.
    pub fn barrier(&self) -> Result<()> {
        self.gather_kind(crate::obs::KIND_BARRIER_WAIT, 2, "barrier.gather")?;
        self.broadcast(())
    }
}

impl<EU: Transport<()>, ED: Transport<()>> Port<(), (), EU, ED> {
    /// Worker half of the epoch barrier.
    pub fn barrier(&self) -> Result<()> {
        self.send(())?;
        self.recv_kind(crate::obs::KIND_BARRIER_WAIT, 3, "barrier.recv")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_orders_by_worker_id() {
        let (hub, ports) = star::<usize, usize>(4);
        let handles: Vec<_> = ports
            .into_iter()
            .map(|p| {
                std::thread::spawn(move || -> Result<()> {
                    // Stagger sends so arrival order != worker order.
                    std::thread::sleep(std::time::Duration::from_millis(
                        (7 * (4 - p.id())) as u64,
                    ));
                    p.send(p.id() * 10)?;
                    let back = p.recv()?;
                    assert_eq!(back, p.id() + 100);
                    Ok(())
                })
            })
            .collect();
        let got = hub.gather().unwrap();
        assert_eq!(got, vec![0, 10, 20, 30]);
        hub.scatter(vec![100, 101, 102, 103]).unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn barrier_releases_all_workers() {
        let (hub, ports) = star::<(), ()>(3);
        let handles: Vec<_> = ports
            .into_iter()
            .map(|p| std::thread::spawn(move || p.barrier()))
            .collect();
        hub.barrier().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn dead_worker_surfaces_as_error() {
        let (hub, mut ports) = star::<u32, u32>(2);
        let p1 = ports.pop().unwrap();
        let p0 = ports.pop().unwrap();
        p0.send(5).unwrap();
        drop(p1); // worker 1 dies before contributing
        drop(p0);
        assert!(hub.gather().is_err());
    }

    #[test]
    fn dead_leader_unblocks_workers() {
        let (hub, ports) = star::<u32, u32>(1);
        drop(hub);
        assert!(ports[0].recv().is_err());
        assert!(ports[0].send(1).is_err());
    }

    #[test]
    fn gather_round_parks_runahead_contributions() {
        // Worker 1 runs a whole round ahead (the staleness window):
        // its round-1 message lands before worker 0's round-0 one, and
        // must neither error as a duplicate nor leak into round 0.
        let (mut hub, mut ports) = star::<(u64, u32), ()>(2);
        let p1 = ports.pop().unwrap();
        let p0 = ports.pop().unwrap();
        p1.send((0, 10)).unwrap();
        p1.send((1, 11)).unwrap();
        p0.send((0, 0)).unwrap();
        p0.send((1, 1)).unwrap();
        let tag = |m: &(u64, u32)| RoundTag::Round(m.0);
        let r0 = hub.gather_round(0, tag).unwrap();
        assert_eq!(r0, vec![(0, 0), (0, 10)]);
        let r1 = hub.gather_round(1, tag).unwrap();
        assert_eq!(r1, vec![(1, 1), (1, 11)]);
    }

    #[test]
    fn gather_round_abort_carries_batch_context() {
        let (mut hub, mut ports) = star::<Result<(u64, u32), String>, ()>(2);
        let p1 = ports.pop().unwrap();
        let p0 = ports.pop().unwrap();
        p0.send(Ok((0, 5))).unwrap();
        // Worker 1 dies mid-window and says which batch it was in.
        p1.send(Err("batch 7: worker 1 panicked".into())).unwrap();
        let err = hub
            .gather_round(0, |m| match m {
                Ok((r, _)) => RoundTag::Round(*r),
                Err(e) => RoundTag::Abort(e.clone()),
            })
            .unwrap_err();
        let text = format!("{err:#}");
        assert!(
            text.contains("batch 7") && text.contains("worker 1"),
            "abort must surface the in-flight batch and worker: {text}"
        );
    }

    #[test]
    fn run_contained_names_the_batch_on_panic() {
        let cur = AtomicUsize::new(NO_BATCH);
        let mut notice: Option<(usize, String)> = None;
        let r = run_contained(
            3,
            &cur,
            || {
                cur.store(7, Ordering::Relaxed);
                panic!("boom");
            },
            |bi, msg| notice = Some((bi, msg)),
        );
        assert!(r.is_err());
        let (bi, msg) = notice.expect("death notice must fire");
        assert_eq!(bi, 7, "the batch cursor must survive the unwind");
        assert!(msg.contains("worker 3 panicked"), "unexpected notice: {msg}");
        // And the shared abort wording names the batch (or its absence).
        match RoundTag::abort_for(7, "x") {
            RoundTag::Abort(s) => assert!(s.contains("batch 7")),
            RoundTag::Round(_) => unreachable!(),
        }
        match RoundTag::abort_for(NO_BATCH, "x") {
            RoundTag::Abort(s) => assert!(s.contains("before its first batch")),
            RoundTag::Round(_) => unreachable!(),
        }
    }

    #[test]
    fn gather_round_hangup_names_the_round() {
        let (mut hub, mut ports) = star::<(u64, u32), ()>(2);
        let p1 = ports.pop().unwrap();
        let p0 = ports.pop().unwrap();
        p0.send((4, 1)).unwrap();
        drop(p1); // silent death: no notice at all
        drop(p0);
        let err = hub.gather_round(4, |m| RoundTag::Round(m.0)).unwrap_err();
        let text = format!("{err:#}");
        assert!(
            text.contains("round 4"),
            "hangup error must name the round in flight: {text}"
        );
    }

    #[test]
    fn broadcast_reaches_everyone() {
        let (hub, ports) = star::<u32, String>(2);
        hub.broadcast("go".to_string()).unwrap();
        for p in &ports {
            assert_eq!(p.recv().unwrap(), "go");
        }
    }
}
