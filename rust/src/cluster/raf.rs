//! The RAF engine on the cluster runtime.
//!
//! One OS thread per partition; the calling thread is the leader. Per
//! batch: workers sample their own relations and execute `worker_fwd`
//! concurrently (artifact execution serializes on the shared-session
//! mutex — one CPU PJRT client — but sampling runs lock-free), the
//! leader gathers partials in worker order, runs the `leader` artifact,
//! scatters `∂partials`, gathers worker gradients in worker order and
//! applies all updates. With `train.pipeline` on, each worker prefetches
//! batch `i+1`'s sample right after shipping its batch-`i` partials, so
//! prefetch work hides inside the leader phase — the double-buffered
//! schedule priced by [`crate::metrics::timeline`].
//!
//! Every floating-point reduction folds in (worker, output) order —
//! exactly the order the sequential engine uses — so losses and
//! parameter trajectories are byte-identical to the sequential runtime
//! under any thread interleaving.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cache::FeatureCache;
use crate::comm::SimNet;
use crate::config::{partition_edge_filter, Config};
use crate::coordinator::common::{
    add_assign, apply_learnable_grads, build_inputs, BatchArena, ExtraInputs, Session,
};
use crate::hetgraph::{HetGraph, MetaTree, NodeId};
use crate::kvstore::FetchStats;
use crate::metrics::timeline::{EpochTimeline, LeaderSpan, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::MetaPartition;
use crate::sampling::{sample_tree, Frontier, TreeSample, PAD};
use crate::util::rng::Rng;

use super::collective::{star, Hub, Port};
use super::lock;
use super::mailbox::{slice_bytes, Wire};

/// Worker → leader messages.
enum Up {
    Fwd {
        p1: Vec<f32>,
        p2: Vec<f32>,
        /// KV-store fetch accounting of the forward input build (unique
        /// rows per batch when dedup gather is on).
        stats: FetchStats,
        span: WorkerSpan,
        stages: StageTimes,
    },
    Bwd {
        /// One entry per `wgrad` output, unmerged — the leader folds
        /// them in (worker, output) order to match the sequential
        /// engine's float-accumulation order exactly.
        wgrads: Vec<(String, Vec<f32>)>,
        /// `(src_ty, sampled ids, grads)` per `block_grad` output.
        row_grads: Vec<(usize, Vec<NodeId>, Vec<f32>)>,
        /// One entry per `target_feat_grad` output, unmerged.
        gx: Vec<Vec<f32>>,
        bwd_s: f64,
        stages: StageTimes,
    },
    /// Best-effort death notice: without it, a leader gathering from a
    /// dead worker would block forever while live workers keep the
    /// channel connected.
    Failed(String),
}

impl Wire for Up {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] forward partials per worker (Props. 2–3).
            Up::Fwd { p1, p2, .. } => slice_bytes(p1) + slice_bytes(p2),
            // Model-parallel weight/row grads are applied locally by
            // their owning partition in the modeled system; shipping
            // them to the shared session is an in-process artifact, not
            // wire traffic. Replica sync is charged separately, exactly
            // as in the sequential engine.
            Up::Bwd { .. } => 0,
            Up::Failed(_) => 0,
        }
    }
}

/// Leader → worker messages.
#[derive(Clone)]
enum Down {
    Grads { g1: Vec<f32>, g2: Vec<f32> },
    Ready,
}

impl Wire for Down {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] backward partial-gradients per worker.
            Down::Grads { g1, g2 } => slice_bytes(g1) + slice_bytes(g2),
            Down::Ready => 0,
        }
    }
}

/// Run one RAF epoch on the cluster runtime.
pub fn run_epoch(
    mp: &MetaPartition,
    caches: &mut [FeatureCache],
    replica_count: &HashMap<String, usize>,
    leader_part: usize,
    sess: &mut Session,
    epoch: usize,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = mp.num_parts;
    let gpus = cfg.train.gpus_per_machine.max(1);
    let pipeline = cfg.train.pipeline;
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);

    let mut train = sess.g.train_nodes();
    let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
    shuffle_rng.shuffle(&mut train);
    let b = cfg.train.batch_size;
    let batches: Vec<Vec<NodeId>> = train
        .chunks(b)
        .filter(|c| c.len() == b) // drop the ragged tail (static shapes)
        .map(|c| c.to_vec())
        .collect();

    let cache_mx: Vec<Mutex<&mut FeatureCache>> = caches.iter_mut().map(Mutex::new).collect();
    let sess_mx = Mutex::new(sess);
    let (hub, ports) = star::<Up, Down>(parts);
    let (bhub, bports) = star::<(), ()>(parts);

    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts);
        for ((p, port), bport) in ports.into_iter().enumerate().zip(bports) {
            let cfg = &cfg;
            let g = &g;
            let tree = &tree;
            let batches = &batches;
            let sess_mx = &sess_mx;
            let cache = &cache_mx[p];
            handles.push(s.spawn(move || {
                worker_loop(
                    p, gpus, cfg, epoch, batches, g, tree, mp, sess_mx, cache, &port, &bport,
                    pipeline,
                )
            }));
        }
        let led = leader_loop(
            hub,
            bhub,
            &cfg,
            parts,
            leader_part,
            replica_count,
            &batches,
            &sess_mx,
            &cache_mx,
            pipeline,
        );
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e);
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        // The leader's error already embeds worker root causes (via
        // `Up::Failed`), so it wins; worker errors cover the remainder.
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    })
}

/// Runs the worker body; on error, ships a best-effort death notice so
/// the leader's gather fails fast instead of blocking on a dead peer.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    p: usize,
    gpus: usize,
    cfg: &Config,
    epoch: usize,
    batches: &[Vec<NodeId>],
    g: &Arc<HetGraph>,
    tree: &Arc<MetaTree>,
    mp: &MetaPartition,
    sess_mx: &Mutex<&mut Session>,
    cache_mx: &Mutex<&mut FeatureCache>,
    port: &Port<Up, Down>,
    bport: &Port<(), ()>,
    pipeline: bool,
) -> Result<()> {
    // Contain panics too: a panicked worker that never notified the
    // leader would leave the gather blocked while live peers keep the
    // channel connected.
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        worker_run(
            p, gpus, cfg, epoch, batches, g, tree, mp, sess_mx, cache_mx, port, bport, pipeline,
        )
    }));
    let r = caught.unwrap_or_else(|_| Err(anyhow!("worker {p} panicked")));
    if let Err(e) = &r {
        let _ = port.send(Up::Failed(format!("{e:#}")));
    }
    r
}

#[allow(clippy::too_many_arguments)]
fn worker_run(
    p: usize,
    gpus: usize,
    cfg: &Config,
    epoch: usize,
    batches: &[Vec<NodeId>],
    g: &Arc<HetGraph>,
    tree: &Arc<MetaTree>,
    mp: &MetaPartition,
    sess_mx: &Mutex<&mut Session>,
    cache_mx: &Mutex<&mut FeatureCache>,
    port: &Port<Up, Down>,
    bport: &Port<(), ()>,
    pipeline: bool,
) -> Result<()> {
    bport.barrier()?;
    let scale = cfg.cost.compute_scale;
    let ntypes = g.schema.node_types.len();
    // Per-partition artifact specs are constant across batches: clone
    // them once instead of per batch inside the serialized section.
    let art = format!("worker_fwd_p{p}");
    let art_b = format!("worker_bwd_p{p}");
    let (spec_f, spec_b) = {
        let guard = lock(sess_mx, "session")?;
        (
            guard.rt.manifest.spec(&art)?.clone(),
            guard.rt.manifest.spec(&art_b)?.clone(),
        )
    };
    // Root (target) rows join the fetch frontier only if this worker's
    // artifact actually gathers them — the leader fetches the batch's
    // target rows itself.
    let needs_root = spec_f.inputs.iter().any(|i| i.kind == "target_feat");
    // Per-thread marshalling scratch; `spare` lets two frontier
    // allocations ping-pong with the double-buffered prefetch (the
    // in-flight batch holds one while the prefetch fills the other).
    let mut arena = BatchArena::new();
    let mut spare: Option<Frontier> = None;
    let mut prefetched: Option<(TreeSample, Option<Frontier>, f64)> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        if bi > 0 {
            // Batch i's forward needs batch i-1's updated weights.
            match port.recv()? {
                Down::Ready => {}
                Down::Grads { .. } => bail!("worker {p}: gradients arrived before Ready"),
            }
        }
        let (sample, frontier, sample_s) = match prefetched.take() {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let filter = partition_edge_filter(tree, mp, p);
                let s = sample_tree(
                    g,
                    tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    cfg.train.batch_seed(epoch, bi),
                    filter,
                );
                let fr = cfg
                    .train
                    .dedup_fetch
                    .then(|| Frontier::take_rebuilt(&mut spare, tree, &s, ntypes, needs_root));
                (s, fr, t0.elapsed().as_secs_f64() * scale)
            }
        };

        // ---- forward: marshal + execute under the session lock ----
        arena.begin_batch(ntypes);
        let (p1, p2, stats, span) = {
            let mut guard = lock(sess_mx, "session")?;
            let sess: &mut Session = &mut **guard;
            let t1 = Instant::now();
            let extra = ExtraInputs::new();
            let mut cguard = lock(cache_mx, "cache")?;
            let (lits, acc) = build_inputs(
                sess,
                &spec_f,
                Some(&sample),
                frontier.as_ref(),
                chunk,
                &extra,
                &|_, _| false, // meta-partitioning: all fetches local
                Some(&mut **cguard),
                p % gpus,
                &mut arena,
            )?;
            drop(cguard);
            let copy_s = t1.elapsed().as_secs_f64() * scale;
            let t2 = Instant::now();
            let outs = sess.rt.exec(&art, &lits)?;
            let fwd_s = t2.elapsed().as_secs_f64() * scale / gpus as f64;
            let p1 = crate::runtime::lit_to_vec(
                outs.first().ok_or_else(|| anyhow!("{art}: no outputs"))?,
            )?;
            let p2 = crate::runtime::lit_to_vec(
                outs.get(1).ok_or_else(|| anyhow!("{art}: missing output 1"))?,
            )?;
            let span = WorkerSpan {
                sample_s,
                fetch_ro_s: acc.cache_time_ro_s,
                fetch_lr_s: acc.cache_time_s - acc.cache_time_ro_s,
                copy_s,
                fwd_s,
                bwd_s: 0.0,
            };
            (p1, p2, acc.stats, span)
        };
        let mut stages = StageTimes::default();
        stages.add(Stage::Sample, span.sample_s);
        stages.add(Stage::Copy, span.copy_s);
        stages.add(Stage::Fetch, span.fetch_ro_s + span.fetch_lr_s);
        stages.add(Stage::Forward, span.fwd_s);
        port.send(Up::Fwd {
            p1,
            p2,
            stats,
            span,
            stages,
        })?;

        // ---- double-buffer: prefetch batch i+1 during the leader phase
        // (sampling *and* the dedup frontier, so the dedup work overlaps
        // the leader's gather/step/scatter) ----
        if pipeline && bi + 1 < batches.len() {
            let t = Instant::now();
            let filter = partition_edge_filter(tree, mp, p);
            let s = sample_tree(
                g,
                tree,
                &cfg.model.fanouts,
                &batches[bi + 1],
                0,
                cfg.train.batch_seed(epoch, bi + 1),
                filter,
            );
            let fr = cfg
                .train
                .dedup_fetch
                .then(|| Frontier::take_rebuilt(&mut spare, tree, &s, ntypes, needs_root));
            prefetched = Some((s, fr, t.elapsed().as_secs_f64() * scale));
        }

        // ---- backward ----
        let (g1, g2) = match port.recv()? {
            Down::Grads { g1, g2 } => (g1, g2),
            Down::Ready => bail!("worker {p}: Ready arrived before gradients"),
        };
        let (wgrads, row_grads, gx, bwd_s) = {
            let mut guard = lock(sess_mx, "session")?;
            let sess: &mut Session = &mut **guard;
            let mut extra = ExtraInputs::new();
            extra.insert(("grad".into(), 1), g1);
            extra.insert(("grad".into(), 2), g2);
            let t5 = Instant::now();
            // Reuses the forward pass's staged rows: same batch, same
            // frontier, features unmodified until the update phase.
            let (lits, _) = build_inputs(
                sess,
                &spec_b,
                Some(&sample),
                frontier.as_ref(),
                chunk,
                &extra,
                &|_, _| false,
                None, // rows already resident from forward
                p % gpus,
                &mut arena,
            )?;
            let outs = sess.rt.exec(&art_b, &lits)?;
            let bwd_s = t5.elapsed().as_secs_f64() * scale / gpus as f64;
            let mut wgrads: Vec<(String, Vec<f32>)> = Vec::new();
            let mut row_grads: Vec<(usize, Vec<NodeId>, Vec<f32>)> = Vec::new();
            let mut gx: Vec<Vec<f32>> = Vec::new();
            for (o, out) in spec_b.outputs.iter().zip(&outs) {
                match o.kind.as_str() {
                    "wgrad" => {
                        wgrads.push((o.name.clone(), crate::runtime::lit_to_vec(out)?));
                    }
                    "block_grad" => {
                        let (child, src_ty) = sess.edge_child(o.edge as usize);
                        row_grads.push((
                            src_ty,
                            sample.ids[child].clone(),
                            crate::runtime::lit_to_vec(out)?,
                        ));
                    }
                    "target_feat_grad" => {
                        gx.push(crate::runtime::lit_to_vec(out)?);
                    }
                    _ => {}
                }
            }
            (wgrads, row_grads, gx, bwd_s)
        };
        let mut bstages = StageTimes::default();
        bstages.add(Stage::Backward, bwd_s);
        port.send(Up::Bwd {
            wgrads,
            row_grads,
            gx,
            bwd_s,
            stages: bstages,
        })?;
        // Batch done; recycle the frontier allocation for a later
        // prefetch (the i+1 prefetch above already took the other one).
        if let Some(f) = frontier {
            spare = Some(f);
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    hub: Hub<Up, Down>,
    bhub: Hub<(), ()>,
    cfg: &Config,
    parts: usize,
    leader_part: usize,
    replica_count: &HashMap<String, usize>,
    batches: &[Vec<NodeId>],
    sess_mx: &Mutex<&mut Session>,
    caches: &[Mutex<&mut FeatureCache>],
    pipeline: bool,
) -> Result<EpochReport> {
    bhub.barrier()?;
    let scale = cfg.cost.compute_scale;
    let b = cfg.train.batch_size;
    let h = cfg.model.hidden;
    let mut net = SimNet::new(parts, cfg.cost.clone());
    let mut timeline = EpochTimeline::new(parts);
    let mut stages = StageTimes::default();
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut batches_done = 0usize;
    let mut fetch = FetchStats::default();
    // The leader's own marshalling scratch (its artifact has no sample,
    // so no frontier — batch ids are already unique).
    let mut leader_arena = BatchArena::new();

    for (bi, chunk) in batches.iter().enumerate() {
        // ---- gather worker partials (worker-id order) ----
        let ups = hub.gather()?;
        let wire: Vec<u64> = ups.iter().map(|u| u.wire_bytes()).collect();
        let mut partial_sums = vec![vec![0f32; b * h]; 2];
        let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
        for (w, up) in ups.into_iter().enumerate() {
            match up {
                Up::Fwd {
                    p1,
                    p2,
                    stats,
                    span,
                    stages: wstages,
                } => {
                    add_assign(&mut partial_sums[0], &p1);
                    add_assign(&mut partial_sums[1], &p2);
                    fetch.merge(stats);
                    worker_spans.push(span);
                    stages.merge(&wstages);
                }
                Up::Bwd { .. } => bail!("protocol error: Bwd before Fwd from worker {w}"),
                Up::Failed(msg) => bail!("worker {w} failed: {msg}"),
            }
        }
        // The leader partition's partials are machine-local.
        let gather_bytes: Vec<u64> = wire
            .iter()
            .enumerate()
            .map(|(w, &bytes)| if w == leader_part { 0 } else { bytes })
            .collect();
        let t_gather = net.gather(leader_part, &gather_bytes)?;
        stages.add(Stage::Forward, t_gather);

        // ---- leader step: cross-relation agg + head + loss + backward ----
        let (loss, acc, g1, g2, mut gx_root, t4_s, leader_t) = {
            let mut guard = lock(sess_mx, "session")?;
            let sess: &mut Session = &mut **guard;
            sess.adam_t += 1;
            let spec = sess.rt.manifest.spec("leader")?.clone();
            let mut extra = ExtraInputs::new();
            extra.insert(("partial_sum".into(), 1), partial_sums[0].clone());
            extra.insert(("partial_sum".into(), 2), partial_sums[1].clone());
            let t3 = Instant::now();
            let mut lc = lock(&caches[leader_part], "leader cache")?;
            let (lits, leader_acc) = build_inputs(
                sess,
                &spec,
                None,
                None,
                chunk,
                &extra,
                &|_, _| false,
                Some(&mut **lc),
                0,
                &mut leader_arena,
            )?;
            drop(lc);
            fetch.merge(leader_acc.stats);
            let outs = sess.rt.exec("leader", &lits)?;
            let leader_t = t3.elapsed().as_secs_f64() * scale;
            if outs.len() < 5 {
                bail!("leader artifact returned {} outputs, expected >= 5", outs.len());
            }
            let loss = crate::runtime::lit_scalar(&outs[0])? as f64;
            let acc = crate::runtime::lit_scalar(&outs[1])? as f64;
            let g1 = crate::runtime::lit_to_vec(&outs[2])?;
            let g2 = crate::runtime::lit_to_vec(&outs[3])?;
            let gx_root = crate::runtime::lit_to_vec(&outs[4])?;
            // Leader's own (head) weight updates.
            let t4 = Instant::now();
            for (o, out) in spec.outputs.iter().zip(&outs) {
                if o.kind == "wgrad" {
                    let grad = crate::runtime::lit_to_vec(out)?;
                    sess.params.step(&o.name, &grad)?;
                }
            }
            let t4_s = t4.elapsed().as_secs_f64();
            (loss, acc, g1, g2, gx_root, t4_s, leader_t)
        };
        stages.add(Stage::Forward, leader_t * 0.5);
        stages.add(Stage::Backward, leader_t * 0.5);
        stages.add(Stage::Update, t4_s);
        loss_sum += loss;
        acc_sum += acc;

        // ---- scatter gradients back (2 tensors per worker, symmetric) ----
        let t_scatter = net.gather(leader_part, &gather_bytes)?;
        stages.add(Stage::Backward, t_scatter);
        hub.broadcast(Down::Grads { g1, g2 })?;

        // ---- gather worker gradients (worker-id order) ----
        let ups = hub.gather()?;
        let mut wgrads_all: HashMap<String, Vec<f32>> = HashMap::new();
        let mut row_grads_all: HashMap<usize, (Vec<NodeId>, Vec<f32>)> = HashMap::new();
        let mut gx_extra: Vec<f32> = Vec::new();
        for (w, up) in ups.into_iter().enumerate() {
            match up {
                Up::Bwd {
                    wgrads,
                    row_grads,
                    gx,
                    bwd_s,
                    stages: wstages,
                } => {
                    for (name, gvec) in wgrads {
                        match wgrads_all.get_mut(&name) {
                            Some(acc) => add_assign(acc, &gvec),
                            None => {
                                wgrads_all.insert(name, gvec);
                            }
                        }
                    }
                    for (ty, ids, gvec) in row_grads {
                        let entry = row_grads_all
                            .entry(ty)
                            .or_insert_with(|| (Vec::new(), Vec::new()));
                        entry.0.extend_from_slice(&ids);
                        entry.1.extend_from_slice(&gvec);
                    }
                    for gvec in gx {
                        if gx_extra.is_empty() {
                            gx_extra = gvec;
                        } else {
                            add_assign(&mut gx_extra, &gvec);
                        }
                    }
                    if let Some(span) = worker_spans.get_mut(w) {
                        span.bwd_s = bwd_s;
                    }
                    stages.merge(&wstages);
                }
                Up::Fwd { .. } => bail!("protocol error: Fwd before Bwd from worker {w}"),
                Up::Failed(msg) => bail!("worker {w} failed: {msg}"),
            }
        }

        // ---- model-parallel weight + learnable-feature updates ----
        let (update_t, lf_t, sync_t) = {
            let mut guard = lock(sess_mx, "session")?;
            let sess: &mut Session = &mut **guard;
            let t6 = Instant::now();
            let mut sync_bytes = 0u64;
            for (name, grad) in &wgrads_all {
                // Replicated relations: replicas push grads to the owner.
                let replicas = replica_count.get(name).copied().unwrap_or(1);
                if replicas > 1 {
                    sync_bytes += (grad.len() * 4 * (replicas - 1)) as u64;
                }
                sess.params.step(name, grad)?;
            }
            let update_t = t6.elapsed().as_secs_f64();
            let sync_t = if sync_bytes > 0 {
                net.send(1 % parts, leader_part, sync_bytes)?
            } else {
                0.0
            };

            // Learnable-feature updates (sparse Adam, local rows).
            let t7 = Instant::now();
            let mut cache_write_t = 0.0;
            if !gx_extra.is_empty() {
                add_assign(&mut gx_root, &gx_extra);
            }
            let tgt = sess.g.schema.target;
            if sess.store.is_learnable(tgt) {
                apply_learnable_grads(sess, tgt, chunk, &gx_root, 1.0);
                let cost = cfg.cost.clone();
                let mut lc = lock(&caches[leader_part], "leader cache")?;
                for &id in chunk {
                    cache_write_t += lc.access(&cost, tgt, id, 0, true);
                }
            }
            for (ty, (ids, grads)) in &row_grads_all {
                apply_learnable_grads(sess, *ty, ids, grads, 1.0);
                let cost = cfg.cost.clone();
                // Write-back path through the owning partition's cache.
                let mut c0 = lock(&caches[0], "cache 0")?;
                for &id in ids.iter().filter(|&&id| id != PAD) {
                    cache_write_t += c0.access(&cost, *ty, id, 0, true);
                }
            }
            let lf_t = t7.elapsed().as_secs_f64() + cache_write_t;
            (update_t, lf_t, sync_t)
        };
        stages.add(Stage::Update, update_t + lf_t);
        if sync_t > 0.0 {
            stages.add(Stage::GradSync, sync_t);
        }

        timeline.push_batch(
            worker_spans,
            LeaderSpan {
                gather_s: t_gather,
                leader_s: leader_t,
                scatter_s: t_scatter,
                update_s: t4_s + update_t + lf_t,
                sync_s: sync_t,
            },
        );
        batches_done += 1;
        if bi + 1 < batches.len() {
            hub.broadcast(Down::Ready)?;
        }
    }

    let epoch_time_s = timeline.sequential_time();
    let critical_path_s = if pipeline {
        timeline.pipelined_time()
    } else {
        epoch_time_s
    };
    Ok(EpochReport {
        epoch_time_s,
        critical_path_s,
        worker_busy_s: timeline.worker_busy_s(),
        stages,
        comm: net.total(),
        fetch,
        loss_mean: if batches_done > 0 {
            loss_sum / batches_done as f64
        } else {
            f64::NAN
        },
        accuracy: if batches_done > 0 {
            acc_sum / (batches_done * b) as f64
        } else {
            f64::NAN
        },
        batches: batches_done,
    })
}
