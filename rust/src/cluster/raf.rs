//! The RAF engine on the cluster runtime.
//!
//! One OS thread per partition; the calling thread is the leader. Each
//! worker thread exclusively owns its partition's
//! [`ExecContext`](crate::exec::ExecContext), so per batch the workers
//! sample, marshal and execute `worker_fwd` **concurrently** on their
//! own PJRT clients; the leader gathers partials in worker order, runs
//! the `leader` artifact on its own context, scatters `∂partials` (with
//! the post-head-update parameter snapshot), gathers worker gradients
//! in worker order and applies all updates.
//!
//! Two overlap levers stack on this (PR 1 and PR 4):
//!
//! * `train.pipeline` — the synchronous double-buffer: each worker
//!   prefetches batch `i+1`'s sample right after shipping its batch-`i`
//!   partials, hiding prefetch work inside the leader phase.
//! * `train.staleness = k >= 1` — the async 1F1B window: the leader
//!   releases batch `i+k` right after gathering batch `i`'s partials,
//!   so workers marshal+execute later forwards (against a snapshot
//!   missing at most `k` updates) while batch `i`'s backward and update
//!   are still in flight. Workers process the leader's messages in
//!   send order — forward of `i+k`, then backward of `i` — keeping up
//!   to `k + 1` batches open as [`InFlight`] state (each with its own
//!   arena: the backward rebuild scatters from its *own* forward's
//!   staged rows). All collectives are batch-tagged
//!   ([`Hub::gather_round`]) because fast workers run ahead. The
//!   schedule — releases, gradient folds, store phases — keeps a fixed
//!   deterministic order, so a given staleness value reproduces its
//!   trajectory exactly; `k = 0` is byte-identical to the synchronous
//!   protocol.
//!
//! Parameters are leader-owned: workers marshal weights from the
//! versioned read-only snapshot broadcast at each batch's release (the
//! `Ready` message) and the backward pass from the refreshed snapshot
//! riding the gradient scatter; gradients travel back tagged with the
//! snapshot version that produced them and the fold rejects mismatches.
//! The leader's cache traffic goes through fork-ledger views of the
//! partition caches (shared residency, private hit/miss counters),
//! folded back after the worker threads exit — the runtime is lock-free
//! end to end. Every floating-point reduction folds in (worker, output)
//! order — exactly the order the sequential engine uses — so at
//! staleness 0 losses and parameter trajectories are byte-identical to
//! the sequential runtime under any thread interleaving.
//!
//! Since PR 5 both loops are generic over the
//! [`Transport`](super::mailbox::Transport) endpoints. [`run_epoch`]
//! wires them over in-process channels (thread per partition, as
//! before); [`run_epoch_tcp`] runs the *same* loops over the socket
//! star of [`crate::net::tcp`] — one OS process per rank, each having
//! derived the identical batch schedule from the seeds, with every
//! protocol message crossing the wire through the
//! [`WireCodec`](crate::net::codec::WireCodec) impls below. The one
//! cross-process addition is the `Down::Store` delta: the leader's
//! learnable-feature updates are read back and broadcast so every
//! worker process's KV store replays them, and per-lane FIFO delivers
//! each delta before any batch released after it — marshals therefore
//! read exactly the store state the shared-store runtime would, and
//! losses stay byte-identical across `channel | tcp` at any fixed
//! staleness.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::comm::SimNet;
use crate::config::{partition_edge_filter, Config};
use crate::coordinator::common::Session;
use crate::exec::plan::raf_apply_updates;
use crate::exec::{
    BatchArena, BatchPlan, EpochWorld, ExecContext, ExecGate, GradAccumulator, InFlight,
    ParamsView,
};
use crate::hetgraph::{HetGraph, NodeId};
use crate::kvstore::{FetchStats, StoreDelta};
use crate::metrics::timeline::{AsyncShape, EpochTimeline, LeaderSpan, WallClock, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::net::codec::{ByteReader, ByteWriter, WireCodec};
use crate::net::tcp::{TcpChannel, TcpNode, LANE_MESH_DATA};
use crate::net::Role;
use crate::partition::MetaPartition;
use crate::runtime::{
    need_full_msg, DiffChain, ParamDiff, ParamSnapshot, ParamStore, SnapOrDiff, SnapshotChain,
};
use crate::sampling::{sample_tree, Frontier, TreeSample};
use crate::util::{add_assign, rng::Rng};

use super::collective::{run_contained, star, Hub, Port, RoundTag, NO_BATCH};
use super::mailbox::{slice_bytes, Mailbox, Transport, Wire};

/// Worker → leader messages, tagged with their batch so the leader's
/// round gather can park run-ahead contributions from fast workers.
#[derive(Debug, PartialEq)]
enum Up {
    Fwd {
        bi: usize,
        p1: Vec<f32>,
        p2: Vec<f32>,
        /// KV-store fetch accounting of the forward input build (unique
        /// rows per batch when dedup gather is on).
        stats: FetchStats,
        span: WorkerSpan,
        stages: StageTimes,
        /// Wall-clock forward-execution interval (epoch-relative) — the
        /// per-context overlap evidence.
        wall_fwd: (f64, f64),
    },
    Bwd {
        bi: usize,
        /// Unreduced gradient outputs — the leader folds them in
        /// (worker, output) order to match the sequential engine's
        /// float-accumulation order exactly. Tagged with the snapshot
        /// version that produced them.
        grads: crate::exec::WorkerGrads,
        bwd_s: f64,
        stages: StageTimes,
        /// Wall-clock backward interval — with a staleness window open,
        /// the backward-vs-later-forward overlap evidence.
        wall_bwd: (f64, f64),
    },
    /// Best-effort death notice naming the batch that was in flight:
    /// without it, a leader gathering from a dead worker would block
    /// forever while live workers keep the channel connected, and
    /// without the batch tag the root cause would drown in a bare
    /// channel hangup.
    Failed { bi: usize, msg: String },
    /// Epoch-end flight-recorder payload (PR 6): this rank's trace
    /// tracks and metrics. Always sent — empty when tracing is off —
    /// so the message schedule never depends on the trace flag.
    Obs { blob: crate::obs::TraceBlob },
    /// Explicit resync NACK (PR 8, `wire_snapshots = diff`): this
    /// worker's snapshot chain cannot apply the diff it just received
    /// (`have` = the version it holds, [`u64::MAX`] = none yet;
    /// `want` = the diff's base version). Aborts the leader's gather
    /// with an error naming the rank and both versions; the restarted
    /// epoch's first frame is a full snapshot — that is the resync.
    NeedFull { bi: usize, have: u64, want: u64 },
}

/// Gather rounds: two per batch, forwards then backwards.
fn fwd_round(bi: usize) -> u64 {
    2 * bi as u64
}
fn bwd_round(bi: usize) -> u64 {
    2 * bi as u64 + 1
}
/// The epoch-end trace-blob gather rides its own round tag,
/// collision-free with any batch's `2·bi` / `2·bi + 1`.
const OBS_ROUND: u64 = u64::MAX;

fn up_tag(u: &Up) -> RoundTag {
    match u {
        Up::Fwd { bi, .. } => RoundTag::Round(fwd_round(*bi)),
        Up::Bwd { bi, .. } => RoundTag::Round(bwd_round(*bi)),
        Up::Failed { bi, msg } => RoundTag::abort_for(*bi, msg),
        Up::Obs { .. } => RoundTag::Round(OBS_ROUND),
        Up::NeedFull { bi, have, want } => {
            RoundTag::abort_for(*bi, &need_full_msg(*have, *want))
        }
    }
}

impl Wire for Up {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] forward partials per worker (Props. 2–3).
            Up::Fwd { p1, p2, .. } => slice_bytes(p1) + slice_bytes(p2),
            // Model-parallel weight/row grads are applied locally by
            // their owning partition in the modeled system; shipping
            // them to the leader-owned store is an in-process artifact,
            // not wire traffic. Replica sync is charged separately,
            // exactly as in the sequential engine.
            Up::Bwd { .. } => 0,
            Up::Failed { .. } => 0,
            // Observability is harness traffic, not the modeled
            // system's (the real socket counters still see its frames).
            Up::Obs { .. } => 0,
            Up::NeedFull { .. } => 0,
        }
    }
}

/// Leader → worker messages, batch-tagged. `Ready` releases batch `bi`
/// with the newest broadcast weights (under a staleness window these
/// may trail the store by up to `k` updates), `Grads` ships `∂partials`
/// plus the post-head-update weights the backward rebuild marshals
/// from, and `Store` replays the leader's learnable-feature writes into
/// a worker *process's* KV store (TCP only; the in-process runtime
/// shares one store and never sends it). In the modeled system each
/// partition owns its weights and learnable rows locally (model
/// parallelism), so snapshot and delta distribution are artifacts of
/// the harness, not wire traffic — only the 2·[B,H] gradients count.
#[derive(Clone, Debug, PartialEq)]
enum Down {
    Grads {
        bi: usize,
        g1: Vec<f32>,
        g2: Vec<f32>,
        params: Arc<ParamSnapshot>,
    },
    Ready {
        bi: usize,
        params: Arc<ParamSnapshot>,
    },
    /// Post-update learnable rows of batch `bi` (see [`StoreDelta`]).
    Store { bi: usize, delta: StoreDelta },
    /// `Ready` with a version-chained [`ParamDiff`] instead of the full
    /// snapshot (PR 8, `wire_snapshots = diff`): only the tensors that
    /// advanced since the previous frame on this lane. Workers resolve
    /// it against their [`SnapshotChain`] into the bit-identical full
    /// snapshot before the engine loop ever sees it.
    ReadyDiff { bi: usize, diff: ParamDiff },
    /// `Grads` with a version-chained [`ParamDiff`] (same chain as
    /// `ReadyDiff` — Ready and Grads frames alternate on one FIFO
    /// lane, so a single chain covers both).
    GradsDiff {
        bi: usize,
        g1: Vec<f32>,
        g2: Vec<f32>,
        diff: ParamDiff,
    },
}

impl Wire for Down {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] backward partial-gradients per worker.
            Down::Grads { g1, g2, .. } => slice_bytes(g1) + slice_bytes(g2),
            Down::GradsDiff { g1, g2, .. } => slice_bytes(g1) + slice_bytes(g2),
            Down::Ready { .. } => 0,
            Down::ReadyDiff { .. } => 0,
            Down::Store { .. } => 0,
        }
    }
}

// ---- wire codec (PR 5): every protocol message next to its type ----

impl WireCodec for Up {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Up::Fwd { bi, p1, p2, stats, span, stages, wall_fwd } => {
                w.u8(0);
                w.usize(*bi);
                w.f32s(p1);
                w.f32s(p2);
                stats.encode(w);
                span.encode(w);
                stages.encode(w);
                wall_fwd.encode(w);
            }
            Up::Bwd { bi, grads, bwd_s, stages, wall_bwd } => {
                w.u8(1);
                w.usize(*bi);
                grads.encode(w);
                w.f64(*bwd_s);
                stages.encode(w);
                wall_bwd.encode(w);
            }
            Up::Failed { bi, msg } => {
                w.u8(2);
                w.usize(*bi);
                w.str(msg);
            }
            Up::Obs { blob } => {
                w.u8(3);
                blob.encode(w);
            }
            Up::NeedFull { bi, have, want } => {
                w.u8(4);
                w.usize(*bi);
                w.u64(*have);
                w.u64(*want);
            }
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<Up> {
        match r.u8()? {
            0 => {
                let bi = r.usize()?;
                let p1 = r.f32s()?;
                let p2 = r.f32s()?;
                let stats = FetchStats::decode(r)?;
                let span = WorkerSpan::decode(r)?;
                let stages = StageTimes::decode(r)?;
                let wall_fwd = <(f64, f64)>::decode(r)?;
                Ok(Up::Fwd { bi, p1, p2, stats, span, stages, wall_fwd })
            }
            1 => {
                let bi = r.usize()?;
                let grads = crate::exec::WorkerGrads::decode(r)?;
                let bwd_s = r.f64()?;
                let stages = StageTimes::decode(r)?;
                let wall_bwd = <(f64, f64)>::decode(r)?;
                Ok(Up::Bwd { bi, grads, bwd_s, stages, wall_bwd })
            }
            2 => {
                let bi = r.usize()?;
                let msg = r.str()?;
                Ok(Up::Failed { bi, msg })
            }
            3 => Ok(Up::Obs { blob: crate::obs::TraceBlob::decode(r)? }),
            4 => {
                let bi = r.usize()?;
                let have = r.u64()?;
                let want = r.u64()?;
                Ok(Up::NeedFull { bi, have, want })
            }
            t => bail!("unknown RAF worker-message tag {t}"),
        }
    }
}

impl WireCodec for Down {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Down::Ready { bi, params } => {
                w.u8(0);
                w.usize(*bi);
                params.encode(w);
            }
            Down::Grads { bi, g1, g2, params } => {
                w.u8(1);
                w.usize(*bi);
                w.f32s(g1);
                w.f32s(g2);
                params.encode(w);
            }
            Down::Store { bi, delta } => {
                w.u8(2);
                w.usize(*bi);
                delta.encode(w);
            }
            Down::ReadyDiff { bi, diff } => {
                w.u8(3);
                w.usize(*bi);
                diff.encode(w);
            }
            Down::GradsDiff { bi, g1, g2, diff } => {
                w.u8(4);
                w.usize(*bi);
                w.f32s(g1);
                w.f32s(g2);
                diff.encode(w);
            }
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<Down> {
        match r.u8()? {
            0 => {
                let bi = r.usize()?;
                let params = Arc::new(ParamSnapshot::decode(r)?);
                Ok(Down::Ready { bi, params })
            }
            1 => {
                let bi = r.usize()?;
                let g1 = r.f32s()?;
                let g2 = r.f32s()?;
                let params = Arc::new(ParamSnapshot::decode(r)?);
                Ok(Down::Grads { bi, g1, g2, params })
            }
            2 => {
                let bi = r.usize()?;
                let delta = StoreDelta::decode(r)?;
                Ok(Down::Store { bi, delta })
            }
            3 => {
                let bi = r.usize()?;
                let diff = ParamDiff::decode(r)?;
                Ok(Down::ReadyDiff { bi, diff })
            }
            4 => {
                let bi = r.usize()?;
                let g1 = r.f32s()?;
                let g2 = r.f32s()?;
                let diff = ParamDiff::decode(r)?;
                Ok(Down::GradsDiff { bi, g1, g2, diff })
            }
            t => bail!("unknown RAF leader-message tag {t}"),
        }
    }
}

/// The worker↔worker relay of the peer-to-peer aggregation chain
/// (PR 8, `wire_exchange = mesh`): the running partial sums after
/// worker `p`'s add, shipped to worker `p + 1`. The receiver's
/// transport tags the sender rank, so the payload carries only the
/// batch and the accumulators.
#[derive(Clone, Debug, PartialEq)]
struct MeshFwd {
    bi: usize,
    acc1: Vec<f32>,
    acc2: Vec<f32>,
}

impl Wire for MeshFwd {
    fn wire_bytes(&self) -> u64 {
        // In the modeled system the relay IS the partial-aggregation
        // traffic (the same 2·[B,H] the star ships leader-ward — the
        // mesh moves it between neighbors instead).
        slice_bytes(&self.acc1) + slice_bytes(&self.acc2)
    }
}

impl WireCodec for MeshFwd {
    fn encode(&self, w: &mut ByteWriter) {
        w.usize(self.bi);
        w.f32s(&self.acc1);
        w.f32s(&self.acc2);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<MeshFwd> {
        let bi = r.usize()?;
        let acc1 = r.f32s()?;
        let acc2 = r.f32s()?;
        Ok(MeshFwd { bi, acc1, acc2 })
    }
}

/// The epoch's batch schedule. Derived from config seeds only, so every
/// process of a multi-process cluster computes the identical schedule
/// without exchanging a byte.
fn batch_schedule(g: &HetGraph, cfg: &Config, epoch: usize) -> Vec<Vec<NodeId>> {
    let mut train = g.train_nodes();
    let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
    shuffle_rng.shuffle(&mut train);
    let b = cfg.train.batch_size;
    train
        .chunks(b)
        .filter(|c| c.len() == b) // drop the ragged tail (static shapes)
        .map(|c| c.to_vec())
        .collect()
}

/// Run one RAF epoch on the cluster runtime.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch(
    plan: &BatchPlan,
    contexts: &mut [ExecContext],
    leader_ctx: &mut ExecContext,
    mp: &MetaPartition,
    replica_count: &HashMap<String, usize>,
    leader_part: usize,
    gate: Option<&ExecGate>,
    sess: &mut Session,
    epoch: usize,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = mp.num_parts;
    let pipeline = cfg.train.pipeline;
    // The staleness window rides the pipeline: with pipelining disabled
    // the runtime is the synchronous A/B baseline.
    let staleness = if pipeline { cfg.train.staleness } else { 0 };
    if staleness > 0 && !cfg.train.dedup_fetch {
        bail!(
            "train.staleness = {staleness} requires train.dedup_fetch (the backward \
             rebuild reuses the forward's staged rows)"
        );
    }
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);

    let batches = batch_schedule(&g, &cfg, epoch);
    if batches.is_empty() {
        // Nothing to release: spawning workers would race the initial
        // Ready broadcast against their immediate teardown.
        return Ok(EpochReport::empty(parts));
    }

    // The leader's cache traffic runs through fork-ledger views while
    // the worker threads own the primaries; counts fold back below.
    let mut fork_leader = contexts[leader_part]
        .cache
        .as_ref()
        .map(|c| c.fork_ledger());
    let mut fork_p0 = contexts[0].cache.as_ref().map(|c| c.fork_ledger());

    let world = EpochWorld {
        cfg: &cfg,
        g: &g,
        tree: &tree,
        store: &sess.store,
        gate,
        epoch_t0: Instant::now(),
    };
    let params = &mut sess.params;
    let adam_t = &mut sess.adam_t;

    let (hub, ports) = star::<Up, Down>(parts);
    let (bhub, bports) = star::<(), ()>(parts);
    // The worker↔worker relay lane (PR 8, `wire_exchange = mesh`): a
    // full in-process mesh so the partial-aggregation chain flows
    // peer-to-peer, exactly like the TCP mesh lane does cross-process.
    let meshes: Vec<Option<Mailbox<MeshFwd>>> = if cfg.train.wire_exchange.is_mesh() {
        Mailbox::mesh(parts).into_iter().map(Some).collect()
    } else {
        (0..parts).map(|_| None).collect()
    };

    let report = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts);
        for (((ctx, port), bport), mesh) in
            contexts.iter_mut().zip(ports).zip(bports).zip(meshes)
        {
            let world = &world;
            let batches = &batches;
            handles.push(s.spawn(move || {
                worker_loop(
                    ctx,
                    plan,
                    world,
                    mp,
                    epoch,
                    batches,
                    &port,
                    &bport,
                    mesh.as_ref(),
                    pipeline,
                    staleness,
                )
            }));
        }
        let led = leader_loop(
            hub,
            bhub,
            plan,
            &world,
            leader_ctx,
            params,
            adam_t,
            fork_leader.as_mut(),
            fork_p0.as_mut(),
            replica_count,
            &batches,
            parts,
            leader_part,
            pipeline,
            staleness,
            false, // one shared store: nothing to replicate
        );
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e);
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        // The leader's error already embeds worker root causes (via
        // `Up::Failed`), so it wins; worker errors cover the remainder.
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    });

    if let Some(f) = fork_leader {
        if let Some(c) = contexts[leader_part].cache.as_mut() {
            c.absorb_ledger(&f);
        }
    }
    if let Some(f) = fork_p0 {
        if let Some(c) = contexts[0].cache.as_mut() {
            c.absorb_ledger(&f);
        }
    }
    report
}

/// Receive the next protocol message, transparently replaying store
/// deltas into this process's KV store (the TCP replication of the
/// leader's learnable-feature writes; never sent in-process) and
/// resolving diff frames (PR 8, `wire_snapshots = diff`) against this
/// worker's snapshot chain — the engine loops only ever see full
/// `Ready`/`Grads` frames, bit-identical to what full-snapshot mode
/// ships. Per-lane FIFO guarantees a delta lands before any batch the
/// leader released after the update that produced it, and keeps the
/// diff chain in send order.
fn recv_data<EU: Transport<Up>, ED: Transport<Down>>(
    port: &Port<Up, Down, EU, ED>,
    world: &EpochWorld<'_>,
    chain: &mut SnapshotChain,
) -> Result<Down> {
    loop {
        match port.recv()? {
            Down::Store { bi, delta } => delta
                .apply(&mut world.store_mut())
                .with_context(|| format!("replaying batch {bi}'s learnable-feature delta"))?,
            Down::Ready { bi, params } => {
                // Full frames re-base the chain even when diffs are off:
                // Ready and Grads alternate on one FIFO lane, so a
                // single chain covers both frame kinds.
                chain.note_full(&params);
                return Ok(Down::Ready { bi, params });
            }
            Down::Grads { bi, g1, g2, params } => {
                chain.note_full(&params);
                return Ok(Down::Grads { bi, g1, g2, params });
            }
            Down::ReadyDiff { bi, diff } => {
                let params = resolve_diff(port, chain, bi, &diff)?;
                return Ok(Down::Ready { bi, params });
            }
            Down::GradsDiff { bi, g1, g2, diff } => {
                let params = resolve_diff(port, chain, bi, &diff)?;
                return Ok(Down::Grads { bi, g1, g2, params });
            }
        }
    }
}

/// Resolve one diff frame into the full snapshot the engine loops
/// expect. A chain break (gap, or diff-before-full) ships the explicit
/// [`Up::NeedFull`] NACK — best-effort, the leader's gather may
/// already be unwinding — and surfaces as an error naming the rank and
/// both versions; it never panics. The restarted epoch's first frame
/// is always full, which is the resync.
fn resolve_diff<EU: Transport<Up>, ED: Transport<Down>>(
    port: &Port<Up, Down, EU, ED>,
    chain: &mut SnapshotChain,
    bi: usize,
    diff: &ParamDiff,
) -> Result<Arc<ParamSnapshot>> {
    let p = port.id();
    match chain.apply(p, diff) {
        Ok(snap) => Ok(snap),
        Err(e) => {
            let have = chain.version().unwrap_or(u64::MAX);
            let want = diff.from_version;
            let _ = port.send(Up::NeedFull { bi, have, want });
            Err(e.context(format!(
                "worker {p}, batch {bi}: {}",
                need_full_msg(have, want)
            )))
        }
    }
}

/// Run one batch's peer-to-peer aggregation relay (PR 8,
/// `wire_exchange = mesh`). Worker 0 starts the fold from zeroed sums
/// — reproducing the leader's star fold, which adds worker partials
/// into zeros in worker-id order — and each worker `p` adds its own
/// partials into the sums relayed from `p - 1`. The last worker
/// returns the folded sums, which ride its `Up::Fwd` leader-ward and
/// are taken **verbatim** there (re-adding them into zeros could flip
/// a `-0.0`); every other worker relays to `p + 1` and returns empty
/// tensors, so its `Up::Fwd` models zero wire bytes — the leader-lane
/// saving the mesh buys. The fold order is worker-id order either
/// way, so losses stay byte-identical to the star.
fn mesh_exchange<EM: Transport<MeshFwd>>(
    mesh: &EM,
    p: usize,
    parts: usize,
    bi: usize,
    own1: Vec<f32>,
    own2: Vec<f32>,
) -> Result<(Vec<f32>, Vec<f32>)> {
    let (mut acc1, mut acc2) = if p == 0 {
        (vec![0f32; own1.len()], vec![0f32; own2.len()])
    } else {
        let env = mesh.recv().with_context(|| {
            format!(
                "worker {p}, batch {bi}: receiving the mesh relay from worker {}",
                p - 1
            )
        })?;
        if env.from != p - 1 {
            bail!(
                "worker {p}, batch {bi}: mesh relay arrived from worker {} (expected {})",
                env.from,
                p - 1
            );
        }
        let MeshFwd { bi: mbi, acc1, acc2 } = env.payload;
        if mbi != bi {
            bail!("worker {p}: mesh relay for batch {mbi} arrived while folding batch {bi}");
        }
        if acc1.len() != own1.len() || acc2.len() != own2.len() {
            bail!(
                "worker {p}, batch {bi}: mesh relay shape mismatch ({} and {} elems \
                 vs this worker's {} and {})",
                acc1.len(),
                acc2.len(),
                own1.len(),
                own2.len()
            );
        }
        (acc1, acc2)
    };
    add_assign(&mut acc1, &own1);
    add_assign(&mut acc2, &own2);
    if p + 1 < parts {
        mesh.send(p + 1, MeshFwd { bi, acc1, acc2 }).with_context(|| {
            format!(
                "worker {p}, batch {bi}: relaying the mesh fold to worker {}",
                p + 1
            )
        })?;
        Ok((Vec::new(), Vec::new()))
    } else {
        Ok((acc1, acc2))
    }
}

/// Runs the worker body; on error (or panic), ships a best-effort death
/// notice naming the batch that was in flight so the leader's gather
/// fails fast — with the root cause — instead of blocking on a dead
/// peer or reporting a bare hangup.
#[allow(clippy::too_many_arguments)]
fn worker_loop<EU, ED, BU, BD, EM>(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    mp: &MetaPartition,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down, EU, ED>,
    bport: &Port<(), (), BU, BD>,
    mesh: Option<&EM>,
    pipeline: bool,
    staleness: usize,
) -> Result<()>
where
    EU: Transport<Up>,
    ED: Transport<Down>,
    BU: Transport<()>,
    BD: Transport<()>,
    EM: Transport<MeshFwd>,
{
    let p = ctx.worker;
    // The batch cursor outlives a panic's unwinding, so the death
    // notice still names the batch in flight.
    let cur = AtomicUsize::new(NO_BATCH);
    run_contained(
        p,
        &cur,
        || {
            if staleness == 0 {
                worker_run_sync(
                    ctx, plan, world, mp, epoch, batches, port, bport, mesh, pipeline, &cur,
                )
            } else {
                worker_run_windowed(
                    ctx, plan, world, mp, epoch, batches, port, bport, mesh, staleness, &cur,
                )
            }
        },
        |bi, msg| {
            let _ = port.send(Up::Failed { bi, msg });
        },
    )
}

/// The synchronous (`staleness = 0`) worker: strict Ready → forward →
/// Grads → backward alternation, with the double-buffered prefetch of
/// batch `i+1`'s sample (and dedup frontier) hidden inside the leader
/// phase when `pipeline` is on. Byte-for-byte the pre-window protocol.
#[allow(clippy::too_many_arguments)]
fn worker_run_sync<EU, ED, BU, BD, EM>(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    mp: &MetaPartition,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down, EU, ED>,
    bport: &Port<(), (), BU, BD>,
    mesh: Option<&EM>,
    pipeline: bool,
    cur: &AtomicUsize,
) -> Result<()>
where
    EU: Transport<Up>,
    ED: Transport<Down>,
    BU: Transport<()>,
    BD: Transport<()>,
    EM: Transport<MeshFwd>,
{
    bport.barrier()?;
    // One snapshot chain per epoch, matching the leader's per-epoch
    // diff chain (the epoch's first frame is always full).
    let mut chain = SnapshotChain::new();
    let p = ctx.worker;
    if world.cfg.train.trace {
        crate::obs::thread_register(p as u32, "worker");
    }
    let cache_base = crate::obs::cache_obs_base(ctx.cache.as_ref());
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[p];
    // One arena serves every batch: forward stages it, the same batch's
    // backward scatters from it before the next forward begins.
    let mut arena = BatchArena::new();
    // Per-thread dedup-frontier scratch; `spare` lets two frontier
    // allocations ping-pong with the double-buffered prefetch (the
    // in-flight batch holds one while the prefetch fills the other).
    let mut spare: Option<Frontier> = None;
    let mut prefetched: Option<(TreeSample, Option<Frontier>, f64)> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        cur.store(bi, Ordering::Relaxed);
        crate::obs::set_batch(bi as u64);
        port.maybe_fault(&cfg.train, epoch, bi)?;
        // Batch i's forward needs batch i-1's updated weights: the
        // Ready release carries the current parameter snapshot.
        let snapshot = match recv_data(port, world, &mut chain)? {
            Down::Ready { bi: rbi, params } => {
                if rbi != bi {
                    bail!("worker {p}: Ready for batch {rbi} arrived while expecting batch {bi}");
                }
                params
            }
            Down::Grads { bi: gbi, .. } => {
                bail!("worker {p}: batch {gbi} gradients arrived before batch {bi}'s Ready")
            }
            Down::Store { bi: sbi, .. } => {
                bail!("worker {p}: batch {sbi} store delta escaped recv_data (protocol bug)")
            }
            Down::ReadyDiff { bi: dbi, .. } | Down::GradsDiff { bi: dbi, .. } => {
                bail!("worker {p}: batch {dbi} diff frame escaped recv_data (protocol bug)")
            }
        };
        let (sample, frontier, sample_s) = match prefetched.take() {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let filter = partition_edge_filter(world.tree, mp, p);
                let s = sample_tree(
                    world.g,
                    world.tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    cfg.train.batch_seed(epoch, bi),
                    filter,
                );
                let fr = cfg
                    .train
                    .dedup_fetch
                    .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
                (s, fr, t0.elapsed().as_secs_f64() * scale)
            }
        };

        // ---- forward stage on this worker's own context ----
        let fwd = wp.raf_forward(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            &sample,
            frontier.as_ref(),
            chunk,
            sample_s,
            &mut arena,
        )?;
        // Mesh mode folds the partials peer-to-peer before the leader
        // lane sees them (non-terminal workers ship empty tensors).
        let (p1, p2) = match mesh {
            Some(m) => mesh_exchange(m, p, mp.num_parts, bi, fwd.p1, fwd.p2)?,
            None => (fwd.p1, fwd.p2),
        };
        port.send(Up::Fwd {
            bi,
            p1,
            p2,
            stats: fwd.stats,
            span: fwd.span,
            stages: fwd.stages,
            wall_fwd: fwd.wall_fwd,
        })?;

        // ---- double-buffer: prefetch batch i+1 during the leader phase
        // (sampling *and* the dedup frontier, so the dedup work overlaps
        // the leader's gather/step/scatter) ----
        if pipeline && bi + 1 < batches.len() {
            let t = Instant::now();
            let filter = partition_edge_filter(world.tree, mp, p);
            let s = sample_tree(
                world.g,
                world.tree,
                &cfg.model.fanouts,
                &batches[bi + 1],
                0,
                cfg.train.batch_seed(epoch, bi + 1),
                filter,
            );
            let fr = cfg
                .train
                .dedup_fetch
                .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
            prefetched = Some((s, fr, t.elapsed().as_secs_f64() * scale));
        }

        // ---- backward stage: ∂partials + the post-head-update snapshot ----
        let (g1, g2, snapshot) = match recv_data(port, world, &mut chain)? {
            Down::Grads { bi: gbi, g1, g2, params } => {
                if gbi != bi {
                    bail!("worker {p}: gradients for batch {gbi} arrived while expecting {bi}");
                }
                (g1, g2, params)
            }
            Down::Ready { bi: rbi, .. } => {
                bail!("worker {p}: batch {rbi} Ready arrived before batch {bi}'s gradients")
            }
            Down::Store { bi: sbi, .. } => {
                bail!("worker {p}: batch {sbi} store delta escaped recv_data (protocol bug)")
            }
            Down::ReadyDiff { bi: dbi, .. } | Down::GradsDiff { bi: dbi, .. } => {
                bail!("worker {p}: batch {dbi} diff frame escaped recv_data (protocol bug)")
            }
        };
        let bwd = wp.raf_backward(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            &sample,
            frontier.as_ref(),
            chunk,
            g1,
            g2,
            &mut arena,
        )?;
        port.send(Up::Bwd {
            bi,
            grads: bwd.grads,
            bwd_s: bwd.bwd_s,
            stages: bwd.stages,
            wall_bwd: bwd.wall_bwd,
        })?;
        // Batch done; recycle the frontier allocation for a later
        // prefetch (the i+1 prefetch above already took the other one).
        if let Some(f) = frontier {
            spare = Some(f);
        }
    }
    // ---- flight-recorder exchange: publish this rank's cache deltas,
    // then ship the (possibly empty) trace blob leader-ward. Always
    // sent, so the protocol shape is identical tracing on or off. ----
    crate::obs::record_cache_obs(world.g, ctx.cache.as_ref(), cache_base.as_deref());
    port.send(Up::Obs { blob: crate::obs::TraceBlob::collect(p as u32) })?;
    Ok(())
}

/// The windowed (`staleness = k >= 1`) worker: a resumable per-batch
/// state machine driven by the leader's message order. A `Ready`
/// release opens a batch — sample, marshal and execute its forward
/// against the shipped snapshot, then park it as [`InFlight`] — and a
/// `Grads` scatter closes the oldest open batch with its backward. The
/// leader interleaves releases ahead of scatters (forward of `i+k`
/// before backward of `i`), which is exactly the 1F1B schedule; up to
/// `k + 1` batches are open at once, each owning its arena so backward
/// rebuilds scatter from their own forward's staged rows.
#[allow(clippy::too_many_arguments)]
fn worker_run_windowed<EU, ED, BU, BD, EM>(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    mp: &MetaPartition,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down, EU, ED>,
    bport: &Port<(), (), BU, BD>,
    mesh: Option<&EM>,
    staleness: usize,
    cur: &AtomicUsize,
) -> Result<()>
where
    EU: Transport<Up>,
    ED: Transport<Down>,
    BU: Transport<()>,
    BD: Transport<()>,
    EM: Transport<MeshFwd>,
{
    bport.barrier()?;
    let mut chain = SnapshotChain::new();
    let p = ctx.worker;
    if world.cfg.train.trace {
        crate::obs::thread_register(p as u32, "worker");
    }
    let cache_base = crate::obs::cache_obs_base(ctx.cache.as_ref());
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[p];
    let mut open: VecDeque<InFlight> = VecDeque::with_capacity(staleness + 1);
    let mut arena_pool: Vec<BatchArena> = Vec::new();
    let mut frontier_pool: Vec<Frontier> = Vec::new();
    let mut next_ready = 0usize; // releases arrive in batch order
    let mut completed = 0usize;

    while completed < batches.len() {
        match recv_data(port, world, &mut chain)? {
            Down::Store { bi, .. } => {
                bail!("worker {p}: batch {bi} store delta escaped recv_data (protocol bug)")
            }
            Down::ReadyDiff { bi, .. } | Down::GradsDiff { bi, .. } => {
                bail!("worker {p}: batch {bi} diff frame escaped recv_data (protocol bug)")
            }
            Down::Ready { bi, params } => {
                if bi != next_ready {
                    bail!("worker {p}: release for batch {bi} arrived, expected {next_ready}");
                }
                next_ready += 1;
                cur.store(bi, Ordering::Relaxed);
                crate::obs::set_batch(bi as u64);
                port.maybe_fault(&cfg.train, epoch, bi)?;
                let chunk = &batches[bi];
                let t0 = Instant::now();
                let filter = partition_edge_filter(world.tree, mp, p);
                let sample = sample_tree(
                    world.g,
                    world.tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    cfg.train.batch_seed(epoch, bi),
                    filter,
                );
                let frontier = cfg.train.dedup_fetch.then(|| {
                    let mut spare = frontier_pool.pop();
                    Frontier::take_rebuilt(&mut spare, world.tree, &sample, ntypes, wp.needs_root)
                });
                let sample_s = t0.elapsed().as_secs_f64() * scale;
                let mut arena = arena_pool.pop().unwrap_or_default();
                let fwd = wp.raf_forward(
                    ctx,
                    world,
                    ParamsView::Snapshot(&params),
                    &sample,
                    frontier.as_ref(),
                    chunk,
                    sample_s,
                    &mut arena,
                )?;
                // Same relay as the sync loop. Deadlock-free under the
                // 1F1B window: every worker processes the leader's one
                // FIFO lane in the same order, so the whole mesh chain
                // for batch `bi` completes before any worker moves on
                // to a backward — and mesh edges only run p-1 → p, so
                // there is no cycle to wait on.
                let (p1, p2) = match mesh {
                    Some(m) => mesh_exchange(m, p, mp.num_parts, bi, fwd.p1, fwd.p2)?,
                    None => (fwd.p1, fwd.p2),
                };
                port.send(Up::Fwd {
                    bi,
                    p1,
                    p2,
                    stats: fwd.stats,
                    span: fwd.span,
                    stages: fwd.stages,
                    wall_fwd: fwd.wall_fwd,
                })?;
                open.push_back(InFlight {
                    bi,
                    sample,
                    frontier,
                    arena,
                });
            }
            Down::Grads { bi, g1, g2, params } => {
                let mut inflight = open.pop_front().ok_or_else(|| {
                    anyhow!("worker {p}: gradients for batch {bi} with no batch in flight")
                })?;
                if inflight.bi != bi {
                    bail!(
                        "worker {p}: gradients for batch {bi} but batch {} is the oldest in flight",
                        inflight.bi
                    );
                }
                cur.store(bi, Ordering::Relaxed);
                crate::obs::set_batch(bi as u64);
                let bwd = wp.raf_backward(
                    ctx,
                    world,
                    ParamsView::Snapshot(&params),
                    &inflight.sample,
                    inflight.frontier.as_ref(),
                    &batches[bi],
                    g1,
                    g2,
                    &mut inflight.arena,
                )?;
                port.send(Up::Bwd {
                    bi,
                    grads: bwd.grads,
                    bwd_s: bwd.bwd_s,
                    stages: bwd.stages,
                    wall_bwd: bwd.wall_bwd,
                })?;
                arena_pool.push(inflight.arena);
                if let Some(f) = inflight.frontier {
                    frontier_pool.push(f);
                }
                completed += 1;
            }
        }
    }
    // ---- flight-recorder exchange (see `worker_run_sync`) ----
    crate::obs::record_cache_obs(world.g, ctx.cache.as_ref(), cache_base.as_deref());
    port.send(Up::Obs { blob: crate::obs::TraceBlob::collect(p as u32) })?;
    Ok(())
}

/// Build batch `bi`'s release from the leader's diff chain: the full
/// snapshot when the chain is disabled or starting, else the
/// version-chained delta of exactly the tensors that advanced since
/// the previous frame. Returns the store version the release carries —
/// identical in both modes, so `ready_versions` (and the grad-lag
/// gauge) never depend on the wire format.
fn ready_release(chain: &mut DiffChain, params: &ParamStore, bi: usize) -> (u64, Down) {
    match chain.next(params) {
        SnapOrDiff::Full(snap) => {
            let v = snap.version;
            (v, Down::Ready { bi, params: snap })
        }
        SnapOrDiff::Diff(diff) => (diff.to_version, Down::ReadyDiff { bi, diff }),
    }
}

#[allow(clippy::too_many_arguments)]
fn leader_loop<EU, ED, BU, BD>(
    mut hub: Hub<Up, Down, EU, ED>,
    bhub: Hub<(), (), BU, BD>,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    leader_ctx: &mut ExecContext,
    params: &mut crate::runtime::ParamStore,
    adam_t: &mut i32,
    mut fork_leader: Option<&mut crate::cache::FeatureCache>,
    mut fork_p0: Option<&mut crate::cache::FeatureCache>,
    replica_count: &HashMap<String, usize>,
    batches: &[Vec<NodeId>],
    parts: usize,
    leader_part: usize,
    pipeline: bool,
    staleness: usize,
    replicate: bool,
) -> Result<EpochReport>
where
    EU: Transport<Up>,
    ED: Transport<Down>,
    BU: Transport<()>,
    BD: Transport<()>,
{
    bhub.barrier()?;
    let cfg = world.cfg;
    if cfg.train.trace {
        // The leader's rank id is `parts` — one past the worker ranks.
        crate::obs::thread_register(parts as u32, "leader");
    }
    // PR 8 wire knobs. The diff chain is per-epoch — its first frame is
    // always a full snapshot, which also covers the post-recovery
    // restart (recovery re-enters this loop) — and mesh mode moves the
    // partial-aggregation fold onto the worker↔worker relay, leaving
    // only the last worker's folded sums on the leader lane.
    let mesh = cfg.train.wire_exchange.is_mesh();
    let mut chain = DiffChain::new(cfg.train.wire_snapshots.is_diff());
    let b = cfg.train.batch_size;
    let h = cfg.model.hidden;
    let n = batches.len();
    let mut net = SimNet::new(parts, cfg.cost.clone());
    let mut timeline = EpochTimeline::new(parts);
    let mut stages = StageTimes::default();
    let mut worker_stages = vec![StageTimes::default(); parts];
    let mut wall = WallClock::new(parts);
    let mut leader_arena = BatchArena::new();
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut batch_losses = Vec::with_capacity(n);
    let mut batches_done = 0usize;
    let mut fetch = FetchStats::default();

    // Prime the release window: the synchronous protocol opens batch 0
    // only; a k-window opens k batches up front (batch j's snapshot then
    // trails by j <= k updates — within the bound).
    let mut released = 0usize;
    // Snapshot version each batch's release carried — the grad-version
    // lag observed at fold time is `grads_version - ready_versions[bi]`
    // (how far the forward's weights trailed the backward's).
    let mut ready_versions: Vec<u64> = Vec::with_capacity(n);
    for _ in 0..staleness.max(1).min(n) {
        // Consecutive primes see an unchanged store, so in diff mode
        // every prime after the first is an empty (from == to) diff.
        let (ver, msg) = ready_release(&mut chain, params, released);
        ready_versions.push(ver);
        hub.broadcast(msg)?;
        released += 1;
    }

    for (bi, chunk) in batches.iter().enumerate() {
        crate::obs::set_batch(bi as u64);
        // ---- gather worker partials (worker-id order) ----
        let ups = hub
            .gather_round(fwd_round(bi), up_tag)
            .with_context(|| format!("batch {bi}: collecting forward partials"))?;
        let wire: Vec<u64> = ups.iter().map(|u| u.wire_bytes()).collect();
        let mut partial_sums = [vec![0f32; b * h], vec![0f32; b * h]];
        let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
        for (w, up) in ups.into_iter().enumerate() {
            match up {
                Up::Fwd {
                    bi: ubi,
                    p1,
                    p2,
                    stats,
                    span,
                    stages: wstages,
                    wall_fwd,
                } => {
                    if ubi != bi {
                        bail!("protocol error: batch {ubi} partials in batch {bi}'s round");
                    }
                    if mesh {
                        // The relay already folded in worker-id order;
                        // only the chain's last worker carries the sums.
                        // Take them **verbatim** — re-adding them into
                        // the zeroed accumulators could flip a `-0.0`
                        // and break bit-identity with the star fold.
                        if w + 1 == parts {
                            ensure!(
                                p1.len() == b * h && p2.len() == b * h,
                                "batch {bi}: worker {w} closed the mesh fold with {} and {} \
                                 elems (expected {} each)",
                                p1.len(),
                                p2.len(),
                                b * h
                            );
                            partial_sums[0] = p1;
                            partial_sums[1] = p2;
                        } else {
                            ensure!(
                                p1.is_empty() && p2.is_empty(),
                                "batch {bi}: worker {w} shipped {} and {} partial elems on \
                                 the leader lane in mesh mode (the relay owns them)",
                                p1.len(),
                                p2.len()
                            );
                        }
                    } else {
                        add_assign(&mut partial_sums[0], &p1);
                        add_assign(&mut partial_sums[1], &p2);
                    }
                    fetch.merge(stats);
                    worker_spans.push(span);
                    stages.merge(&wstages);
                    worker_stages[w].merge(&wstages);
                    wall.record_forward(w, wall_fwd);
                }
                Up::Bwd { bi: ubi, .. } => {
                    bail!("protocol error: batch {ubi} gradients in batch {bi}'s forward round")
                }
                Up::Failed { bi: fbi, msg } => bail!(
                    "batch {fbi} death notice escaped gather_round's abort path \
                     (protocol bug): {msg}"
                ),
                Up::Obs { .. } => {
                    bail!("protocol error: trace blob in batch {bi}'s forward round")
                }
                Up::NeedFull { bi: nbi, have, want } => bail!(
                    "batch {nbi}: worker {w}'s resync NACK escaped gather_round's abort \
                     path (protocol bug): worker {w} {}",
                    need_full_msg(have, want)
                ),
            }
        }
        // ---- async release: batch bi+k goes out the moment batch bi's
        // partials landed, so its forward overlaps this batch's leader
        // phase, backward and update (staleness <= k by construction:
        // the snapshot carries every update through batch bi-1).
        //
        // No explicit store barrier is needed here (unlike the vanilla
        // engine's `Marshaled` notice): this batch's update — the next
        // store write — runs only after the backward gather below, a
        // worker ships its backward only after processing every earlier
        // Down message, and this release is sent *before* the gradient
        // scatter. So by the time `Bwd(bi)` arrives from worker w, w has
        // finished marshalling (store reads included) every batch
        // released so far — the backward gather IS the barrier, and
        // every marshal deterministically sees the updates through its
        // own release point. ----
        if staleness >= 1 && released < n {
            let (ver, msg) = ready_release(&mut chain, params, released);
            ready_versions.push(ver);
            hub.broadcast(msg)?;
            released += 1;
        }
        crate::obs::gauge_max("staleness.open", (released - bi) as f64);
        // The leader partition's partials are machine-local.
        let gather_bytes: Vec<u64> = wire
            .iter()
            .enumerate()
            .map(|(w, &bytes)| if w == leader_part { 0 } else { bytes })
            .collect();
        let t_gather = net.gather(leader_part, &gather_bytes)?;
        stages.add(Stage::Forward, t_gather);

        // ---- leader stage: cross-relation agg + head + loss + bwd ----
        let lo = plan.raf_leader_step(
            leader_ctx,
            world,
            params,
            adam_t,
            fork_leader.as_deref_mut(),
            &partial_sums,
            chunk,
            &mut leader_arena,
        )?;
        fetch.merge(lo.stats);
        stages.add(Stage::Forward, lo.leader_s * 0.5);
        stages.add(Stage::Backward, lo.leader_s * 0.5);
        stages.add(Stage::Update, lo.head_update_s);
        loss_sum += lo.loss;
        acc_sum += lo.acc;
        batch_losses.push(lo.loss);

        // ---- scatter gradients back (2 tensors per worker, symmetric),
        // with the post-head-update snapshot the backward marshals from ----
        let t_scatter = net.gather(leader_part, &gather_bytes)?;
        stages.add(Stage::Backward, t_scatter);
        // The gradient scatter rides the same diff chain as the
        // releases (one FIFO lane, alternating frame kinds).
        let (grads_version, gmsg) = match chain.next(params) {
            SnapOrDiff::Full(snap) => {
                let v = snap.version;
                (v, Down::Grads { bi, g1: lo.g1, g2: lo.g2, params: snap })
            }
            SnapOrDiff::Diff(diff) => (
                diff.to_version,
                Down::GradsDiff { bi, g1: lo.g1, g2: lo.g2, diff },
            ),
        };
        crate::obs::hist_observe(
            "grad.version_lag",
            grads_version.saturating_sub(ready_versions[bi]) as f64,
        );
        hub.broadcast(gmsg)?;

        // ---- gather worker gradients (worker-id order), holding every
        // fold to the snapshot version this batch's scatter shipped ----
        let ups = hub
            .gather_round(bwd_round(bi), up_tag)
            .with_context(|| format!("batch {bi}: collecting worker gradients"))?;
        let mut gacc = GradAccumulator::for_version(grads_version);
        for (w, up) in ups.into_iter().enumerate() {
            match up {
                Up::Bwd {
                    bi: ubi,
                    grads,
                    bwd_s,
                    stages: wstages,
                    wall_bwd,
                } => {
                    if ubi != bi {
                        bail!("protocol error: batch {ubi} gradients in batch {bi}'s round");
                    }
                    gacc.absorb(grads)
                        .with_context(|| format!("batch {bi}, worker {w}"))?;
                    if let Some(span) = worker_spans.get_mut(w) {
                        span.bwd_s = bwd_s;
                    }
                    stages.merge(&wstages);
                    worker_stages[w].merge(&wstages);
                    wall.record_backward(w, wall_bwd);
                }
                Up::Fwd { bi: ubi, .. } => {
                    bail!("protocol error: batch {ubi} partials in batch {bi}'s backward round")
                }
                Up::Failed { bi: fbi, msg } => bail!(
                    "batch {fbi} death notice escaped gather_round's abort path \
                     (protocol bug): {msg}"
                ),
                Up::Obs { .. } => {
                    bail!("protocol error: trace blob in batch {bi}'s backward round")
                }
                Up::NeedFull { bi: nbi, have, want } => bail!(
                    "batch {nbi}: worker {w}'s resync NACK escaped gather_round's abort \
                     path (protocol bug): worker {w} {}",
                    need_full_msg(have, want)
                ),
            }
        }

        // ---- update stage (weights + learnable features) ----
        let mut gx_root = lo.gx_root;
        let upd = raf_apply_updates(
            world,
            params,
            *adam_t,
            replica_count,
            &gacc,
            &mut gx_root,
            chunk,
            fork_leader.as_deref_mut(),
            fork_p0.as_deref_mut(),
        )?;
        stages.add(Stage::Update, upd.update_s + upd.lf_s);
        let sync_t = if upd.sync_bytes > 0 {
            let t = net.send(1 % parts, leader_part, upd.sync_bytes)?;
            stages.add(Stage::GradSync, t);
            t
        } else {
            0.0
        };
        // ---- TCP only: replicate this update's learnable-row writes
        // into every worker process's store. Sent *before* any later
        // release, so per-lane FIFO lands the delta ahead of every
        // marshal that must observe it — exactly the shared-store
        // visibility order. ----
        if replicate {
            let mut touched = gacc.touched_rows();
            touched.push((world.g.schema.target, chunk.clone()));
            let delta = {
                let store = world.store();
                StoreDelta::capture(&store, touched.iter().map(|(ty, ids)| (*ty, ids.as_slice())))
                    .with_context(|| format!("batch {bi}: capturing the learnable-row delta"))?
            };
            if !delta.is_empty() {
                hub.broadcast(Down::Store { bi, delta })?;
            }
        }

        timeline.push_batch(
            worker_spans,
            LeaderSpan {
                gather_s: t_gather,
                leader_s: lo.leader_s,
                scatter_s: t_scatter,
                update_s: lo.head_update_s + upd.update_s + upd.lf_s,
                sync_s: sync_t,
            },
        );
        batches_done += 1;
        // ---- synchronous release: batch bi+1 waits for this update ----
        if staleness == 0 && released < n {
            let (ver, msg) = ready_release(&mut chain, params, released);
            ready_versions.push(ver);
            hub.broadcast(msg)?;
            released += 1;
        }
    }

    // ---- flight-recorder exchange: every worker's last Up message is
    // its trace blob (empty when tracing is off — the gather happens
    // either way, keeping the protocol shape independent of the
    // flag). Merge them with the leader's own collection. ----
    let mut obs = crate::obs::ObsReport::default();
    for up in hub
        .gather_round(OBS_ROUND, up_tag)
        .context("collecting worker trace blobs")?
    {
        match up {
            Up::Obs { blob } => blob.merge_into(&mut obs),
            other => bail!("protocol error: {other:?} in the trace-blob round"),
        }
    }
    crate::obs::TraceBlob::collect(parts as u32).merge_into(&mut obs);

    let epoch_time_s = timeline.sequential_time();
    let critical_path_s = if staleness >= 1 {
        timeline.async_pipelined_time(staleness, AsyncShape::Raf)
    } else if pipeline {
        timeline.pipelined_time()
    } else {
        epoch_time_s
    };
    Ok(EpochReport {
        epoch_time_s,
        critical_path_s,
        worker_busy_s: timeline.worker_busy_s(),
        worker_stages,
        wall,
        stages,
        comm: net.total(),
        fetch,
        wire: Default::default(), // the in-process transports move no frames
        loss_mean: if batches_done > 0 {
            loss_sum / batches_done as f64
        } else {
            f64::NAN
        },
        accuracy: if batches_done > 0 {
            acc_sum / (batches_done * b) as f64
        } else {
            f64::NAN
        },
        batches: batches_done,
        batch_losses,
        obs,
    })
}

/// One process's typed socket lanes for this engine's protocol — the
/// shared [`Lanes`](super::Lanes) bundle instantiated with the
/// engine's private message enums, plus (PR 8) the optional
/// worker↔worker relay lane. Opened once per training run and reused
/// across epochs.
pub struct TcpLanes {
    lanes: super::Lanes<Up, Down>,
    /// The mesh relay lane (`wire_exchange = mesh`): present only on
    /// worker ranks of a mesh-dialed node — the leader carries no
    /// relay traffic, and star-dialed nodes have no worker↔worker
    /// connections to open it over.
    mesh: Option<TcpChannel<MeshFwd>>,
}

impl TcpLanes {
    pub fn open(node: &TcpNode, parts: usize, mesh: bool) -> Result<TcpLanes> {
        let lanes = super::Lanes::open(node, parts)?;
        let mesh = if mesh && lanes.role != Role::Leader {
            Some(node.open_lane(LANE_MESH_DATA)?)
        } else {
            None
        };
        Ok(TcpLanes { lanes, mesh })
    }
}

/// Run one RAF epoch of a **multi-process** cluster: this process plays
/// exactly the rank its [`TcpLanes`] were opened for — the leader loop
/// or one partition's worker loop — over the socket star. Every process
/// derives the identical batch schedule from the config seeds; worker
/// ranks return an empty report (plus their wire traffic), the leader's
/// report carries the losses and is byte-identical to the in-process
/// channel transport at any fixed staleness.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch_tcp(
    plan: &BatchPlan,
    contexts: &mut [ExecContext],
    leader_ctx: &mut ExecContext,
    mp: &MetaPartition,
    replica_count: &HashMap<String, usize>,
    leader_part: usize,
    gate: Option<&ExecGate>,
    sess: &mut Session,
    epoch: usize,
    lanes: &TcpLanes,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = mp.num_parts;
    let pipeline = cfg.train.pipeline;
    let staleness = if pipeline { cfg.train.staleness } else { 0 };
    if staleness > 0 && !cfg.train.dedup_fetch {
        bail!(
            "train.staleness = {staleness} requires train.dedup_fetch (the backward \
             rebuild reuses the forward's staged rows)"
        );
    }
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);
    let batches = batch_schedule(&g, &cfg, epoch);
    if batches.is_empty() {
        // Every rank computes the same empty schedule and skips the
        // epoch without touching the wire.
        return Ok(EpochReport::empty(parts));
    }
    let world = EpochWorld {
        cfg: &cfg,
        g: &g,
        tree: &tree,
        store: &sess.store,
        gate,
        epoch_t0: Instant::now(),
    };
    let wire0 = lanes.lanes.traffic();

    match lanes.lanes.role {
        Role::Leader => {
            let mut fork_leader = contexts[leader_part]
                .cache
                .as_ref()
                .map(|c| c.fork_ledger());
            let mut fork_p0 = contexts[0].cache.as_ref().map(|c| c.fork_ledger());
            let hub = Hub::from_endpoints(&lanes.lanes.up, &lanes.lanes.down, parts);
            let bhub = Hub::from_endpoints(&lanes.lanes.bar_up, &lanes.lanes.bar_down, parts);
            let led = leader_loop(
                hub,
                bhub,
                plan,
                &world,
                leader_ctx,
                &mut sess.params,
                &mut sess.adam_t,
                fork_leader.as_mut(),
                fork_p0.as_mut(),
                replica_count,
                &batches,
                parts,
                leader_part,
                pipeline,
                staleness,
                true, // every worker process owns a store replica
            );
            if let Some(f) = fork_leader {
                if let Some(c) = contexts[leader_part].cache.as_mut() {
                    c.absorb_ledger(&f);
                }
            }
            if let Some(f) = fork_p0 {
                if let Some(c) = contexts[0].cache.as_mut() {
                    c.absorb_ledger(&f);
                }
            }
            let mut rep = led?;
            rep.wire = lanes.lanes.traffic().since(&wire0);
            Ok(rep)
        }
        Role::Worker(w) => {
            let ctx = contexts
                .get_mut(w)
                .ok_or_else(|| anyhow!("worker rank {w} outside the {parts}-partition plan"))?;
            let port = Port::from_endpoints(&lanes.lanes.up, &lanes.lanes.down, parts);
            let bport = Port::from_endpoints(&lanes.lanes.bar_up, &lanes.lanes.bar_down, parts);
            worker_loop(
                ctx,
                plan,
                &world,
                mp,
                epoch,
                &batches,
                &port,
                &bport,
                lanes.mesh.as_ref(),
                pipeline,
                staleness,
            )?;
            let mut rep = EpochReport::empty(parts);
            rep.wire = lanes.lanes.traffic().since(&wire0);
            Ok(rep)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::codec::{decode_message, encode_message};

    fn snapshot_fixture() -> Arc<ParamSnapshot> {
        Arc::new(ParamSnapshot::from_tensors(
            9,
            vec![("w_head".into(), vec![0.5, -0.5]), ("w_rel".into(), vec![1.0])],
        ))
    }

    #[test]
    fn raf_up_messages_round_trip() {
        let msgs = [
            Up::Fwd {
                bi: 3,
                p1: vec![1.0, 2.0],
                p2: vec![-1.0],
                stats: FetchStats { rows: 5, bytes: 80, remote_rows: 0, remote_bytes: 0 },
                span: WorkerSpan { sample_s: 0.1, fwd_s: 0.2, ..Default::default() },
                stages: StageTimes { secs: [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0] },
                wall_fwd: (0.25, 0.5),
            },
            Up::Bwd {
                bi: usize::MAX, // NO_BATCH-shaped indices must survive
                grads: crate::exec::WorkerGrads {
                    wgrads: vec![("w".into(), vec![0.125])],
                    row_grads: vec![(1, vec![3, 4], vec![0.5; 4])],
                    gx: vec![vec![2.0]],
                    learnable_rows: vec![(1, 2, 0)],
                    param_version: 7,
                },
                bwd_s: 0.75,
                stages: StageTimes::default(),
                wall_bwd: (1.0, 2.0),
            },
            Up::Failed { bi: 11, msg: "worker 2 panicked".into() },
            // `have = u64::MAX` is the no-snapshot-yet sentinel.
            Up::NeedFull { bi: 5, have: u64::MAX, want: 12 },
            Up::NeedFull { bi: 6, have: 9, want: 12 },
            Up::Obs {
                blob: crate::obs::TraceBlob {
                    rank: 1,
                    tracks: vec![crate::obs::TraceTrack {
                        rank: 1,
                        thread: "worker".into(),
                        dropped: 0,
                        names: vec!["fwd".into()],
                        events: vec![crate::obs::ObsEvent {
                            batch: 3,
                            kind: crate::obs::KIND_COMPUTE,
                            lane: crate::obs::LANE_NONE,
                            name_idx: 0,
                            t0_us: 10,
                            t1_us: 25,
                        }],
                    }],
                    metrics: crate::obs::MetricsSnapshot {
                        counters: vec![("cache.paper.hits".into(), 5)],
                        ..Default::default()
                    },
                },
            },
        ];
        for m in msgs {
            let bytes = encode_message(&m);
            let back: Up = decode_message(&bytes).unwrap();
            assert_eq!(back, m);
            // Modeled bytes never exceed the encoded frame.
            assert!(m.wire_bytes() <= bytes.len() as u64, "{m:?}");
        }
    }

    #[test]
    fn raf_down_messages_round_trip() {
        let msgs = [
            Down::Ready { bi: 0, params: snapshot_fixture() },
            Down::Grads {
                bi: 4,
                g1: vec![0.5; 6],
                g2: vec![-0.5; 6],
                params: snapshot_fixture(),
            },
            Down::Store {
                bi: 2,
                delta: StoreDelta { rows: vec![(1, vec![7, 9], vec![0.1, 0.2])] },
            },
            Down::ReadyDiff {
                bi: 7,
                diff: ParamDiff::from_tensors(
                    9,
                    11,
                    vec![("w_head".into(), vec![0.25, -0.0])],
                ),
            },
            Down::GradsDiff {
                bi: 8,
                g1: vec![1.5; 4],
                g2: vec![-1.5; 4],
                diff: ParamDiff::from_tensors(11, 11, vec![]),
            },
        ];
        for m in msgs {
            let bytes = encode_message(&m);
            let back: Down = decode_message(&bytes).unwrap();
            assert_eq!(back, m);
            assert!(m.wire_bytes() <= bytes.len() as u64);
        }
    }

    #[test]
    fn mesh_relay_round_trips_and_prices_both_tensors() {
        let m = MeshFwd { bi: 3, acc1: vec![1.0, -0.0], acc2: vec![0.5; 3] };
        let bytes = encode_message(&m);
        let back: MeshFwd = decode_message(&bytes).unwrap();
        // -0.0 must survive bit-for-bit (the verbatim-take invariant).
        assert_eq!(back.acc1[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(back, m);
        assert_eq!(m.wire_bytes(), 4 * (2 + 3));
        assert!(m.wire_bytes() <= bytes.len() as u64);
    }

    #[test]
    fn mesh_exchange_folds_in_worker_id_order() {
        // 3 workers over an in-process mesh: the chain must reproduce
        // the star fold (zeros + p0 + p1 + p2) exactly, with only the
        // last worker returning tensors.
        let meshes = Mailbox::<MeshFwd>::mesh(3);
        let owns = [vec![1.0f32, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        let outs = std::thread::scope(|s| {
            let handles: Vec<_> = meshes
                .iter()
                .enumerate()
                .map(|(p, m)| {
                    let own = owns[p].clone();
                    s.spawn(move || mesh_exchange(m, p, 3, 4, own.clone(), own))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap().unwrap())
                .collect::<Vec<_>>()
        });
        assert!(outs[0].0.is_empty() && outs[0].1.is_empty());
        assert!(outs[1].0.is_empty() && outs[1].1.is_empty());
        assert_eq!(outs[2].0, vec![111.0, 222.0]);
        assert_eq!(outs[2].1, vec![111.0, 222.0]);
    }

    #[test]
    fn mesh_exchange_rejects_batch_mismatch() {
        let meshes = Mailbox::<MeshFwd>::mesh(2);
        meshes[0]
            .send(1, MeshFwd { bi: 9, acc1: vec![0.0], acc2: vec![0.0] })
            .unwrap();
        let err = mesh_exchange(&meshes[1], 1, 2, 4, vec![1.0], vec![1.0]).unwrap_err();
        assert!(format!("{err}").contains("batch 9"), "{err}");
    }

    #[test]
    fn corrupt_tags_and_truncations_are_rejected() {
        let mut bytes = encode_message(&Up::Failed { bi: 1, msg: "x".into() });
        bytes[0] = 0xFF;
        let err = decode_message::<Up>(&bytes).unwrap_err();
        assert!(format!("{err}").contains("tag 255"), "{err}");

        let down = Down::Ready { bi: 1, params: snapshot_fixture() };
        let bytes = encode_message(&down);
        for cut in 0..bytes.len() {
            assert!(
                decode_message::<Down>(&bytes[..cut]).is_err(),
                "truncation at {cut} must error, not panic"
            );
        }
        let mut long = bytes.clone();
        long.push(7);
        assert!(decode_message::<Down>(&long).is_err(), "trailing bytes rejected");
        assert!(decode_message::<Down>(&[9]).is_err(), "unknown Down tag rejected");
    }
}
