//! The RAF engine on the cluster runtime.
//!
//! One OS thread per partition; the calling thread is the leader. Each
//! worker thread exclusively owns its partition's
//! [`ExecContext`](crate::exec::ExecContext), so per batch the workers
//! sample, marshal and execute `worker_fwd` **concurrently** on their
//! own PJRT clients; the leader gathers partials in worker order, runs
//! the `leader` artifact on its own context, scatters `∂partials` (with
//! the post-head-update parameter snapshot), gathers worker gradients
//! in worker order and applies all updates. With `train.pipeline` on,
//! each worker prefetches batch `i+1`'s sample right after shipping its
//! batch-`i` partials, so prefetch work hides inside the leader phase —
//! the double-buffered schedule priced by [`crate::metrics::timeline`].
//!
//! Parameters are leader-owned: workers marshal weights from the
//! versioned read-only snapshot broadcast at each batch's release (the
//! `Ready` message) and the backward pass from the refreshed snapshot
//! riding the gradient scatter. The leader's cache traffic goes through
//! fork-ledger views of the partition caches (shared residency, private
//! hit/miss counters), folded back after the worker threads exit — the
//! runtime is lock-free end to end.
//!
//! Every floating-point reduction folds in (worker, output) order —
//! exactly the order the sequential engine uses — so losses and
//! parameter trajectories are byte-identical to the sequential runtime
//! under any thread interleaving.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::comm::SimNet;
use crate::config::{partition_edge_filter, Config};
use crate::coordinator::common::Session;
use crate::exec::plan::raf_apply_updates;
use crate::exec::{BatchPlan, EpochWorld, ExecContext, ExecGate, GradAccumulator, ParamsView};
use crate::hetgraph::NodeId;
use crate::kvstore::FetchStats;
use crate::metrics::timeline::{EpochTimeline, LeaderSpan, WallClock, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::MetaPartition;
use crate::runtime::ParamSnapshot;
use crate::sampling::{sample_tree, Frontier, TreeSample};
use crate::util::{add_assign, rng::Rng};

use super::collective::{star, Hub, Port};
use super::mailbox::{slice_bytes, Wire};

/// Worker → leader messages.
enum Up {
    Fwd {
        p1: Vec<f32>,
        p2: Vec<f32>,
        /// KV-store fetch accounting of the forward input build (unique
        /// rows per batch when dedup gather is on).
        stats: FetchStats,
        span: WorkerSpan,
        stages: StageTimes,
        /// Wall-clock forward-execution interval (epoch-relative) — the
        /// per-context overlap evidence.
        wall_fwd: (f64, f64),
    },
    Bwd {
        /// Unreduced gradient outputs — the leader folds them in
        /// (worker, output) order to match the sequential engine's
        /// float-accumulation order exactly.
        grads: crate::exec::WorkerGrads,
        bwd_s: f64,
        stages: StageTimes,
    },
    /// Best-effort death notice: without it, a leader gathering from a
    /// dead worker would block forever while live workers keep the
    /// channel connected.
    Failed(String),
}

impl Wire for Up {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] forward partials per worker (Props. 2–3).
            Up::Fwd { p1, p2, .. } => slice_bytes(p1) + slice_bytes(p2),
            // Model-parallel weight/row grads are applied locally by
            // their owning partition in the modeled system; shipping
            // them to the leader-owned store is an in-process artifact,
            // not wire traffic. Replica sync is charged separately,
            // exactly as in the sequential engine.
            Up::Bwd { .. } => 0,
            Up::Failed(_) => 0,
        }
    }
}

/// Leader → worker messages. Both carry the current parameter snapshot:
/// `Ready` releases the next batch with the post-update weights,
/// `Grads` ships `∂partials` plus the post-head-update weights the
/// backward rebuild marshals from. In the modeled system each partition
/// owns its weights locally (model parallelism), so snapshot
/// distribution is an in-process artifact of the single-machine
/// harness, not wire traffic — only the 2·[B,H] gradients count.
#[derive(Clone)]
enum Down {
    Grads {
        g1: Vec<f32>,
        g2: Vec<f32>,
        params: Arc<ParamSnapshot>,
    },
    Ready {
        params: Arc<ParamSnapshot>,
    },
}

impl Wire for Down {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] backward partial-gradients per worker.
            Down::Grads { g1, g2, .. } => slice_bytes(g1) + slice_bytes(g2),
            Down::Ready { .. } => 0,
        }
    }
}

/// Run one RAF epoch on the cluster runtime.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch(
    plan: &BatchPlan,
    contexts: &mut [ExecContext],
    leader_ctx: &mut ExecContext,
    mp: &MetaPartition,
    replica_count: &HashMap<String, usize>,
    leader_part: usize,
    gate: Option<&ExecGate>,
    sess: &mut Session,
    epoch: usize,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = mp.num_parts;
    let pipeline = cfg.train.pipeline;
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);

    let mut train = sess.g.train_nodes();
    let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
    shuffle_rng.shuffle(&mut train);
    let b = cfg.train.batch_size;
    let batches: Vec<Vec<NodeId>> = train
        .chunks(b)
        .filter(|c| c.len() == b) // drop the ragged tail (static shapes)
        .map(|c| c.to_vec())
        .collect();
    if batches.is_empty() {
        // Nothing to release: spawning workers would race the initial
        // Ready broadcast against their immediate teardown.
        return Ok(EpochReport::empty(parts));
    }

    // The leader's cache traffic runs through fork-ledger views while
    // the worker threads own the primaries; counts fold back below.
    let mut fork_leader = contexts[leader_part]
        .cache
        .as_ref()
        .map(|c| c.fork_ledger());
    let mut fork_p0 = contexts[0].cache.as_ref().map(|c| c.fork_ledger());

    let world = EpochWorld {
        cfg: &cfg,
        g: &g,
        tree: &tree,
        store: &sess.store,
        gate,
        epoch_t0: Instant::now(),
    };
    let params = &mut sess.params;
    let adam_t = &mut sess.adam_t;

    let (hub, ports) = star::<Up, Down>(parts);
    let (bhub, bports) = star::<(), ()>(parts);

    let report = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts);
        for ((ctx, port), bport) in contexts.iter_mut().zip(ports).zip(bports) {
            let world = &world;
            let batches = &batches;
            handles.push(s.spawn(move || {
                worker_loop(ctx, plan, world, mp, epoch, batches, &port, &bport, pipeline)
            }));
        }
        let led = leader_loop(
            hub,
            bhub,
            plan,
            &world,
            leader_ctx,
            params,
            adam_t,
            fork_leader.as_mut(),
            fork_p0.as_mut(),
            replica_count,
            &batches,
            parts,
            leader_part,
            pipeline,
        );
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e);
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        // The leader's error already embeds worker root causes (via
        // `Up::Failed`), so it wins; worker errors cover the remainder.
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    });

    if let Some(f) = fork_leader {
        if let Some(c) = contexts[leader_part].cache.as_mut() {
            c.absorb_ledger(&f);
        }
    }
    if let Some(f) = fork_p0 {
        if let Some(c) = contexts[0].cache.as_mut() {
            c.absorb_ledger(&f);
        }
    }
    report
}

/// Runs the worker body; on error, ships a best-effort death notice so
/// the leader's gather fails fast instead of blocking on a dead peer.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    mp: &MetaPartition,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down>,
    bport: &Port<(), ()>,
    pipeline: bool,
) -> Result<()> {
    // Contain panics too: a panicked worker that never notified the
    // leader would leave the gather blocked while live peers keep the
    // channel connected.
    let p = ctx.worker;
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        worker_run(ctx, plan, world, mp, epoch, batches, port, bport, pipeline)
    }));
    let r = caught.unwrap_or_else(|_| Err(anyhow!("worker {p} panicked")));
    if let Err(e) = &r {
        let _ = port.send(Up::Failed(format!("{e:#}")));
    }
    r
}

#[allow(clippy::too_many_arguments)]
fn worker_run(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    mp: &MetaPartition,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down>,
    bport: &Port<(), ()>,
    pipeline: bool,
) -> Result<()> {
    bport.barrier()?;
    let p = ctx.worker;
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[p];
    // Per-thread dedup-frontier scratch; `spare` lets two frontier
    // allocations ping-pong with the double-buffered prefetch (the
    // in-flight batch holds one while the prefetch fills the other).
    let mut spare: Option<Frontier> = None;
    let mut prefetched: Option<(TreeSample, Option<Frontier>, f64)> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        // Batch i's forward needs batch i-1's updated weights: the
        // Ready release carries the current parameter snapshot.
        let snapshot = match port.recv()? {
            Down::Ready { params } => params,
            Down::Grads { .. } => bail!("worker {p}: gradients arrived before Ready"),
        };
        let (sample, frontier, sample_s) = match prefetched.take() {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let filter = partition_edge_filter(world.tree, mp, p);
                let s = sample_tree(
                    world.g,
                    world.tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    cfg.train.batch_seed(epoch, bi),
                    filter,
                );
                let fr = cfg
                    .train
                    .dedup_fetch
                    .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
                (s, fr, t0.elapsed().as_secs_f64() * scale)
            }
        };

        // ---- forward stage on this worker's own context ----
        let fwd = wp.raf_forward(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            &sample,
            frontier.as_ref(),
            chunk,
            sample_s,
        )?;
        port.send(Up::Fwd {
            p1: fwd.p1,
            p2: fwd.p2,
            stats: fwd.stats,
            span: fwd.span,
            stages: fwd.stages,
            wall_fwd: fwd.wall_fwd,
        })?;

        // ---- double-buffer: prefetch batch i+1 during the leader phase
        // (sampling *and* the dedup frontier, so the dedup work overlaps
        // the leader's gather/step/scatter) ----
        if pipeline && bi + 1 < batches.len() {
            let t = Instant::now();
            let filter = partition_edge_filter(world.tree, mp, p);
            let s = sample_tree(
                world.g,
                world.tree,
                &cfg.model.fanouts,
                &batches[bi + 1],
                0,
                cfg.train.batch_seed(epoch, bi + 1),
                filter,
            );
            let fr = cfg
                .train
                .dedup_fetch
                .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
            prefetched = Some((s, fr, t.elapsed().as_secs_f64() * scale));
        }

        // ---- backward stage: ∂partials + the post-head-update snapshot ----
        let (g1, g2, snapshot) = match port.recv()? {
            Down::Grads { g1, g2, params } => (g1, g2, params),
            Down::Ready { .. } => bail!("worker {p}: Ready arrived before gradients"),
        };
        let bwd = wp.raf_backward(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            &sample,
            frontier.as_ref(),
            chunk,
            g1,
            g2,
        )?;
        port.send(Up::Bwd {
            grads: bwd.grads,
            bwd_s: bwd.bwd_s,
            stages: bwd.stages,
        })?;
        // Batch done; recycle the frontier allocation for a later
        // prefetch (the i+1 prefetch above already took the other one).
        if let Some(f) = frontier {
            spare = Some(f);
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    hub: Hub<Up, Down>,
    bhub: Hub<(), ()>,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    leader_ctx: &mut ExecContext,
    params: &mut crate::runtime::ParamStore,
    adam_t: &mut i32,
    mut fork_leader: Option<&mut crate::cache::FeatureCache>,
    mut fork_p0: Option<&mut crate::cache::FeatureCache>,
    replica_count: &HashMap<String, usize>,
    batches: &[Vec<NodeId>],
    parts: usize,
    leader_part: usize,
    pipeline: bool,
) -> Result<EpochReport> {
    bhub.barrier()?;
    let cfg = world.cfg;
    let b = cfg.train.batch_size;
    let h = cfg.model.hidden;
    let mut net = SimNet::new(parts, cfg.cost.clone());
    let mut timeline = EpochTimeline::new(parts);
    let mut stages = StageTimes::default();
    let mut worker_stages = vec![StageTimes::default(); parts];
    let mut wall = WallClock::new(parts);
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut batches_done = 0usize;
    let mut fetch = FetchStats::default();

    // Release batch 0 with the initial weights.
    hub.broadcast(Down::Ready {
        params: Arc::new(params.snapshot()),
    })?;

    for (bi, chunk) in batches.iter().enumerate() {
        // ---- gather worker partials (worker-id order) ----
        let ups = hub.gather()?;
        let wire: Vec<u64> = ups.iter().map(|u| u.wire_bytes()).collect();
        let mut partial_sums = [vec![0f32; b * h], vec![0f32; b * h]];
        let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
        for (w, up) in ups.into_iter().enumerate() {
            match up {
                Up::Fwd {
                    p1,
                    p2,
                    stats,
                    span,
                    stages: wstages,
                    wall_fwd,
                } => {
                    add_assign(&mut partial_sums[0], &p1);
                    add_assign(&mut partial_sums[1], &p2);
                    fetch.merge(stats);
                    worker_spans.push(span);
                    stages.merge(&wstages);
                    worker_stages[w].merge(&wstages);
                    wall.record_forward(w, wall_fwd);
                }
                Up::Bwd { .. } => bail!("protocol error: Bwd before Fwd from worker {w}"),
                Up::Failed(msg) => bail!("worker {w} failed: {msg}"),
            }
        }
        // The leader partition's partials are machine-local.
        let gather_bytes: Vec<u64> = wire
            .iter()
            .enumerate()
            .map(|(w, &bytes)| if w == leader_part { 0 } else { bytes })
            .collect();
        let t_gather = net.gather(leader_part, &gather_bytes)?;
        stages.add(Stage::Forward, t_gather);

        // ---- leader stage: cross-relation agg + head + loss + bwd ----
        let lo = plan.raf_leader_step(
            leader_ctx,
            world,
            params,
            adam_t,
            fork_leader.as_deref_mut(),
            &partial_sums,
            chunk,
        )?;
        fetch.merge(lo.stats);
        stages.add(Stage::Forward, lo.leader_s * 0.5);
        stages.add(Stage::Backward, lo.leader_s * 0.5);
        stages.add(Stage::Update, lo.head_update_s);
        loss_sum += lo.loss;
        acc_sum += lo.acc;

        // ---- scatter gradients back (2 tensors per worker, symmetric),
        // with the post-head-update snapshot the backward marshals from ----
        let t_scatter = net.gather(leader_part, &gather_bytes)?;
        stages.add(Stage::Backward, t_scatter);
        hub.broadcast(Down::Grads {
            g1: lo.g1,
            g2: lo.g2,
            params: Arc::new(params.snapshot()),
        })?;

        // ---- gather worker gradients (worker-id order) ----
        let ups = hub.gather()?;
        let mut gacc = GradAccumulator::default();
        for (w, up) in ups.into_iter().enumerate() {
            match up {
                Up::Bwd {
                    grads,
                    bwd_s,
                    stages: wstages,
                } => {
                    gacc.absorb(grads);
                    if let Some(span) = worker_spans.get_mut(w) {
                        span.bwd_s = bwd_s;
                    }
                    stages.merge(&wstages);
                    worker_stages[w].merge(&wstages);
                }
                Up::Fwd { .. } => bail!("protocol error: Fwd before Bwd from worker {w}"),
                Up::Failed(msg) => bail!("worker {w} failed: {msg}"),
            }
        }

        // ---- update stage (weights + learnable features) ----
        let mut gx_root = lo.gx_root;
        let upd = raf_apply_updates(
            world,
            params,
            *adam_t,
            replica_count,
            &gacc,
            &mut gx_root,
            chunk,
            fork_leader.as_deref_mut(),
            fork_p0.as_deref_mut(),
        )?;
        stages.add(Stage::Update, upd.update_s + upd.lf_s);
        let sync_t = if upd.sync_bytes > 0 {
            let t = net.send(1 % parts, leader_part, upd.sync_bytes)?;
            stages.add(Stage::GradSync, t);
            t
        } else {
            0.0
        };

        timeline.push_batch(
            worker_spans,
            LeaderSpan {
                gather_s: t_gather,
                leader_s: lo.leader_s,
                scatter_s: t_scatter,
                update_s: lo.head_update_s + upd.update_s + upd.lf_s,
                sync_s: sync_t,
            },
        );
        batches_done += 1;
        if bi + 1 < batches.len() {
            hub.broadcast(Down::Ready {
                params: Arc::new(params.snapshot()),
            })?;
        }
    }

    let epoch_time_s = timeline.sequential_time();
    let critical_path_s = if pipeline {
        timeline.pipelined_time()
    } else {
        epoch_time_s
    };
    Ok(EpochReport {
        epoch_time_s,
        critical_path_s,
        worker_busy_s: timeline.worker_busy_s(),
        worker_stages,
        wall,
        stages,
        comm: net.total(),
        fetch,
        loss_mean: if batches_done > 0 {
            loss_sum / batches_done as f64
        } else {
            f64::NAN
        },
        accuracy: if batches_done > 0 {
            acc_sum / (batches_done * b) as f64
        } else {
            f64::NAN
        },
        batches: batches_done,
    })
}
