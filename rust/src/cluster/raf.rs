//! The RAF engine on the cluster runtime.
//!
//! One OS thread per partition; the calling thread is the leader. Each
//! worker thread exclusively owns its partition's
//! [`ExecContext`](crate::exec::ExecContext), so per batch the workers
//! sample, marshal and execute `worker_fwd` **concurrently** on their
//! own PJRT clients; the leader gathers partials in worker order, runs
//! the `leader` artifact on its own context, scatters `∂partials` (with
//! the post-head-update parameter snapshot), gathers worker gradients
//! in worker order and applies all updates.
//!
//! Two overlap levers stack on this (PR 1 and PR 4):
//!
//! * `train.pipeline` — the synchronous double-buffer: each worker
//!   prefetches batch `i+1`'s sample right after shipping its batch-`i`
//!   partials, hiding prefetch work inside the leader phase.
//! * `train.staleness = k >= 1` — the async 1F1B window: the leader
//!   releases batch `i+k` right after gathering batch `i`'s partials,
//!   so workers marshal+execute later forwards (against a snapshot
//!   missing at most `k` updates) while batch `i`'s backward and update
//!   are still in flight. Workers process the leader's messages in
//!   send order — forward of `i+k`, then backward of `i` — keeping up
//!   to `k + 1` batches open as [`InFlight`] state (each with its own
//!   arena: the backward rebuild scatters from its *own* forward's
//!   staged rows). All collectives are batch-tagged
//!   ([`Hub::gather_round`]) because fast workers run ahead. The
//!   schedule — releases, gradient folds, store phases — keeps a fixed
//!   deterministic order, so a given staleness value reproduces its
//!   trajectory exactly; `k = 0` is byte-identical to the synchronous
//!   protocol.
//!
//! Parameters are leader-owned: workers marshal weights from the
//! versioned read-only snapshot broadcast at each batch's release (the
//! `Ready` message) and the backward pass from the refreshed snapshot
//! riding the gradient scatter; gradients travel back tagged with the
//! snapshot version that produced them and the fold rejects mismatches.
//! The leader's cache traffic goes through fork-ledger views of the
//! partition caches (shared residency, private hit/miss counters),
//! folded back after the worker threads exit — the runtime is lock-free
//! end to end. Every floating-point reduction folds in (worker, output)
//! order — exactly the order the sequential engine uses — so at
//! staleness 0 losses and parameter trajectories are byte-identical to
//! the sequential runtime under any thread interleaving.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::SimNet;
use crate::config::{partition_edge_filter, Config};
use crate::coordinator::common::Session;
use crate::exec::plan::raf_apply_updates;
use crate::exec::{
    BatchArena, BatchPlan, EpochWorld, ExecContext, ExecGate, GradAccumulator, InFlight,
    ParamsView,
};
use crate::hetgraph::NodeId;
use crate::kvstore::FetchStats;
use crate::metrics::timeline::{AsyncShape, EpochTimeline, LeaderSpan, WallClock, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::MetaPartition;
use crate::runtime::ParamSnapshot;
use crate::sampling::{sample_tree, Frontier, TreeSample};
use crate::util::{add_assign, rng::Rng};

use super::collective::{run_contained, star, Hub, Port, RoundTag, NO_BATCH};
use super::mailbox::{slice_bytes, Wire};

/// Worker → leader messages, tagged with their batch so the leader's
/// round gather can park run-ahead contributions from fast workers.
enum Up {
    Fwd {
        bi: usize,
        p1: Vec<f32>,
        p2: Vec<f32>,
        /// KV-store fetch accounting of the forward input build (unique
        /// rows per batch when dedup gather is on).
        stats: FetchStats,
        span: WorkerSpan,
        stages: StageTimes,
        /// Wall-clock forward-execution interval (epoch-relative) — the
        /// per-context overlap evidence.
        wall_fwd: (f64, f64),
    },
    Bwd {
        bi: usize,
        /// Unreduced gradient outputs — the leader folds them in
        /// (worker, output) order to match the sequential engine's
        /// float-accumulation order exactly. Tagged with the snapshot
        /// version that produced them.
        grads: crate::exec::WorkerGrads,
        bwd_s: f64,
        stages: StageTimes,
        /// Wall-clock backward interval — with a staleness window open,
        /// the backward-vs-later-forward overlap evidence.
        wall_bwd: (f64, f64),
    },
    /// Best-effort death notice naming the batch that was in flight:
    /// without it, a leader gathering from a dead worker would block
    /// forever while live workers keep the channel connected, and
    /// without the batch tag the root cause would drown in a bare
    /// channel hangup.
    Failed { bi: usize, msg: String },
}

/// Gather rounds: two per batch, forwards then backwards.
fn fwd_round(bi: usize) -> u64 {
    2 * bi as u64
}
fn bwd_round(bi: usize) -> u64 {
    2 * bi as u64 + 1
}

fn up_tag(u: &Up) -> RoundTag {
    match u {
        Up::Fwd { bi, .. } => RoundTag::Round(fwd_round(*bi)),
        Up::Bwd { bi, .. } => RoundTag::Round(bwd_round(*bi)),
        Up::Failed { bi, msg } => RoundTag::abort_for(*bi, msg),
    }
}

impl Wire for Up {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] forward partials per worker (Props. 2–3).
            Up::Fwd { p1, p2, .. } => slice_bytes(p1) + slice_bytes(p2),
            // Model-parallel weight/row grads are applied locally by
            // their owning partition in the modeled system; shipping
            // them to the leader-owned store is an in-process artifact,
            // not wire traffic. Replica sync is charged separately,
            // exactly as in the sequential engine.
            Up::Bwd { .. } => 0,
            Up::Failed { .. } => 0,
        }
    }
}

/// Leader → worker messages, batch-tagged. Both carry the current
/// parameter snapshot: `Ready` releases batch `bi` with the newest
/// broadcast weights (under a staleness window these may trail the
/// store by up to `k` updates), `Grads` ships `∂partials` plus the
/// post-head-update weights the backward rebuild marshals from. In the
/// modeled system each partition owns its weights locally (model
/// parallelism), so snapshot distribution is an in-process artifact of
/// the single-machine harness, not wire traffic — only the 2·[B,H]
/// gradients count.
#[derive(Clone)]
enum Down {
    Grads {
        bi: usize,
        g1: Vec<f32>,
        g2: Vec<f32>,
        params: Arc<ParamSnapshot>,
    },
    Ready {
        bi: usize,
        params: Arc<ParamSnapshot>,
    },
}

impl Wire for Down {
    fn wire_bytes(&self) -> u64 {
        match self {
            // The 2·[B,H] backward partial-gradients per worker.
            Down::Grads { g1, g2, .. } => slice_bytes(g1) + slice_bytes(g2),
            Down::Ready { .. } => 0,
        }
    }
}

/// Run one RAF epoch on the cluster runtime.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch(
    plan: &BatchPlan,
    contexts: &mut [ExecContext],
    leader_ctx: &mut ExecContext,
    mp: &MetaPartition,
    replica_count: &HashMap<String, usize>,
    leader_part: usize,
    gate: Option<&ExecGate>,
    sess: &mut Session,
    epoch: usize,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = mp.num_parts;
    let pipeline = cfg.train.pipeline;
    // The staleness window rides the pipeline: with pipelining disabled
    // the runtime is the synchronous A/B baseline.
    let staleness = if pipeline { cfg.train.staleness } else { 0 };
    if staleness > 0 && !cfg.train.dedup_fetch {
        bail!(
            "train.staleness = {staleness} requires train.dedup_fetch (the backward \
             rebuild reuses the forward's staged rows)"
        );
    }
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);

    let mut train = sess.g.train_nodes();
    let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
    shuffle_rng.shuffle(&mut train);
    let b = cfg.train.batch_size;
    let batches: Vec<Vec<NodeId>> = train
        .chunks(b)
        .filter(|c| c.len() == b) // drop the ragged tail (static shapes)
        .map(|c| c.to_vec())
        .collect();
    if batches.is_empty() {
        // Nothing to release: spawning workers would race the initial
        // Ready broadcast against their immediate teardown.
        return Ok(EpochReport::empty(parts));
    }

    // The leader's cache traffic runs through fork-ledger views while
    // the worker threads own the primaries; counts fold back below.
    let mut fork_leader = contexts[leader_part]
        .cache
        .as_ref()
        .map(|c| c.fork_ledger());
    let mut fork_p0 = contexts[0].cache.as_ref().map(|c| c.fork_ledger());

    let world = EpochWorld {
        cfg: &cfg,
        g: &g,
        tree: &tree,
        store: &sess.store,
        gate,
        epoch_t0: Instant::now(),
    };
    let params = &mut sess.params;
    let adam_t = &mut sess.adam_t;

    let (hub, ports) = star::<Up, Down>(parts);
    let (bhub, bports) = star::<(), ()>(parts);

    let report = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts);
        for ((ctx, port), bport) in contexts.iter_mut().zip(ports).zip(bports) {
            let world = &world;
            let batches = &batches;
            handles.push(s.spawn(move || {
                worker_loop(
                    ctx, plan, world, mp, epoch, batches, &port, &bport, pipeline, staleness,
                )
            }));
        }
        let led = leader_loop(
            hub,
            bhub,
            plan,
            &world,
            leader_ctx,
            params,
            adam_t,
            fork_leader.as_mut(),
            fork_p0.as_mut(),
            replica_count,
            &batches,
            parts,
            leader_part,
            pipeline,
            staleness,
        );
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e);
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        // The leader's error already embeds worker root causes (via
        // `Up::Failed`), so it wins; worker errors cover the remainder.
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    });

    if let Some(f) = fork_leader {
        if let Some(c) = contexts[leader_part].cache.as_mut() {
            c.absorb_ledger(&f);
        }
    }
    if let Some(f) = fork_p0 {
        if let Some(c) = contexts[0].cache.as_mut() {
            c.absorb_ledger(&f);
        }
    }
    report
}

/// Runs the worker body; on error (or panic), ships a best-effort death
/// notice naming the batch that was in flight so the leader's gather
/// fails fast — with the root cause — instead of blocking on a dead
/// peer or reporting a bare hangup.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    mp: &MetaPartition,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down>,
    bport: &Port<(), ()>,
    pipeline: bool,
    staleness: usize,
) -> Result<()> {
    let p = ctx.worker;
    // The batch cursor outlives a panic's unwinding, so the death
    // notice still names the batch in flight.
    let cur = AtomicUsize::new(NO_BATCH);
    run_contained(
        p,
        &cur,
        || {
            if staleness == 0 {
                worker_run_sync(ctx, plan, world, mp, epoch, batches, port, bport, pipeline, &cur)
            } else {
                worker_run_windowed(
                    ctx, plan, world, mp, epoch, batches, port, bport, staleness, &cur,
                )
            }
        },
        |bi, msg| {
            let _ = port.send(Up::Failed { bi, msg });
        },
    )
}

/// The synchronous (`staleness = 0`) worker: strict Ready → forward →
/// Grads → backward alternation, with the double-buffered prefetch of
/// batch `i+1`'s sample (and dedup frontier) hidden inside the leader
/// phase when `pipeline` is on. Byte-for-byte the pre-window protocol.
#[allow(clippy::too_many_arguments)]
fn worker_run_sync(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    mp: &MetaPartition,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down>,
    bport: &Port<(), ()>,
    pipeline: bool,
    cur: &AtomicUsize,
) -> Result<()> {
    bport.barrier()?;
    let p = ctx.worker;
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[p];
    // One arena serves every batch: forward stages it, the same batch's
    // backward scatters from it before the next forward begins.
    let mut arena = BatchArena::new();
    // Per-thread dedup-frontier scratch; `spare` lets two frontier
    // allocations ping-pong with the double-buffered prefetch (the
    // in-flight batch holds one while the prefetch fills the other).
    let mut spare: Option<Frontier> = None;
    let mut prefetched: Option<(TreeSample, Option<Frontier>, f64)> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        cur.store(bi, Ordering::Relaxed);
        // Batch i's forward needs batch i-1's updated weights: the
        // Ready release carries the current parameter snapshot.
        let snapshot = match port.recv()? {
            Down::Ready { bi: rbi, params } => {
                if rbi != bi {
                    bail!("worker {p}: Ready for batch {rbi} arrived while expecting batch {bi}");
                }
                params
            }
            Down::Grads { bi: gbi, .. } => {
                bail!("worker {p}: batch {gbi} gradients arrived before batch {bi}'s Ready")
            }
        };
        let (sample, frontier, sample_s) = match prefetched.take() {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let filter = partition_edge_filter(world.tree, mp, p);
                let s = sample_tree(
                    world.g,
                    world.tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    cfg.train.batch_seed(epoch, bi),
                    filter,
                );
                let fr = cfg
                    .train
                    .dedup_fetch
                    .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
                (s, fr, t0.elapsed().as_secs_f64() * scale)
            }
        };

        // ---- forward stage on this worker's own context ----
        let fwd = wp.raf_forward(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            &sample,
            frontier.as_ref(),
            chunk,
            sample_s,
            &mut arena,
        )?;
        port.send(Up::Fwd {
            bi,
            p1: fwd.p1,
            p2: fwd.p2,
            stats: fwd.stats,
            span: fwd.span,
            stages: fwd.stages,
            wall_fwd: fwd.wall_fwd,
        })?;

        // ---- double-buffer: prefetch batch i+1 during the leader phase
        // (sampling *and* the dedup frontier, so the dedup work overlaps
        // the leader's gather/step/scatter) ----
        if pipeline && bi + 1 < batches.len() {
            let t = Instant::now();
            let filter = partition_edge_filter(world.tree, mp, p);
            let s = sample_tree(
                world.g,
                world.tree,
                &cfg.model.fanouts,
                &batches[bi + 1],
                0,
                cfg.train.batch_seed(epoch, bi + 1),
                filter,
            );
            let fr = cfg
                .train
                .dedup_fetch
                .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
            prefetched = Some((s, fr, t.elapsed().as_secs_f64() * scale));
        }

        // ---- backward stage: ∂partials + the post-head-update snapshot ----
        let (g1, g2, snapshot) = match port.recv()? {
            Down::Grads { bi: gbi, g1, g2, params } => {
                if gbi != bi {
                    bail!("worker {p}: gradients for batch {gbi} arrived while expecting {bi}");
                }
                (g1, g2, params)
            }
            Down::Ready { bi: rbi, .. } => {
                bail!("worker {p}: batch {rbi} Ready arrived before batch {bi}'s gradients")
            }
        };
        let bwd = wp.raf_backward(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            &sample,
            frontier.as_ref(),
            chunk,
            g1,
            g2,
            &mut arena,
        )?;
        port.send(Up::Bwd {
            bi,
            grads: bwd.grads,
            bwd_s: bwd.bwd_s,
            stages: bwd.stages,
            wall_bwd: bwd.wall_bwd,
        })?;
        // Batch done; recycle the frontier allocation for a later
        // prefetch (the i+1 prefetch above already took the other one).
        if let Some(f) = frontier {
            spare = Some(f);
        }
    }
    Ok(())
}

/// The windowed (`staleness = k >= 1`) worker: a resumable per-batch
/// state machine driven by the leader's message order. A `Ready`
/// release opens a batch — sample, marshal and execute its forward
/// against the shipped snapshot, then park it as [`InFlight`] — and a
/// `Grads` scatter closes the oldest open batch with its backward. The
/// leader interleaves releases ahead of scatters (forward of `i+k`
/// before backward of `i`), which is exactly the 1F1B schedule; up to
/// `k + 1` batches are open at once, each owning its arena so backward
/// rebuilds scatter from their own forward's staged rows.
#[allow(clippy::too_many_arguments)]
fn worker_run_windowed(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    mp: &MetaPartition,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down>,
    bport: &Port<(), ()>,
    staleness: usize,
    cur: &AtomicUsize,
) -> Result<()> {
    bport.barrier()?;
    let p = ctx.worker;
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[p];
    let mut open: VecDeque<InFlight> = VecDeque::with_capacity(staleness + 1);
    let mut arena_pool: Vec<BatchArena> = Vec::new();
    let mut frontier_pool: Vec<Frontier> = Vec::new();
    let mut next_ready = 0usize; // releases arrive in batch order
    let mut completed = 0usize;

    while completed < batches.len() {
        match port.recv()? {
            Down::Ready { bi, params } => {
                if bi != next_ready {
                    bail!("worker {p}: release for batch {bi} arrived, expected {next_ready}");
                }
                next_ready += 1;
                cur.store(bi, Ordering::Relaxed);
                let chunk = &batches[bi];
                let t0 = Instant::now();
                let filter = partition_edge_filter(world.tree, mp, p);
                let sample = sample_tree(
                    world.g,
                    world.tree,
                    &cfg.model.fanouts,
                    chunk,
                    0,
                    cfg.train.batch_seed(epoch, bi),
                    filter,
                );
                let frontier = cfg.train.dedup_fetch.then(|| {
                    let mut spare = frontier_pool.pop();
                    Frontier::take_rebuilt(&mut spare, world.tree, &sample, ntypes, wp.needs_root)
                });
                let sample_s = t0.elapsed().as_secs_f64() * scale;
                let mut arena = arena_pool.pop().unwrap_or_default();
                let fwd = wp.raf_forward(
                    ctx,
                    world,
                    ParamsView::Snapshot(&params),
                    &sample,
                    frontier.as_ref(),
                    chunk,
                    sample_s,
                    &mut arena,
                )?;
                port.send(Up::Fwd {
                    bi,
                    p1: fwd.p1,
                    p2: fwd.p2,
                    stats: fwd.stats,
                    span: fwd.span,
                    stages: fwd.stages,
                    wall_fwd: fwd.wall_fwd,
                })?;
                open.push_back(InFlight {
                    bi,
                    sample,
                    frontier,
                    arena,
                });
            }
            Down::Grads { bi, g1, g2, params } => {
                let mut inflight = open.pop_front().ok_or_else(|| {
                    anyhow!("worker {p}: gradients for batch {bi} with no batch in flight")
                })?;
                if inflight.bi != bi {
                    bail!(
                        "worker {p}: gradients for batch {bi} but batch {} is the oldest in flight",
                        inflight.bi
                    );
                }
                cur.store(bi, Ordering::Relaxed);
                let bwd = wp.raf_backward(
                    ctx,
                    world,
                    ParamsView::Snapshot(&params),
                    &inflight.sample,
                    inflight.frontier.as_ref(),
                    &batches[bi],
                    g1,
                    g2,
                    &mut inflight.arena,
                )?;
                port.send(Up::Bwd {
                    bi,
                    grads: bwd.grads,
                    bwd_s: bwd.bwd_s,
                    stages: bwd.stages,
                    wall_bwd: bwd.wall_bwd,
                })?;
                arena_pool.push(inflight.arena);
                if let Some(f) = inflight.frontier {
                    frontier_pool.push(f);
                }
                completed += 1;
            }
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    mut hub: Hub<Up, Down>,
    bhub: Hub<(), ()>,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    leader_ctx: &mut ExecContext,
    params: &mut crate::runtime::ParamStore,
    adam_t: &mut i32,
    mut fork_leader: Option<&mut crate::cache::FeatureCache>,
    mut fork_p0: Option<&mut crate::cache::FeatureCache>,
    replica_count: &HashMap<String, usize>,
    batches: &[Vec<NodeId>],
    parts: usize,
    leader_part: usize,
    pipeline: bool,
    staleness: usize,
) -> Result<EpochReport> {
    bhub.barrier()?;
    let cfg = world.cfg;
    let b = cfg.train.batch_size;
    let h = cfg.model.hidden;
    let n = batches.len();
    let mut net = SimNet::new(parts, cfg.cost.clone());
    let mut timeline = EpochTimeline::new(parts);
    let mut stages = StageTimes::default();
    let mut worker_stages = vec![StageTimes::default(); parts];
    let mut wall = WallClock::new(parts);
    let mut leader_arena = BatchArena::new();
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut batch_losses = Vec::with_capacity(n);
    let mut batches_done = 0usize;
    let mut fetch = FetchStats::default();

    // Prime the release window: the synchronous protocol opens batch 0
    // only; a k-window opens k batches up front (batch j's snapshot then
    // trails by j <= k updates — within the bound).
    let mut released = 0usize;
    for _ in 0..staleness.max(1).min(n) {
        hub.broadcast(Down::Ready {
            bi: released,
            params: Arc::new(params.snapshot()),
        })?;
        released += 1;
    }

    for (bi, chunk) in batches.iter().enumerate() {
        // ---- gather worker partials (worker-id order) ----
        let ups = hub
            .gather_round(fwd_round(bi), up_tag)
            .with_context(|| format!("batch {bi}: collecting forward partials"))?;
        let wire: Vec<u64> = ups.iter().map(|u| u.wire_bytes()).collect();
        let mut partial_sums = [vec![0f32; b * h], vec![0f32; b * h]];
        let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
        for (w, up) in ups.into_iter().enumerate() {
            match up {
                Up::Fwd {
                    bi: ubi,
                    p1,
                    p2,
                    stats,
                    span,
                    stages: wstages,
                    wall_fwd,
                } => {
                    if ubi != bi {
                        bail!("protocol error: batch {ubi} partials in batch {bi}'s round");
                    }
                    add_assign(&mut partial_sums[0], &p1);
                    add_assign(&mut partial_sums[1], &p2);
                    fetch.merge(stats);
                    worker_spans.push(span);
                    stages.merge(&wstages);
                    worker_stages[w].merge(&wstages);
                    wall.record_forward(w, wall_fwd);
                }
                Up::Bwd { bi: ubi, .. } => {
                    bail!("protocol error: batch {ubi} gradients in batch {bi}'s forward round")
                }
                Up::Failed { .. } => unreachable!("gather_round aborts on Failed"),
            }
        }
        // ---- async release: batch bi+k goes out the moment batch bi's
        // partials landed, so its forward overlaps this batch's leader
        // phase, backward and update (staleness <= k by construction:
        // the snapshot carries every update through batch bi-1).
        //
        // No explicit store barrier is needed here (unlike the vanilla
        // engine's `Marshaled` notice): this batch's update — the next
        // store write — runs only after the backward gather below, a
        // worker ships its backward only after processing every earlier
        // Down message, and this release is sent *before* the gradient
        // scatter. So by the time `Bwd(bi)` arrives from worker w, w has
        // finished marshalling (store reads included) every batch
        // released so far — the backward gather IS the barrier, and
        // every marshal deterministically sees the updates through its
        // own release point. ----
        if staleness >= 1 && released < n {
            hub.broadcast(Down::Ready {
                bi: released,
                params: Arc::new(params.snapshot()),
            })?;
            released += 1;
        }
        // The leader partition's partials are machine-local.
        let gather_bytes: Vec<u64> = wire
            .iter()
            .enumerate()
            .map(|(w, &bytes)| if w == leader_part { 0 } else { bytes })
            .collect();
        let t_gather = net.gather(leader_part, &gather_bytes)?;
        stages.add(Stage::Forward, t_gather);

        // ---- leader stage: cross-relation agg + head + loss + bwd ----
        let lo = plan.raf_leader_step(
            leader_ctx,
            world,
            params,
            adam_t,
            fork_leader.as_deref_mut(),
            &partial_sums,
            chunk,
            &mut leader_arena,
        )?;
        fetch.merge(lo.stats);
        stages.add(Stage::Forward, lo.leader_s * 0.5);
        stages.add(Stage::Backward, lo.leader_s * 0.5);
        stages.add(Stage::Update, lo.head_update_s);
        loss_sum += lo.loss;
        acc_sum += lo.acc;
        batch_losses.push(lo.loss);

        // ---- scatter gradients back (2 tensors per worker, symmetric),
        // with the post-head-update snapshot the backward marshals from ----
        let t_scatter = net.gather(leader_part, &gather_bytes)?;
        stages.add(Stage::Backward, t_scatter);
        let grads_snapshot = Arc::new(params.snapshot());
        let grads_version = grads_snapshot.version;
        hub.broadcast(Down::Grads {
            bi,
            g1: lo.g1,
            g2: lo.g2,
            params: grads_snapshot,
        })?;

        // ---- gather worker gradients (worker-id order), holding every
        // fold to the snapshot version this batch's scatter shipped ----
        let ups = hub
            .gather_round(bwd_round(bi), up_tag)
            .with_context(|| format!("batch {bi}: collecting worker gradients"))?;
        let mut gacc = GradAccumulator::for_version(grads_version);
        for (w, up) in ups.into_iter().enumerate() {
            match up {
                Up::Bwd {
                    bi: ubi,
                    grads,
                    bwd_s,
                    stages: wstages,
                    wall_bwd,
                } => {
                    if ubi != bi {
                        bail!("protocol error: batch {ubi} gradients in batch {bi}'s round");
                    }
                    gacc.absorb(grads)
                        .with_context(|| format!("batch {bi}, worker {w}"))?;
                    if let Some(span) = worker_spans.get_mut(w) {
                        span.bwd_s = bwd_s;
                    }
                    stages.merge(&wstages);
                    worker_stages[w].merge(&wstages);
                    wall.record_backward(w, wall_bwd);
                }
                Up::Fwd { bi: ubi, .. } => {
                    bail!("protocol error: batch {ubi} partials in batch {bi}'s backward round")
                }
                Up::Failed { .. } => unreachable!("gather_round aborts on Failed"),
            }
        }

        // ---- update stage (weights + learnable features) ----
        let mut gx_root = lo.gx_root;
        let upd = raf_apply_updates(
            world,
            params,
            *adam_t,
            replica_count,
            &gacc,
            &mut gx_root,
            chunk,
            fork_leader.as_deref_mut(),
            fork_p0.as_deref_mut(),
        )?;
        stages.add(Stage::Update, upd.update_s + upd.lf_s);
        let sync_t = if upd.sync_bytes > 0 {
            let t = net.send(1 % parts, leader_part, upd.sync_bytes)?;
            stages.add(Stage::GradSync, t);
            t
        } else {
            0.0
        };

        timeline.push_batch(
            worker_spans,
            LeaderSpan {
                gather_s: t_gather,
                leader_s: lo.leader_s,
                scatter_s: t_scatter,
                update_s: lo.head_update_s + upd.update_s + upd.lf_s,
                sync_s: sync_t,
            },
        );
        batches_done += 1;
        // ---- synchronous release: batch bi+1 waits for this update ----
        if staleness == 0 && released < n {
            hub.broadcast(Down::Ready {
                bi: released,
                params: Arc::new(params.snapshot()),
            })?;
            released += 1;
        }
    }

    let epoch_time_s = timeline.sequential_time();
    let critical_path_s = if staleness >= 1 {
        timeline.async_pipelined_time(staleness, AsyncShape::Raf)
    } else if pipeline {
        timeline.pipelined_time()
    } else {
        epoch_time_s
    };
    Ok(EpochReport {
        epoch_time_s,
        critical_path_s,
        worker_busy_s: timeline.worker_busy_s(),
        worker_stages,
        wall,
        stages,
        comm: net.total(),
        fetch,
        loss_mean: if batches_done > 0 {
            loss_sum / batches_done as f64
        } else {
            f64::NAN
        },
        accuracy: if batches_done > 0 {
            acc_sum / (batches_done * b) as f64
        } else {
            f64::NAN
        },
        batches: batches_done,
        batch_losses,
    })
}
