//! Typed mailbox transport between cluster ranks.
//!
//! A [`Mailbox`] is one rank's endpoint in a full mesh of in-process
//! channels: it can `send` a typed message to any peer rank and `recv`
//! the next [`Envelope`] addressed to it. Envelopes carry the sender's
//! rank so collectives can reassemble results in deterministic worker
//! order regardless of thread interleaving.
//!
//! ## The transport contract
//!
//! Since PR 5 the mailbox is one implementation of the [`Transport`]
//! trait, and everything above it — the collectives in
//! [`super::collective`] and both cluster engines — is generic over
//! the endpoint. The second implementation is the socket star of
//! [`crate::net::tcp`], which runs the same protocols with one OS
//! process per rank. An implementation owes exactly four guarantees:
//!
//! 1. **Addressing** — `send(to, m)` delivers `m` to logical rank `to`
//!    only, and `recv()` yields envelopes stamped with the true sender
//!    rank (collectives index their slots by it).
//! 2. **Per-lane FIFO** — messages from one rank to another arrive in
//!    send order (see below); messages from different senders may
//!    interleave arbitrarily.
//! 3. **Hangup-as-error** — a dead peer (dropped endpoint, closed
//!    socket, process exit) surfaces as `anyhow::Error` from `send`/
//!    `recv`, **never** a panic or a silent hang where detectable; the
//!    engines' death notices cover the silent cases.
//! 4. **Payload fidelity** — what arrives is bit-identical to what was
//!    sent (the TCP codec moves floats as raw IEEE-754 bits for this
//!    reason). Determinism of the whole runtime rests on it.
//!
//! Transport errors surface as `anyhow::Result` — never panics — so
//! one failed worker unwinds the whole epoch as an error instead of a
//! poisoned mutex. The codec's fallible decode flows through the same
//! `Result` paths.
//!
//! Ordering contract: delivery is FIFO **per (sender, receiver) lane**
//! — messages from one rank to another arrive in send order, while
//! messages from different senders interleave arbitrarily. The
//! bounded-staleness pipeline leans on this twice: workers process the
//! leader's releases and gradient scatters exactly in the order the
//! leader sent them (the deterministic 1F1B interleaving), and a
//! worker's batch-tagged contributions reach the leader's round
//! reorder buffer in batch order. `tests/test_async_pipeline.rs`
//! property-checks the lane contract under random interleavings.
//!
//! Accounting contract: the mailbox moves data; it does not price it.
//! The engines charge every transfer of the *modeled* system through
//! [`crate::comm::SimNet`] at the collective boundaries with exactly
//! the same calls the sequential runtime makes, so ledger bytes stay
//! exact and runtime-independent. (Control metadata like `Ready`
//! messages and the shipping of model-parallel gradients that the
//! modeled system applies locally are free, as in the sequential
//! engines.)

use std::sync::mpsc::{channel, Receiver, Sender};

use anyhow::{anyhow, Result};

/// Wire-size of a message: the bytes the modeled system would put on
/// the network for it (tensor payloads only; metadata is free).
pub trait Wire {
    fn wire_bytes(&self) -> u64;
}

/// Barrier tokens and other pure-control messages are modeled-free.
impl Wire for () {
    fn wire_bytes(&self) -> u64 {
        0
    }
}

/// Bytes of a dense slice payload.
pub fn slice_bytes<T>(v: &[T]) -> u64 {
    std::mem::size_of_val(v) as u64
}

/// One rank's typed endpoint of a cluster transport — the abstraction
/// the collectives and both cluster engines are written against. See
/// the module docs for the four guarantees an implementation owes
/// (addressing, per-lane FIFO, hangup-as-error, payload fidelity).
///
/// Implemented by the in-process [`Mailbox`] and by the socket-backed
/// [`TcpChannel`](crate::net::TcpChannel); the blanket `&E` impl lets
/// long-lived endpoints (TCP lanes persist across epochs) be borrowed
/// into per-epoch [`Hub`](super::collective::Hub)/
/// [`Port`](super::collective::Port) wrappers.
pub trait Transport<T> {
    /// This endpoint's logical rank.
    fn rank(&self) -> usize;
    /// Send `payload` to logical rank `to`.
    fn send(&self, to: usize, payload: T) -> Result<()>;
    /// Receive the next message addressed to this rank, blocking.
    fn recv(&self) -> Result<Envelope<T>>;
    /// Send one copy of `payload` to every rank `0..workers`. The
    /// default is the per-peer fallback (a clone per worker — what the
    /// in-process channels want, since they move values instead of
    /// encoding them); transports with a real wire override it to
    /// serialize the frame **once** and write the same bytes to every
    /// connection, turning the leader's per-batch broadcast from K
    /// encodes into one (see
    /// [`TcpChannel`](crate::net::TcpChannel)).
    fn broadcast_encoded(&self, workers: usize, payload: &T) -> Result<()>
    where
        T: Clone,
    {
        for w in 0..workers {
            self.send(w, payload.clone())?;
        }
        Ok(())
    }
    /// Deterministic fault injection (`--fail`): make this endpoint
    /// misbehave in the way `kind` names. Default no-op — the
    /// in-process channels have no sockets to drop or heartbeats to
    /// pause, and an `Exit` fault needs no transport help anywhere.
    /// Only the TCP star overrides this; see
    /// [`TcpChannel`](crate::net::TcpChannel).
    fn sabotage(&self, _kind: crate::config::FaultKind) {}
}

impl<T, E: Transport<T>> Transport<T> for &E {
    fn rank(&self) -> usize {
        (**self).rank()
    }
    fn send(&self, to: usize, payload: T) -> Result<()> {
        (**self).send(to, payload)
    }
    fn recv(&self) -> Result<Envelope<T>> {
        (**self).recv()
    }
    // Must forward (not inherit the default): the engines' hubs hold
    // `&TcpChannel`, and the default impl here would silently undo the
    // encode-once override underneath them.
    fn broadcast_encoded(&self, workers: usize, payload: &T) -> Result<()>
    where
        T: Clone,
    {
        (**self).broadcast_encoded(workers, payload)
    }
    fn sabotage(&self, kind: crate::config::FaultKind) {
        (**self).sabotage(kind)
    }
}

impl<T: Send> Transport<T> for Mailbox<T> {
    fn rank(&self) -> usize {
        self.rank
    }
    fn send(&self, to: usize, payload: T) -> Result<()> {
        Mailbox::send(self, to, payload)
    }
    fn recv(&self) -> Result<Envelope<T>> {
        Mailbox::recv(self)
    }
}

/// A message in flight, tagged with its sender rank.
#[derive(Debug)]
pub struct Envelope<T> {
    pub from: usize,
    pub payload: T,
}

/// One rank's endpoint of the mesh.
///
/// The slot for the rank's own sender is intentionally empty: holding a
/// sender to oneself would keep one's receiver alive forever, so a rank
/// waiting on peers that all exited would block instead of erroring.
pub struct Mailbox<T> {
    pub rank: usize,
    rx: Receiver<Envelope<T>>,
    peers: Vec<Option<Sender<Envelope<T>>>>,
}

impl<T: Send> Mailbox<T> {
    /// Build a full mesh of `n` ranks; returns one mailbox per rank.
    pub fn mesh(n: usize) -> Vec<Mailbox<T>> {
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| channel::<Envelope<T>>()).unzip();
        rxs.into_iter()
            .enumerate()
            .map(|(rank, rx)| Mailbox {
                rank,
                rx,
                peers: txs
                    .iter()
                    .enumerate()
                    .map(|(to, tx)| (to != rank).then(|| tx.clone()))
                    .collect(),
            })
            .collect()
    }

    /// Build a hub-and-spoke wiring: rank `workers` is the hub, wired
    /// to and from every spoke; spokes are wired only to the hub. A
    /// spoke's receiver is reachable solely from the hub (and vice
    /// versa), so the death of one side disconnects the other instead
    /// of leaving it blocked on a queue kept alive by third parties —
    /// the property the collectives rely on for error propagation.
    pub fn star(workers: usize) -> (Mailbox<T>, Vec<Mailbox<T>>) {
        let n = workers + 1;
        let (txs, rxs): (Vec<_>, Vec<_>) = (0..n).map(|_| channel::<Envelope<T>>()).unzip();
        let mut rxs = rxs.into_iter();
        let spokes: Vec<Mailbox<T>> = (0..workers)
            .map(|rank| Mailbox {
                rank,
                rx: rxs.next().expect("one receiver per rank"),
                peers: (0..n)
                    .map(|to| (to == workers).then(|| txs[to].clone()))
                    .collect(),
            })
            .collect();
        let hub = Mailbox {
            rank: workers,
            rx: rxs.next().expect("one receiver per rank"),
            peers: (0..n)
                .map(|to| (to < workers).then(|| txs[to].clone()))
                .collect(),
        };
        (hub, spokes)
    }

    /// Number of ranks in the mesh.
    pub fn ranks(&self) -> usize {
        self.peers.len()
    }

    /// Send `payload` to rank `to` (sending to oneself is an error).
    pub fn send(&self, to: usize, payload: T) -> Result<()> {
        let tx = self
            .peers
            .get(to)
            .ok_or_else(|| anyhow!("rank {to} outside {}-rank mesh", self.peers.len()))?
            .as_ref()
            .ok_or_else(|| anyhow!("rank {to} cannot mail itself"))?;
        tx.send(Envelope {
            from: self.rank,
            payload,
        })
        .map_err(|_| anyhow!("rank {to} hung up (worker thread exited early)"))
    }

    /// Receive the next message addressed to this rank, blocking.
    pub fn recv(&self) -> Result<Envelope<T>> {
        self.rx.recv().map_err(|_| {
            anyhow!(
                "all peers of rank {} hung up (cluster tore down mid-epoch)",
                self.rank
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_routes_by_rank() {
        let mut boxes = Mailbox::<u32>::mesh(3);
        let c = boxes.pop().unwrap();
        let b = boxes.pop().unwrap();
        let a = boxes.pop().unwrap();
        a.send(2, 7).unwrap();
        b.send(2, 8).unwrap();
        let mut got = vec![c.recv().unwrap(), c.recv().unwrap()];
        got.sort_by_key(|e| e.from);
        assert_eq!((got[0].from, got[0].payload), (0, 7));
        assert_eq!((got[1].from, got[1].payload), (1, 8));
        assert!(a.send(9, 0).is_err());
    }

    #[test]
    fn hangup_is_an_error_not_a_panic() {
        let mut boxes = Mailbox::<u32>::mesh(2);
        let b = boxes.pop().unwrap();
        let a = boxes.pop().unwrap();
        drop(b);
        // `a`'s own sender into the mesh keeps its queue alive, but the
        // dropped peer can no longer be sent to once its receiver died.
        assert!(a.send(1, 1).is_err());
    }

    #[test]
    fn per_sender_lanes_preserve_send_order() {
        // Two senders interleave at one receiver: arrival order between
        // them is arbitrary, but each sender's own sequence must arrive
        // intact — the lane contract the batch-tagged collectives need.
        let mut boxes = Mailbox::<(usize, u32)>::mesh(3);
        let c = boxes.pop().unwrap();
        let b = boxes.pop().unwrap();
        let a = boxes.pop().unwrap();
        a.send(2, (0, 0)).unwrap();
        b.send(2, (1, 0)).unwrap();
        a.send(2, (0, 1)).unwrap();
        b.send(2, (1, 1)).unwrap();
        a.send(2, (0, 2)).unwrap();
        let mut last_seq = [None::<u32>, None::<u32>];
        for _ in 0..5 {
            let e = c.recv().unwrap();
            let (batch_lane, seq) = e.payload;
            assert_eq!(batch_lane, e.from, "lane id mirrors the sender");
            if let Some(prev) = last_seq[e.from] {
                assert!(seq > prev, "lane {} reordered: {seq} after {prev}", e.from);
            }
            last_seq[e.from] = Some(seq);
        }
        assert_eq!(last_seq, [Some(2), Some(1)]);
    }

    #[test]
    fn threads_exchange_through_the_mesh() {
        let mut boxes = Mailbox::<Vec<f32>>::mesh(2);
        let worker = boxes.pop().unwrap();
        let leader = boxes.pop().unwrap();
        let t = std::thread::spawn(move || -> Result<()> {
            let e = worker.recv()?;
            worker.send(0, e.payload.iter().map(|x| x * 2.0).collect())?;
            Ok(())
        });
        leader.send(1, vec![1.0, 2.0]).unwrap();
        let back = leader.recv().unwrap();
        assert_eq!(back.payload, vec![2.0, 4.0]);
        t.join().unwrap().unwrap();
        assert_eq!(slice_bytes(&[0f32; 4]), 16);
    }
}
