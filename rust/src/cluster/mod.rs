//! The cluster runtime: thread-per-partition workers with pipelined
//! minibatch execution.
//!
//! The sequential coordinator engines play every "worker" in one thread,
//! so epoch time is the *sum* of per-worker stage times. This subsystem
//! gives each partition a real OS-thread worker:
//!
//! * [`mailbox`] — typed mailbox transport between ranks (mesh or
//!   hub-and-spoke), with hangup-as-error semantics so one failed
//!   worker unwinds the epoch as `anyhow::Error`.
//! * [`collective`] — leader/worker barrier plus gather/scatter/
//!   broadcast built over the mailboxes. Gathers reassemble in worker-id
//!   order, never arrival order, which keeps every floating-point
//!   reduction byte-identical under arbitrary thread interleavings —
//!   the cluster runtime reproduces the sequential runtime's sampled
//!   trees, losses and parameter trajectories exactly (Prop. 1 still
//!   holds; `tests/test_cluster_determinism.rs` checks it).
//! * [`raf`] / [`vanilla`] — the two coordinator engines ported onto
//!   the runtime. Per batch, workers sample and fetch concurrently,
//!   ship partials/gradients through the collectives, and the leader
//!   reduces, steps and updates. The double-buffered pipeline prefetches
//!   batch `i+1`'s sampling (and models read-only cache fetch ahead)
//!   while batch `i` sits in the leader phase, which is where the
//!   critical-path win over the sequential runtime comes from (see
//!   [`crate::metrics::timeline`]).
//!
//! Every transfer of the *modeled* system is still charged through
//! [`crate::comm::CostModel`] ledgers with the same calls the
//! sequential engines make, so reported communication bytes are exact
//! and runtime-independent. Select the runtime with the
//! `train.runtime` config flag (`"sequential"` | `"cluster"`); the
//! `train.pipeline` flag isolates the double-buffering for A/B runs.

pub mod collective;
pub mod mailbox;
pub mod raf;
pub mod vanilla;

use std::sync::{Mutex, MutexGuard};

use anyhow::{anyhow, Result};

/// Lock a mutex, converting poisoning (a panic on another thread) into
/// an `anyhow` error instead of propagating the panic.
pub fn lock<'a, T>(m: &'a Mutex<T>, what: &str) -> Result<MutexGuard<'a, T>> {
    m.lock()
        .map_err(|_| anyhow!("{what} mutex poisoned by a failed worker thread"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_reports_poison_as_error() {
        let m = std::sync::Arc::new(Mutex::new(1u32));
        {
            let g = lock(&m, "counter").unwrap();
            assert_eq!(*g, 1);
        }
        let m2 = std::sync::Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        let e = lock(&m, "counter").unwrap_err();
        assert!(e.to_string().contains("counter"));
    }
}
