//! The cluster runtime: thread-per-partition workers with pipelined
//! minibatch execution over per-worker execution contexts.
//!
//! The sequential coordinator engines play every "worker" in one thread,
//! so epoch time is the *sum* of per-worker stage times. This subsystem
//! gives each partition a real OS-thread worker:
//!
//! * [`mailbox`] — typed mailbox transport between ranks (mesh or
//!   hub-and-spoke), with hangup-as-error semantics so one failed
//!   worker unwinds the epoch as `anyhow::Error`.
//! * [`collective`] — leader/worker barrier plus gather/scatter/
//!   broadcast built over the mailboxes. Gathers reassemble in worker-id
//!   order, never arrival order, which keeps every floating-point
//!   reduction byte-identical under arbitrary thread interleavings —
//!   the cluster runtime reproduces the sequential runtime's sampled
//!   trees, losses and parameter trajectories exactly (Prop. 1 still
//!   holds; `tests/test_cluster_determinism.rs` checks it). Gathers are
//!   **round-tagged** (`Hub::gather_round`): under a staleness window
//!   fast workers ship contributions for later batches while an earlier
//!   round is still collecting, and the hub parks them instead of
//!   mistaking them for duplicates; error paths keep the batch that was
//!   in flight.
//! * [`raf`] / [`vanilla`] — thin thread-per-partition schedulers over
//!   the shared stage pipeline in [`crate::exec::BatchPlan`]. Each
//!   worker thread exclusively owns its
//!   [`ExecContext`](crate::exec::ExecContext) — its own PJRT client,
//!   compiled executables and feature cache — so
//!   forward/backward of different partitions execute **genuinely
//!   concurrently**: there is no shared session and no lock around
//!   artifact execution (PR 1's serialized shared session survives only
//!   behind the `train.shared_session` escape hatch in the exec layer).
//!   Parameters reach workers as versioned read-only snapshots
//!   broadcast by the leader each batch; the feature KV store is read
//!   concurrently during marshal and written only by the leader's
//!   update phase. The double-buffered pipeline still prefetches batch
//!   `i+1`'s sampling while batch `i` sits in the leader phase (see
//!   [`crate::metrics::timeline`]), and `train.staleness = k >= 1`
//!   opens the async 1F1B window on top: the leader releases batch
//!   `i+k` right after gathering batch `i`'s results, so later
//!   forwards (against snapshots at most `k` updates behind) overlap
//!   in-flight backwards and updates. The schedule stays deterministic
//!   — releases, store-write barriers and version-pinned gradient
//!   folds keep a fixed order — and `k = 0` remains byte-identical to
//!   the synchronous protocol (`tests/test_async_pipeline.rs`).
//!
//! Every transfer of the *modeled* system is still charged through
//! [`crate::comm::CostModel`] ledgers with the same calls the
//! sequential engines make, so reported communication bytes are exact
//! and runtime-independent. Select the runtime with the
//! `train.runtime` config flag (`"sequential"` | `"cluster"`); the
//! `train.pipeline` flag isolates the double-buffering for A/B runs and
//! `train.shared_session` reproduces the old serialized execution.

pub mod collective;
pub mod mailbox;
pub mod raf;
pub mod vanilla;
