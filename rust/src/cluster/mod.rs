//! The cluster runtime: thread-per-partition workers with pipelined
//! minibatch execution over per-worker execution contexts.
//!
//! The sequential coordinator engines play every "worker" in one thread,
//! so epoch time is the *sum* of per-worker stage times. This subsystem
//! gives each partition a real OS-thread worker:
//!
//! * [`mailbox`] — typed mailbox transport between ranks (mesh or
//!   hub-and-spoke), with hangup-as-error semantics so one failed
//!   worker unwinds the epoch as `anyhow::Error`.
//! * [`collective`] — leader/worker barrier plus gather/scatter/
//!   broadcast built over the mailboxes. Gathers reassemble in worker-id
//!   order, never arrival order, which keeps every floating-point
//!   reduction byte-identical under arbitrary thread interleavings —
//!   the cluster runtime reproduces the sequential runtime's sampled
//!   trees, losses and parameter trajectories exactly (Prop. 1 still
//!   holds; `tests/test_cluster_determinism.rs` checks it). Gathers are
//!   **round-tagged** (`Hub::gather_round`): under a staleness window
//!   fast workers ship contributions for later batches while an earlier
//!   round is still collecting, and the hub parks them instead of
//!   mistaking them for duplicates; error paths keep the batch that was
//!   in flight.
//! * [`raf`] / [`vanilla`] — thin thread-per-partition schedulers over
//!   the shared stage pipeline in [`crate::exec::BatchPlan`]. Each
//!   worker thread exclusively owns its
//!   [`ExecContext`](crate::exec::ExecContext) — its own PJRT client,
//!   compiled executables and feature cache — so
//!   forward/backward of different partitions execute **genuinely
//!   concurrently**: there is no shared session and no lock around
//!   artifact execution (PR 1's serialized shared session survives only
//!   behind the `train.shared_session` escape hatch in the exec layer).
//!   Parameters reach workers as versioned read-only snapshots
//!   broadcast by the leader each batch; the feature KV store is read
//!   concurrently during marshal and written only by the leader's
//!   update phase. The double-buffered pipeline still prefetches batch
//!   `i+1`'s sampling while batch `i` sits in the leader phase (see
//!   [`crate::metrics::timeline`]), and `train.staleness = k >= 1`
//!   opens the async 1F1B window on top: the leader releases batch
//!   `i+k` right after gathering batch `i`'s results, so later
//!   forwards (against snapshots at most `k` updates behind) overlap
//!   in-flight backwards and updates. The schedule stays deterministic
//!   — releases, store-write barriers and version-pinned gradient
//!   folds keep a fixed order — and `k = 0` remains byte-identical to
//!   the synchronous protocol (`tests/test_async_pipeline.rs`).
//!
//! Every transfer of the *modeled* system is still charged through
//! [`crate::comm::CostModel`] ledgers with the same calls the
//! sequential engines make, so reported communication bytes are exact
//! and runtime-independent. Select the runtime with the
//! `train.runtime` config flag (`"sequential"` | `"cluster"`); the
//! `train.pipeline` flag isolates the double-buffering for A/B runs and
//! `train.shared_session` reproduces the old serialized execution.
//!
//! Since PR 5 the whole stack above is generic over the
//! [`Transport`](mailbox::Transport) contract: the collectives and
//! both engine loops run unchanged over in-process channels
//! (`train.transport = "channel"`, threads as above) or over the TCP
//! star of [`crate::net`] (`"tcp"` — one OS process per rank, every
//! message through the versioned wire codec, the leader's
//! learnable-feature updates replicated to worker-process stores by
//! delta broadcast). Losses are byte-identical across both transports
//! at any fixed staleness; `heta launch -n K` spawns a local
//! multi-process cluster.
//!
//! PR 6 threads the [`crate::obs`] flight recorder through the whole
//! runtime: collective receives open wire-wait/barrier-wait stall
//! spans, both engines register their worker/leader threads when
//! `train.trace` is set, and at epoch end every worker ships a
//! clock-aligned `TraceBlob` (tracks + metrics) to the leader on the
//! stats path — unconditionally, so the message schedule (and the
//! losses) are byte-identical with tracing on or off.

pub mod collective;
pub mod mailbox;
pub mod raf;
pub mod vanilla;

use anyhow::{ensure, Result};

use crate::net::codec::WireCodec;
use crate::net::tcp::{
    TcpChannel, TcpNode, LANE_BARRIER_DOWN, LANE_BARRIER_UP, LANE_DATA_DOWN, LANE_DATA_UP,
};
use crate::net::{Role, WireTraffic};
use mailbox::Wire;

/// One process's four typed socket lanes, generic over an engine's
/// protocol types (`U`p worker→leader, `D`own leader→worker). Opened
/// once per training run from the session's [`TcpNode`] and reused
/// across epochs (each lane's receive queue exists exactly once). Both
/// engines wrap this in their own `TcpLanes` newtype, instantiated
/// with their private message enums.
pub(crate) struct Lanes<U, D> {
    pub(crate) up: TcpChannel<U>,
    pub(crate) down: TcpChannel<D>,
    pub(crate) bar_up: TcpChannel<()>,
    pub(crate) bar_down: TcpChannel<()>,
    pub(crate) role: Role,
}

impl<U: WireCodec + Wire, D: WireCodec + Wire> Lanes<U, D> {
    pub(crate) fn open(node: &TcpNode, parts: usize) -> Result<Lanes<U, D>> {
        ensure!(
            node.workers() == parts,
            "the TCP star has {} worker ranks but this config trains {parts} partitions \
             (check --peers / train.num_partitions)",
            node.workers()
        );
        Ok(Lanes {
            up: node.open_lane(LANE_DATA_UP)?,
            down: node.open_lane(LANE_DATA_DOWN)?,
            bar_up: node.open_lane(LANE_BARRIER_UP)?,
            bar_down: node.open_lane(LANE_BARRIER_DOWN)?,
            role: node.role(),
        })
    }

    /// Node-level counters: every lane of this process.
    pub(crate) fn traffic(&self) -> WireTraffic {
        self.up.traffic()
    }
}
