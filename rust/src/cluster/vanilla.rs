//! The vanilla (DGL/GraphLearn-style) engine on the cluster runtime.
//!
//! Data parallelism: each worker thread samples the full k-hop tree for
//! its microbatch and runs the fused `vanilla` train-step artifact on
//! its **own** execution context — concurrently with every other
//! worker; the leader prices the ring all-reduce, applies the mean
//! gradients and the sparse learnable-feature updates, then releases
//! the next batch with a fresh parameter snapshot. With
//! `train.pipeline` on, workers prefetch batch `i+1`'s sample while the
//! leader runs batch `i`'s all-reduce + update phase.
//!
//! `train.staleness = k >= 1` opens the async window (PR 4): the
//! leader releases batch `i+k` right after gathering batch `i`'s step
//! results, so workers sample+marshal+execute later batches — against
//! snapshots missing at most `k` updates — while the leader is still
//! applying batch `i`'s updates. The fused step has no separate
//! backward, so the determinism question is the **feature store**: a
//! marshal overlapping an update would read learnable rows racily.
//! The windowed worker therefore splits the stage at its resumable
//! point — marshal, announce `Marshaled`, then execute — and the
//! leader's update waits for the `Marshaled` notice of *every released
//! batch* before writing the store. Each marshal then deterministically
//! sees exactly the updates through batch `i - k - 1`, while artifact
//! execution (the long half) still overlaps the update window. All
//! contributions are batch-tagged; fast workers run whole rounds ahead
//! and the leader's gather parks them ([`Hub::gather_round`]).
//!
//! The runtime is lock-free: workers charge nothing to shared ledgers —
//! they ship their remote-byte counts up with the step results, and the
//! leader (the only owner of the [`SimNet`]) charges them in worker-id
//! order, exactly matching the sequential engine's totals. As with the
//! RAF port, every reduction folds in (worker, output) order — pinned
//! per batch to the released snapshot's version — so at staleness 0
//! losses and parameter trajectories are byte-identical to the
//! sequential vanilla engine.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::{Lane, SimNet};
use crate::config::Config;
use crate::coordinator::common::Session;
use crate::exec::plan::vanilla_apply_updates;
use crate::exec::{
    BatchArena, BatchPlan, EpochWorld, ExecContext, ExecGate, GradAccumulator, ParamsView,
};
use crate::hetgraph::NodeId;
use crate::kvstore::FetchStats;
use crate::metrics::timeline::{AsyncShape, EpochTimeline, LeaderSpan, WallClock, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::NodePartition;
use crate::runtime::ParamSnapshot;
use crate::sampling::{remote_counts, sample_tree, Frontier, TreeSample};
use crate::util::rng::Rng;

use super::collective::{run_contained, star, Hub, Port, RoundTag, NO_BATCH};
use super::mailbox::Wire;

/// One fused train step's results.
struct StepMsg {
    loss: f64,
    acc: f64,
    /// Unreduced gradient outputs (leader folds in worker order,
    /// version-pinned to the batch's released snapshot).
    grads: crate::exec::WorkerGrads,
    /// KV-store fetch accounting of this worker's input build (unique
    /// rows per batch when dedup gather is on; `remote_bytes` is what
    /// the leader charges to this worker's network ledger).
    stats: FetchStats,
    /// Remote-neighbor-lookup id traffic of the sampling stage, charged
    /// by the leader (workers own no ledgers — the runtime is lock-free).
    sample_remote_bytes: u64,
    span: WorkerSpan,
    stages: StageTimes,
    wall_fwd: (f64, f64),
}

/// Worker → leader messages, batch-tagged for the round gather.
enum Up {
    /// Store barrier notice of the windowed schedule: this worker's
    /// feature-store reads for batch `bi` are done (its marshal
    /// finished; execution may still be running). The leader may not
    /// write the store while any released batch is unmarshalled.
    /// Never sent by the synchronous protocol.
    Marshaled { bi: usize },
    Step { bi: usize, msg: Box<StepMsg> },
    /// Best-effort death notice naming the in-flight batch: without it
    /// a leader gathering from a dead worker would block forever while
    /// live workers keep the channel connected.
    Failed { bi: usize, msg: String },
}

/// Gather rounds: up to two per batch — the marshal notice, then the
/// step results.
fn marshal_round(bi: usize) -> u64 {
    2 * bi as u64
}
fn step_round(bi: usize) -> u64 {
    2 * bi as u64 + 1
}

fn up_tag(u: &Up) -> RoundTag {
    match u {
        Up::Marshaled { bi } => RoundTag::Round(marshal_round(*bi)),
        Up::Step { bi, .. } => RoundTag::Round(step_round(*bi)),
        Up::Failed { bi, msg } => RoundTag::abort_for(*bi, msg),
    }
}

impl Wire for Up {
    fn wire_bytes(&self) -> u64 {
        // Dense gradients move via the ring all-reduce the leader
        // charges to every worker ledger (the modeled system never
        // ships raw per-worker grads to a coordinator); the marshal
        // notice and death notice are control metadata.
        0
    }
}

/// Batch release carrying the post-update parameter snapshot every
/// replica applies identically (data parallelism); snapshot
/// distribution is an in-process artifact of the single-machine
/// harness — the all-reduce already priced the gradient exchange.
#[derive(Clone)]
struct ReadyMsg {
    bi: usize,
    params: Arc<ParamSnapshot>,
}

impl Wire for ReadyMsg {
    fn wire_bytes(&self) -> u64 {
        0
    }
}

/// Run one vanilla epoch on the cluster runtime.
pub fn run_epoch(
    plan: &BatchPlan,
    contexts: &mut [ExecContext],
    part: &NodePartition,
    gate: Option<&ExecGate>,
    sess: &mut Session,
    epoch: usize,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = part.num_parts;
    let b = cfg.train.batch_size;
    let vb = (b / parts).max(1);
    let pipeline = cfg.train.pipeline;
    // The staleness window rides the pipeline: with pipelining disabled
    // the runtime is the synchronous A/B baseline.
    let staleness = if pipeline { cfg.train.staleness } else { 0 };
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);

    let mut train = sess.g.train_nodes();
    let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
    shuffle_rng.shuffle(&mut train);
    let mut batches: Vec<Vec<NodeId>> = Vec::new();
    for c in train.chunks(b) {
        if c.len() < vb * parts {
            break;
        }
        batches.push(c.to_vec());
    }
    if batches.is_empty() {
        // Nothing to release: spawning workers would race the initial
        // Ready broadcast against their immediate teardown.
        return Ok(EpochReport::empty(parts));
    }

    let world = EpochWorld {
        cfg: &cfg,
        g: &g,
        tree: &tree,
        store: &sess.store,
        gate,
        epoch_t0: Instant::now(),
    };
    let params = &mut sess.params;
    let adam_t = &mut sess.adam_t;

    let (hub, ports) = star::<Up, ReadyMsg>(parts);
    let (bhub, bports) = star::<(), ()>(parts);

    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts);
        for ((ctx, port), bport) in contexts.iter_mut().zip(ports).zip(bports) {
            let world = &world;
            let batches = &batches;
            handles.push(s.spawn(move || {
                worker_loop(
                    ctx, plan, world, part, vb, epoch, batches, &port, &bport, pipeline, staleness,
                )
            }));
        }
        let led = leader_loop(
            hub, bhub, &world, params, adam_t, parts, vb, &batches, pipeline, staleness,
        );
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e);
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        // The leader's error already embeds worker root causes (via
        // the `Failed` death notice), so it wins; worker errors cover
        // the remainder.
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    })
}

/// Runs the worker body; on error (or panic), ships a best-effort death
/// notice naming the in-flight batch so the leader's gather fails fast
/// with the root cause instead of blocking on a dead peer.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    part: &NodePartition,
    vb: usize,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, ReadyMsg>,
    bport: &Port<(), ()>,
    pipeline: bool,
    staleness: usize,
) -> Result<()> {
    let w = ctx.worker;
    // The batch cursor outlives a panic's unwinding, so the death
    // notice still names the batch in flight.
    let cur = AtomicUsize::new(NO_BATCH);
    run_contained(
        w,
        &cur,
        || {
            if staleness == 0 {
                worker_run_sync(
                    ctx, plan, world, part, vb, epoch, batches, port, bport, pipeline, &cur,
                )
            } else {
                worker_run_windowed(ctx, plan, world, part, vb, epoch, batches, port, bport, &cur)
            }
        },
        |bi, msg| {
            let _ = port.send(Up::Failed { bi, msg });
        },
    )
}

/// The synchronous (`staleness = 0`) worker: one fused step per
/// release, with the double-buffered sample prefetch when `pipeline`
/// is on. Byte-for-byte the pre-window protocol (no marshal notices).
#[allow(clippy::too_many_arguments)]
fn worker_run_sync(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    part: &NodePartition,
    vb: usize,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, ReadyMsg>,
    bport: &Port<(), ()>,
    pipeline: bool,
    cur: &AtomicUsize,
) -> Result<()> {
    bport.barrier()?;
    let w = ctx.worker;
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let layers = cfg.model.layers;
    let parts = part.num_parts;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[w];
    // One arena serves every batch (the fused step has no backward to
    // keep staging alive for).
    let mut arena = BatchArena::new();
    // Per-thread dedup-frontier scratch; `spare` lets one frontier
    // allocation ping-pong with the double-buffered prefetch.
    let mut spare: Option<Frontier> = None;
    let mut prefetched: Option<(TreeSample, Option<Frontier>, f64)> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        cur.store(bi, Ordering::Relaxed);
        let ready = port.recv()?;
        if ready.bi != bi {
            bail!("worker {w}: release for batch {} arrived while expecting {bi}", ready.bi);
        }
        let snapshot = ready.params;
        let micro = &chunk[w * vb..(w + 1) * vb];
        let batch_seed = cfg.train.batch_seed(epoch, bi);

        // -- sampling over the whole graph: remote hops are RPCs --
        let (sample, frontier, mut sample_s) = match prefetched.take() {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let s = sample_tree(
                    world.g,
                    world.tree,
                    &cfg.model.fanouts,
                    micro,
                    w * vb,
                    batch_seed,
                    |_| true,
                );
                let fr = cfg
                    .train
                    .dedup_fetch
                    .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
                (s, fr, t0.elapsed().as_secs_f64() * scale)
            }
        };
        let rstats = remote_counts(world.tree, &sample, part, w);
        // Remote neighbor lookups: id traffic + one RPC per hop per
        // remote machine; the byte count ships up for the leader-owned
        // ledger.
        sample_s += cfg.cost.xfer_time_msgs(
            Lane::Net,
            rstats.remote * 8,
            (layers * (parts - 1)).max(1) as u64,
        );

        // -- fused marshal + train step on this worker's own context --
        let step = wp.vanilla_step(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            part,
            &sample,
            frontier.as_ref(),
            micro,
            sample_s,
            &mut arena,
        )?;
        port.send(Up::Step {
            bi,
            msg: Box::new(StepMsg {
                loss: step.loss,
                acc: step.acc,
                grads: step.grads,
                stats: step.stats,
                sample_remote_bytes: rstats.remote * 8,
                span: step.span,
                stages: step.stages,
                wall_fwd: step.wall_fwd,
            }),
        })?;
        // This batch's frontier is done; recycle its allocation for the
        // prefetch below (ping-pong, no steady-state allocation).
        if let Some(f) = frontier {
            spare = Some(f);
        }

        // -- double-buffer: prefetch the next microbatch's sample (and
        // its dedup frontier, so the dedup work overlaps the leader
        // phase of batch `bi`) --
        if pipeline && bi + 1 < batches.len() {
            let nseed = cfg.train.batch_seed(epoch, bi + 1);
            let t = Instant::now();
            let s = sample_tree(
                world.g,
                world.tree,
                &cfg.model.fanouts,
                &batches[bi + 1][w * vb..(w + 1) * vb],
                w * vb,
                nseed,
                |_| true,
            );
            let fr = cfg
                .train
                .dedup_fetch
                .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
            prefetched = Some((s, fr, t.elapsed().as_secs_f64() * scale));
        }
    }
    Ok(())
}

/// The windowed (`staleness >= 1`) worker: per release, sample and
/// marshal the batch, announce `Marshaled` (the leader's store
/// barrier), then execute and ship the step results. Releases queue up
/// in the mailbox while the worker grinds, so no separate prefetch is
/// needed — the window itself provides the run-ahead.
#[allow(clippy::too_many_arguments)]
fn worker_run_windowed(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    part: &NodePartition,
    vb: usize,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, ReadyMsg>,
    bport: &Port<(), ()>,
    cur: &AtomicUsize,
) -> Result<()> {
    bport.barrier()?;
    let w = ctx.worker;
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let layers = cfg.model.layers;
    let parts = part.num_parts;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[w];
    let mut arena = BatchArena::new();
    let mut spare: Option<Frontier> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        cur.store(bi, Ordering::Relaxed);
        let ready = port.recv()?;
        if ready.bi != bi {
            bail!("worker {w}: release for batch {} arrived while expecting {bi}", ready.bi);
        }
        let snapshot = ready.params;
        let micro = &chunk[w * vb..(w + 1) * vb];

        let t0 = Instant::now();
        let sample = sample_tree(
            world.g,
            world.tree,
            &cfg.model.fanouts,
            micro,
            w * vb,
            cfg.train.batch_seed(epoch, bi),
            |_| true,
        );
        let frontier = cfg
            .train
            .dedup_fetch
            .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &sample, ntypes, wp.needs_root));
        let mut sample_s = t0.elapsed().as_secs_f64() * scale;
        let rstats = remote_counts(world.tree, &sample, part, w);
        sample_s += cfg.cost.xfer_time_msgs(
            Lane::Net,
            rstats.remote * 8,
            (layers * (parts - 1)).max(1) as u64,
        );

        // Marshal, announce the store barrier, then execute — one
        // shared-session token brackets both halves, like the fused
        // synchronous stage.
        let step = {
            let _token = world.serialize();
            let m = wp.vanilla_marshal(
                ctx,
                world,
                ParamsView::Snapshot(&snapshot),
                part,
                &sample,
                frontier.as_ref(),
                micro,
                &mut arena,
            )?;
            port.send(Up::Marshaled { bi })?;
            wp.vanilla_execute(ctx, world, m, part, &sample, micro, sample_s, snapshot.version)?
        };
        port.send(Up::Step {
            bi,
            msg: Box::new(StepMsg {
                loss: step.loss,
                acc: step.acc,
                grads: step.grads,
                stats: step.stats,
                sample_remote_bytes: rstats.remote * 8,
                span: step.span,
                stages: step.stages,
                wall_fwd: step.wall_fwd,
            }),
        })?;
        if let Some(f) = frontier {
            spare = Some(f);
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    mut hub: Hub<Up, ReadyMsg>,
    bhub: Hub<(), ()>,
    world: &EpochWorld<'_>,
    params: &mut crate::runtime::ParamStore,
    adam_t: &mut i32,
    parts: usize,
    vb: usize,
    batches: &[Vec<NodeId>],
    pipeline: bool,
    staleness: usize,
) -> Result<EpochReport> {
    bhub.barrier()?;
    let n = batches.len();
    let mut net = SimNet::new(parts, world.cfg.cost.clone());
    let mut timeline = EpochTimeline::new(parts);
    let mut stages = StageTimes::default();
    let mut worker_stages = vec![StageTimes::default(); parts];
    let mut wall = WallClock::new(parts);
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut batch_losses = Vec::with_capacity(n);
    let mut batches_done = 0usize;
    let mut fetch = FetchStats::default();

    // Prime the release window (k = 0 opens batch 0 only; a k-window
    // opens k batches — batch j's snapshot trails by j <= k updates),
    // recording each released snapshot's version: the fold of batch
    // bi's gradients is pinned to ready_versions[bi].
    let mut ready_versions: Vec<u64> = Vec::with_capacity(n);
    let mut released = 0usize;
    for _ in 0..staleness.max(1).min(n) {
        let snap = Arc::new(params.snapshot());
        ready_versions.push(snap.version);
        hub.broadcast(ReadyMsg { bi: released, params: snap })?;
        released += 1;
    }
    // Count of batches whose `Marshaled` barrier notice has been
    // consumed (windowed schedule only).
    let mut marshal_gathered = 0usize;

    for bi in 0..n {
        let msgs = hub
            .gather_round(step_round(bi), up_tag)
            .with_context(|| format!("batch {bi}: collecting step results"))?;
        let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
        let mut gacc = GradAccumulator::for_version(ready_versions[bi]);
        let mut batch_loss = 0.0f64;
        for (wid, up) in msgs.into_iter().enumerate() {
            let m = match up {
                Up::Step { bi: ubi, msg } => {
                    if ubi != bi {
                        bail!("protocol error: batch {ubi} step results in batch {bi}'s round");
                    }
                    msg
                }
                Up::Marshaled { bi: ubi } => {
                    bail!("protocol error: batch {ubi} marshal notice in batch {bi}'s step round")
                }
                Up::Failed { .. } => unreachable!("gather_round aborts on Failed"),
            };
            let StepMsg {
                loss,
                acc,
                grads,
                stats,
                sample_remote_bytes,
                span,
                stages: wstages,
                wall_fwd,
            } = *m;
            // Charge the worker's remote traffic to its ledger — same
            // calls, same totals as the sequential engine.
            net.charge(wid, Lane::Net, sample_remote_bytes, 0.0)?;
            net.charge(wid, Lane::Net, stats.remote_bytes, 0.0)?;
            batch_loss += loss / parts as f64;
            acc_sum += acc;
            gacc.absorb(grads)
                .with_context(|| format!("batch {bi}, worker {wid}"))?;
            fetch.merge(stats);
            worker_spans.push(span);
            stages.merge(&wstages);
            worker_stages[wid].merge(&wstages);
            wall.record_forward(wid, wall_fwd);
        }
        loss_sum += batch_loss;
        batch_losses.push(batch_loss);

        // -- async release: batch bi+k goes out before this batch's
        // update, bounding its forward snapshot at k missing updates --
        if staleness >= 1 && released < n {
            let snap = Arc::new(params.snapshot());
            ready_versions.push(snap.version);
            hub.broadcast(ReadyMsg { bi: released, params: snap })?;
            released += 1;
        }
        // -- store barrier: before the update may write learnable rows,
        // every released batch must have finished marshalling (its
        // feature reads then deterministically precede this write) --
        if staleness >= 1 {
            while marshal_gathered < released {
                let mbi = marshal_gathered;
                hub.gather_round(marshal_round(mbi), up_tag)
                    .with_context(|| format!("batch {mbi}: store-barrier marshal notices"))?;
                marshal_gathered += 1;
            }
        }

        // -- all-reduce + model + learnable updates (shared stage) --
        let upd = vanilla_apply_updates(world, params, adam_t, gacc, &mut net, parts)?;
        stages.add(Stage::GradSync, upd.allreduce_s);
        stages.add(Stage::Update, upd.update_s + upd.lf_s);

        timeline.push_batch(
            worker_spans,
            LeaderSpan {
                gather_s: upd.allreduce_s,
                leader_s: 0.0,
                scatter_s: 0.0,
                update_s: upd.update_s + upd.lf_s,
                sync_s: 0.0,
            },
        );
        batches_done += 1;
        // -- synchronous release: batch bi+1 waits for this update --
        if staleness == 0 && released < n {
            let snap = Arc::new(params.snapshot());
            ready_versions.push(snap.version);
            hub.broadcast(ReadyMsg { bi: released, params: snap })?;
            released += 1;
        }
    }

    let epoch_time_s = timeline.sequential_time();
    let critical_path_s = if staleness >= 1 {
        timeline.async_pipelined_time(staleness, AsyncShape::Vanilla)
    } else if pipeline {
        timeline.pipelined_time()
    } else {
        epoch_time_s
    };
    Ok(EpochReport {
        epoch_time_s,
        critical_path_s,
        worker_busy_s: timeline.worker_busy_s(),
        worker_stages,
        wall,
        stages,
        comm: net.total(),
        fetch,
        loss_mean: if batches_done > 0 {
            loss_sum / batches_done as f64
        } else {
            f64::NAN
        },
        accuracy: if batches_done > 0 {
            acc_sum / (batches_done * vb * parts) as f64
        } else {
            f64::NAN
        },
        batches: batches_done,
        batch_losses,
    })
}
