//! The vanilla (DGL/GraphLearn-style) engine on the cluster runtime.
//!
//! Data parallelism: each worker thread samples the full k-hop tree for
//! its microbatch, fetches features (remote rows cross the modeled
//! network), and runs the fused `vanilla` train-step artifact; the
//! leader prices the ring all-reduce, applies the mean gradients and
//! the sparse learnable-feature updates, then releases the next batch.
//! With `train.pipeline` on, workers prefetch batch `i+1`'s sample
//! while the leader runs batch `i`'s all-reduce + update phase.
//!
//! As with the RAF port, every reduction folds in (worker, output)
//! order, so losses and parameter trajectories are byte-identical to
//! the sequential vanilla engine.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cache::FeatureCache;
use crate::comm::{Lane, SimNet};
use crate::config::Config;
use crate::coordinator::common::{
    add_assign, apply_learnable_grads, build_inputs, learnable_rows_sorted, vanilla_fetch_time,
    vanilla_learnable_update_cost, BatchArena, ExtraInputs, Session,
};
use crate::hetgraph::{HetGraph, MetaTree, NodeId};
use crate::kvstore::FetchStats;
use crate::metrics::timeline::{EpochTimeline, LeaderSpan, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::NodePartition;
use crate::sampling::{remote_counts, sample_tree, Frontier, TreeSample, PAD};
use crate::util::rng::Rng;

use super::collective::{star, Hub, Port};
use super::lock;
use super::mailbox::Wire;

/// Worker → leader message: one fused train step's results.
struct StepMsg {
    loss: f64,
    acc: f64,
    /// Per-output weight grads, unmerged (leader folds in worker order).
    wgrads: Vec<(String, Vec<f32>)>,
    /// `(ty, ids, grads)` per learnable-row grad output.
    row_grads: Vec<(usize, Vec<NodeId>, Vec<f32>)>,
    /// `(ty, valid rows, remote rows)` per learnable type, sorted by
    /// type — the leader's sparse-update cost model (real dims).
    learnable_rows: Vec<(usize, u64, u64)>,
    /// KV-store fetch accounting of this worker's input build (unique
    /// rows per batch when dedup gather is on).
    stats: FetchStats,
    span: WorkerSpan,
    stages: StageTimes,
}

impl Wire for StepMsg {
    fn wire_bytes(&self) -> u64 {
        // Dense gradients move via the ring all-reduce the leader
        // charges to every worker ledger (the modeled system never
        // ships raw per-worker grads to a coordinator).
        0
    }
}

/// `Err` is a worker's best-effort death notice: without it a leader
/// gathering from a dead worker would block forever while live workers
/// keep the channel connected.
type StepResult = std::result::Result<StepMsg, String>;

#[derive(Clone)]
struct ReadyMsg;

impl Wire for ReadyMsg {
    fn wire_bytes(&self) -> u64 {
        0
    }
}

/// Run one vanilla epoch on the cluster runtime.
pub fn run_epoch(
    part: &NodePartition,
    caches: Option<&mut Vec<FeatureCache>>,
    sess: &mut Session,
    epoch: usize,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = part.num_parts;
    let b = cfg.train.batch_size;
    let vb = (b / parts).max(1);
    let pipeline = cfg.train.pipeline;
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);

    let mut train = sess.g.train_nodes();
    let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
    shuffle_rng.shuffle(&mut train);
    let mut batches: Vec<Vec<NodeId>> = Vec::new();
    for c in train.chunks(b) {
        if c.len() < vb * parts {
            break;
        }
        batches.push(c.to_vec());
    }

    let cache_mx: Option<Vec<Mutex<&mut FeatureCache>>> =
        caches.map(|cs| cs.iter_mut().map(Mutex::new).collect());
    let net_mx = Mutex::new(SimNet::new(parts, cfg.cost.clone()));
    let sess_mx = Mutex::new(sess);
    let (hub, ports) = star::<StepResult, ReadyMsg>(parts);
    let (bhub, bports) = star::<(), ()>(parts);

    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts);
        for ((w, port), bport) in ports.into_iter().enumerate().zip(bports) {
            let cfg = &cfg;
            let g = &g;
            let tree = &tree;
            let batches = &batches;
            let sess_mx = &sess_mx;
            let net_mx = &net_mx;
            let cache = cache_mx.as_ref().map(|v| &v[w]);
            handles.push(s.spawn(move || {
                worker_loop(
                    w, parts, vb, cfg, epoch, batches, g, tree, part, sess_mx, net_mx, cache,
                    &port, &bport, pipeline,
                )
            }));
        }
        let led = leader_loop(
            hub, bhub, &cfg, parts, vb, &batches, &sess_mx, &net_mx, pipeline,
        );
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e);
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        // The leader's error already embeds worker root causes (via
        // the `Err` death notice), so it wins; worker errors cover the
        // remainder.
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    })
}

/// Runs the worker body; on error, ships a best-effort death notice so
/// the leader's gather fails fast instead of blocking on a dead peer.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    w: usize,
    parts: usize,
    vb: usize,
    cfg: &Config,
    epoch: usize,
    batches: &[Vec<NodeId>],
    g: &Arc<HetGraph>,
    tree: &Arc<MetaTree>,
    part: &NodePartition,
    sess_mx: &Mutex<&mut Session>,
    net_mx: &Mutex<SimNet>,
    cache_mx: Option<&Mutex<&mut FeatureCache>>,
    port: &Port<StepResult, ReadyMsg>,
    bport: &Port<(), ()>,
    pipeline: bool,
) -> Result<()> {
    // Contain panics too: a panicked worker that never notified the
    // leader would leave the gather blocked while live peers keep the
    // channel connected.
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        worker_run(
            w, parts, vb, cfg, epoch, batches, g, tree, part, sess_mx, net_mx, cache_mx, port,
            bport, pipeline,
        )
    }));
    let r = caught.unwrap_or_else(|_| Err(anyhow!("worker {w} panicked")));
    if let Err(e) = &r {
        let _ = port.send(Err(format!("{e:#}")));
    }
    r
}

#[allow(clippy::too_many_arguments)]
fn worker_run(
    w: usize,
    parts: usize,
    vb: usize,
    cfg: &Config,
    epoch: usize,
    batches: &[Vec<NodeId>],
    g: &Arc<HetGraph>,
    tree: &Arc<MetaTree>,
    part: &NodePartition,
    sess_mx: &Mutex<&mut Session>,
    net_mx: &Mutex<SimNet>,
    cache_mx: Option<&Mutex<&mut FeatureCache>>,
    port: &Port<StepResult, ReadyMsg>,
    bport: &Port<(), ()>,
    pipeline: bool,
) -> Result<()> {
    bport.barrier()?;
    let scale = cfg.cost.compute_scale;
    let gpus = cfg.train.gpus_per_machine.max(1);
    let layers = cfg.model.layers;
    let ntypes = g.schema.node_types.len();
    let cost = cfg.cost.clone();
    // The manifest is immutable during an epoch: clone the fused-step
    // spec once instead of per batch inside the serialized section.
    let spec = {
        let guard = lock(sess_mx, "session")?;
        guard.rt.manifest.spec("vanilla")?.clone()
    };
    // Root (target) rows join the fetch frontier only if the artifact
    // actually gathers them.
    let needs_root = spec.inputs.iter().any(|i| i.kind == "target_feat");
    // Per-thread marshalling scratch; `spare` lets one frontier
    // allocation ping-pong with the double-buffered prefetch.
    let mut arena = BatchArena::new();
    let mut spare: Option<Frontier> = None;
    let mut prefetched: Option<(TreeSample, Option<Frontier>, f64)> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        if bi > 0 {
            port.recv()?;
        }
        let micro = &chunk[w * vb..(w + 1) * vb];
        let batch_seed = cfg.train.batch_seed(epoch, bi);

        // -- sampling over the whole graph: remote hops are RPCs --
        let (sample, frontier, mut sample_t) = match prefetched.take() {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let s = sample_tree(g, tree, &cfg.model.fanouts, micro, w * vb, batch_seed, |_| {
                    true
                });
                let fr = cfg
                    .train
                    .dedup_fetch
                    .then(|| Frontier::take_rebuilt(&mut spare, tree, &s, ntypes, needs_root));
                (s, fr, t0.elapsed().as_secs_f64() * scale)
            }
        };
        let rstats = remote_counts(tree, &sample, part, w);
        sample_t += cost.xfer_time_msgs(
            Lane::Net,
            rstats.remote * 8,
            (layers * (parts - 1)).max(1) as u64,
        );
        lock(net_mx, "net")?.charge(w, Lane::Net, rstats.remote * 8, 0.0)?;

        // -- fetch + fused step under the session lock --
        arena.begin_batch(ntypes);
        let (msg_core, fetch_t, copy_s, step_t) = {
            let mut guard = lock(sess_mx, "session")?;
            let sess: &mut Session = &mut **guard;
            let t1 = Instant::now();
            let extra = ExtraInputs::new();
            let mut cguard = match cache_mx {
                Some(m) => Some(lock(m, "cache")?),
                None => None,
            };
            let (lits, acc) = build_inputs(
                sess,
                &spec,
                Some(&sample),
                frontier.as_ref(),
                micro,
                &extra,
                &|ty, id| part.owner_of(ty, id) != w,
                cguard.as_mut().map(|gd| &mut ***gd),
                0,
                &mut arena,
            )?;
            drop(cguard);
            let copy_s = t1.elapsed().as_secs_f64() * scale;
            let fetch_t = vanilla_fetch_time(&cost, &acc, cache_mx.is_some(), parts);
            lock(net_mx, "net")?.charge(w, Lane::Net, acc.stats.remote_bytes, 0.0)?;

            let t2 = Instant::now();
            let outs = sess.rt.exec("vanilla", &lits)?;
            let step_t = t2.elapsed().as_secs_f64() * scale / gpus as f64;
            if outs.len() < 2 {
                bail!("vanilla artifact returned {} outputs, expected >= 2", outs.len());
            }
            let loss = crate::runtime::lit_scalar(&outs[0])? as f64;
            let acc_v = crate::runtime::lit_scalar(&outs[1])? as f64;

            let mut wgrads: Vec<(String, Vec<f32>)> = Vec::new();
            let mut row_grads: Vec<(usize, Vec<NodeId>, Vec<f32>)> = Vec::new();
            // type → (valid rows, remote rows) for the update-cost model.
            let mut learnable_counts: HashMap<usize, (u64, u64)> = HashMap::new();
            for (o, out) in spec.outputs.iter().zip(&outs) {
                match o.kind.as_str() {
                    "wgrad" => {
                        wgrads.push((o.name.clone(), crate::runtime::lit_to_vec(out)?));
                    }
                    "block_grad" => {
                        let (child, src_ty) = sess.edge_child(o.edge as usize);
                        let counts = learnable_counts.entry(src_ty).or_insert((0, 0));
                        for &id in &sample.ids[child] {
                            if id != PAD {
                                counts.0 += 1;
                                if part.owner_of(src_ty, id) != w {
                                    counts.1 += 1;
                                }
                            }
                        }
                        row_grads.push((
                            src_ty,
                            sample.ids[child].clone(),
                            crate::runtime::lit_to_vec(out)?,
                        ));
                    }
                    "target_feat_grad" => {
                        if sess.store.is_learnable(sess.g.schema.target) {
                            let counts = learnable_counts
                                .entry(sess.g.schema.target)
                                .or_insert((0, 0));
                            counts.0 += micro.len() as u64;
                            row_grads.push((
                                sess.g.schema.target,
                                micro.to_vec(),
                                crate::runtime::lit_to_vec(out)?,
                            ));
                        }
                    }
                    _ => {}
                }
            }
            let mut learnable_rows: Vec<(usize, u64, u64)> = learnable_counts
                .into_iter()
                .map(|(ty, (rows, remote))| (ty, rows, remote))
                .collect();
            learnable_rows.sort_unstable_by_key(|e| e.0);
            (
                (loss, acc_v, wgrads, row_grads, learnable_rows, acc.stats),
                fetch_t,
                copy_s,
                step_t,
            )
        };
        let (loss, acc_v, wgrads, row_grads, learnable_rows, stats) = msg_core;

        let mut stages = StageTimes::default();
        stages.add(Stage::Sample, sample_t);
        stages.add(Stage::Copy, copy_s);
        stages.add(Stage::Fetch, fetch_t);
        stages.add(Stage::Forward, step_t * 0.45);
        stages.add(Stage::Backward, step_t * 0.55);
        let span = WorkerSpan {
            sample_s: sample_t,
            // Vanilla fetch mixes remote and learnable rows, so the
            // whole fetch stays slot-bound (conservative); sampling is
            // the prefetchable stage here.
            fetch_ro_s: 0.0,
            fetch_lr_s: fetch_t,
            copy_s,
            fwd_s: step_t,
            bwd_s: 0.0,
        };
        port.send(Ok(StepMsg {
            loss,
            acc: acc_v,
            wgrads,
            row_grads,
            learnable_rows,
            stats,
            span,
            stages,
        }))?;
        // This batch's frontier is done; recycle its allocation for the
        // prefetch below (ping-pong, no steady-state allocation).
        if let Some(f) = frontier {
            spare = Some(f);
        }

        // -- double-buffer: prefetch the next microbatch's sample (and
        // its dedup frontier, so the dedup work overlaps the leader
        // phase of batch `bi`) --
        if pipeline && bi + 1 < batches.len() {
            let nseed = cfg.train.batch_seed(epoch, bi + 1);
            let t = Instant::now();
            let s = sample_tree(
                g,
                tree,
                &cfg.model.fanouts,
                &batches[bi + 1][w * vb..(w + 1) * vb],
                w * vb,
                nseed,
                |_| true,
            );
            let fr = cfg
                .train
                .dedup_fetch
                .then(|| Frontier::take_rebuilt(&mut spare, tree, &s, ntypes, needs_root));
            prefetched = Some((s, fr, t.elapsed().as_secs_f64() * scale));
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    hub: Hub<StepResult, ReadyMsg>,
    bhub: Hub<(), ()>,
    cfg: &Config,
    parts: usize,
    vb: usize,
    batches: &[Vec<NodeId>],
    sess_mx: &Mutex<&mut Session>,
    net_mx: &Mutex<SimNet>,
    pipeline: bool,
) -> Result<EpochReport> {
    bhub.barrier()?;
    let mut timeline = EpochTimeline::new(parts);
    let mut stages = StageTimes::default();
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut batches_done = 0usize;
    let mut fetch = FetchStats::default();

    for bi in 0..batches.len() {
        let msgs = hub.gather()?;
        let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
        let mut wgrads: HashMap<String, Vec<f32>> = HashMap::new();
        let mut row_grads: HashMap<usize, (Vec<NodeId>, Vec<f32>)> = HashMap::new();
        // type → (valid rows, remote rows), merged across workers.
        let mut learnable_counts: HashMap<usize, (u64, u64)> = HashMap::new();
        for (wid, m) in msgs.into_iter().enumerate() {
            let m = match m {
                Ok(m) => m,
                Err(e) => bail!("worker {wid} failed: {e}"),
            };
            loss_sum += m.loss / parts as f64;
            acc_sum += m.acc;
            for (name, gvec) in m.wgrads {
                match wgrads.get_mut(&name) {
                    Some(acc) => add_assign(acc, &gvec),
                    None => {
                        wgrads.insert(name, gvec);
                    }
                }
            }
            for (ty, ids, gvec) in m.row_grads {
                let entry = row_grads.entry(ty).or_insert_with(|| (Vec::new(), Vec::new()));
                entry.0.extend_from_slice(&ids);
                entry.1.extend_from_slice(&gvec);
            }
            for (ty, rows, remote) in m.learnable_rows {
                let counts = learnable_counts.entry(ty).or_insert((0, 0));
                counts.0 += rows;
                counts.1 += remote;
            }
            fetch.merge(m.stats);
            worker_spans.push(m.span);
            stages.merge(&m.stages);
        }

        // -- dense gradient all-reduce + updates under the session lock --
        let (t_ar, upd_t, lf_t) = {
            let mut guard = lock(sess_mx, "session")?;
            let sess: &mut Session = &mut **guard;
            sess.adam_t += 1;
            let grad_bytes = (sess.params.total_elems() * 4) as u64;
            let mut net = lock(net_mx, "net")?;
            let t_ar = net.allreduce(grad_bytes);

            // -- model update (every replica applies the mean grad) --
            let t3 = Instant::now();
            let inv = 1.0 / parts as f32;
            for (name, mut grad) in wgrads.drain() {
                for gv in grad.iter_mut() {
                    *gv *= inv;
                }
                sess.params.step(&name, &grad)?;
            }
            let upd_t = t3.elapsed().as_secs_f64();

            // -- learnable-feature updates: remote rows pay the network --
            let t4 = Instant::now();
            for (ty, (ids, grads)) in &row_grads {
                apply_learnable_grads(sess, *ty, ids, grads, inv);
            }
            let mut lf_t = t4.elapsed().as_secs_f64();
            let lr = learnable_rows_sorted(learnable_counts, &sess.store);
            let (cost_t, remote_bytes) = vanilla_learnable_update_cost(&net.cost, &lr, parts);
            lf_t += cost_t;
            if remote_bytes > 0 {
                net.charge(0, Lane::Net, remote_bytes, 0.0)?;
            }
            (t_ar, upd_t, lf_t)
        };
        stages.add(Stage::GradSync, t_ar);
        stages.add(Stage::Update, upd_t + lf_t);

        timeline.push_batch(
            worker_spans,
            LeaderSpan {
                gather_s: t_ar,
                leader_s: 0.0,
                scatter_s: 0.0,
                update_s: upd_t + lf_t,
                sync_s: 0.0,
            },
        );
        batches_done += 1;
        if bi + 1 < batches.len() {
            hub.broadcast(ReadyMsg)?;
        }
    }

    let comm = lock(net_mx, "net")?.total();
    let epoch_time_s = timeline.sequential_time();
    let critical_path_s = if pipeline {
        timeline.pipelined_time()
    } else {
        epoch_time_s
    };
    Ok(EpochReport {
        epoch_time_s,
        critical_path_s,
        worker_busy_s: timeline.worker_busy_s(),
        stages,
        comm,
        fetch,
        loss_mean: if batches_done > 0 {
            loss_sum / batches_done as f64
        } else {
            f64::NAN
        },
        accuracy: if batches_done > 0 {
            acc_sum / (batches_done * vb * parts) as f64
        } else {
            f64::NAN
        },
        batches: batches_done,
    })
}
