//! The vanilla (DGL/GraphLearn-style) engine on the cluster runtime.
//!
//! Data parallelism: each worker thread samples the full k-hop tree for
//! its microbatch and runs the fused `vanilla` train-step artifact on
//! its **own** execution context — concurrently with every other
//! worker; the leader prices the ring all-reduce, applies the mean
//! gradients and the sparse learnable-feature updates, then releases
//! the next batch with a fresh parameter snapshot. With
//! `train.pipeline` on, workers prefetch batch `i+1`'s sample while the
//! leader runs batch `i`'s all-reduce + update phase.
//!
//! `train.staleness = k >= 1` opens the async window (PR 4): the
//! leader releases batch `i+k` right after gathering batch `i`'s step
//! results, so workers sample+marshal+execute later batches — against
//! snapshots missing at most `k` updates — while the leader is still
//! applying batch `i`'s updates. The fused step has no separate
//! backward, so the determinism question is the **feature store**: a
//! marshal overlapping an update would read learnable rows racily.
//! The windowed worker therefore splits the stage at its resumable
//! point — marshal, announce `Marshaled`, then execute — and the
//! leader's update waits for the `Marshaled` notice of *every released
//! batch* before writing the store. Each marshal then deterministically
//! sees exactly the updates through batch `i - k - 1`, while artifact
//! execution (the long half) still overlaps the update window. All
//! contributions are batch-tagged; fast workers run whole rounds ahead
//! and the leader's gather parks them ([`Hub::gather_round`]).
//!
//! The runtime is lock-free: workers charge nothing to shared ledgers —
//! they ship their remote-byte counts up with the step results, and the
//! leader (the only owner of the [`SimNet`]) charges them in worker-id
//! order, exactly matching the sequential engine's totals. As with the
//! RAF port, every reduction folds in (worker, output) order — pinned
//! per batch to the released snapshot's version — so at staleness 0
//! losses and parameter trajectories are byte-identical to the
//! sequential vanilla engine.
//!
//! Since PR 5 both loops are generic over the
//! [`Transport`](super::mailbox::Transport) endpoints: [`run_epoch`]
//! wires in-process channels, [`run_epoch_tcp`] the socket star of
//! [`crate::net::tcp`] with one OS process per rank (identical seeded
//! batch schedule everywhere; protocol messages cross the wire through
//! the [`WireCodec`](crate::net::codec::WireCodec) impls below). The
//! leader's learnable-feature writes are replicated into worker
//! processes' stores via the `Down::Store` delta — sent after each
//! update, so per-lane FIFO lands it before any batch released later,
//! reproducing the shared-store visibility order (and the `Marshaled`
//! store barrier keeps working unchanged: a worker sends the notice
//! after its marshal, the leader writes only after gathering every
//! released batch's notice). Losses are byte-identical across
//! `channel | tcp` at any fixed staleness.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::{Lane, SimNet};
use crate::config::Config;
use crate::coordinator::common::Session;
use crate::exec::plan::vanilla_apply_updates;
use crate::exec::{
    BatchArena, BatchPlan, EpochWorld, ExecContext, ExecGate, GradAccumulator, ParamsView,
    WorkerGrads,
};
use crate::hetgraph::{HetGraph, NodeId};
use crate::kvstore::{FetchStats, StoreDelta};
use crate::metrics::timeline::{AsyncShape, EpochTimeline, LeaderSpan, WallClock, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::net::codec::{ByteReader, ByteWriter, WireCodec};
use crate::net::tcp::TcpNode;
use crate::net::Role;
use crate::partition::NodePartition;
use crate::runtime::{
    need_full_msg, DiffChain, ParamDiff, ParamSnapshot, ParamStore, SnapOrDiff, SnapshotChain,
};
use crate::sampling::{remote_counts, sample_tree, Frontier, TreeSample};
use crate::util::rng::Rng;

use super::collective::{run_contained, star, Hub, Port, RoundTag, NO_BATCH};
use super::mailbox::{Transport, Wire};

/// One fused train step's results.
#[derive(Debug, PartialEq)]
struct StepMsg {
    loss: f64,
    acc: f64,
    /// Unreduced gradient outputs (leader folds in worker order,
    /// version-pinned to the batch's released snapshot).
    grads: WorkerGrads,
    /// KV-store fetch accounting of this worker's input build (unique
    /// rows per batch when dedup gather is on; `remote_bytes` is what
    /// the leader charges to this worker's network ledger).
    stats: FetchStats,
    /// Remote-neighbor-lookup id traffic of the sampling stage, charged
    /// by the leader (workers own no ledgers — the runtime is lock-free).
    sample_remote_bytes: u64,
    span: WorkerSpan,
    stages: StageTimes,
    wall_fwd: (f64, f64),
}

/// Worker → leader messages, batch-tagged for the round gather.
#[derive(Debug, PartialEq)]
enum Up {
    /// Store barrier notice of the windowed schedule: this worker's
    /// feature-store reads for batch `bi` are done (its marshal
    /// finished; execution may still be running). The leader may not
    /// write the store while any released batch is unmarshalled.
    /// Never sent by the synchronous protocol.
    Marshaled { bi: usize },
    Step { bi: usize, msg: Box<StepMsg> },
    /// Best-effort death notice naming the in-flight batch: without it
    /// a leader gathering from a dead worker would block forever while
    /// live workers keep the channel connected.
    Failed { bi: usize, msg: String },
    /// Epoch-end flight-recorder payload (PR 6): this rank's trace
    /// tracks and metrics. Always sent — empty when tracing is off —
    /// so the message schedule never depends on the trace flag.
    Obs { blob: crate::obs::TraceBlob },
    /// Explicit resync NACK (PR 8, `wire_snapshots = diff`): this
    /// worker's snapshot chain cannot apply the diff it just received
    /// (`have` = the version it holds, [`u64::MAX`] = none yet;
    /// `want` = the diff's base version). Aborts the leader's gather
    /// with an error naming the rank and both versions; the restarted
    /// epoch's first frame is a full snapshot — that is the resync.
    NeedFull { bi: usize, have: u64, want: u64 },
}

/// Gather rounds: up to two per batch — the marshal notice, then the
/// step results.
fn marshal_round(bi: usize) -> u64 {
    2 * bi as u64
}
fn step_round(bi: usize) -> u64 {
    2 * bi as u64 + 1
}
/// The epoch-end trace-blob gather rides its own round tag,
/// collision-free with any batch's `2·bi` / `2·bi + 1`.
const OBS_ROUND: u64 = u64::MAX;

fn up_tag(u: &Up) -> RoundTag {
    match u {
        Up::Marshaled { bi } => RoundTag::Round(marshal_round(*bi)),
        Up::Step { bi, .. } => RoundTag::Round(step_round(*bi)),
        Up::Failed { bi, msg } => RoundTag::abort_for(*bi, msg),
        Up::Obs { .. } => RoundTag::Round(OBS_ROUND),
        Up::NeedFull { bi, have, want } => {
            RoundTag::abort_for(*bi, &need_full_msg(*have, *want))
        }
    }
}

impl Wire for Up {
    fn wire_bytes(&self) -> u64 {
        // Dense gradients move via the ring all-reduce the leader
        // charges to every worker ledger (the modeled system never
        // ships raw per-worker grads to a coordinator); the marshal
        // notice and death notice are control metadata.
        0
    }
}

/// Leader → worker messages. `Ready` releases a batch with the
/// post-update parameter snapshot every replica applies identically
/// (data parallelism); `Store` replays the leader's learnable-feature
/// writes into a worker *process's* KV store (TCP only — one shared
/// in-process store never sends it). Both are modeled-free wire-wise:
/// snapshot/row distribution is an artifact of the harness (the
/// all-reduce already priced the gradient exchange, and learnable rows
/// live with their owners in the modeled system).
#[derive(Clone, Debug, PartialEq)]
enum Down {
    Ready { bi: usize, params: Arc<ParamSnapshot> },
    /// Post-update learnable rows of batch `bi` (see [`StoreDelta`]).
    Store { bi: usize, delta: StoreDelta },
    /// `Ready` with a version-chained [`ParamDiff`] instead of the
    /// full snapshot (PR 8, `wire_snapshots = diff`): only the tensors
    /// that advanced since the previous release. Workers resolve it
    /// against their [`SnapshotChain`] into the bit-identical full
    /// snapshot before the engine loop ever sees it. (The vanilla
    /// engine has no mesh lane: its partial aggregation is the
    /// all-reduce the cost model already prices, so `wire_exchange =
    /// mesh` is a documented no-op here.)
    ReadyDiff { bi: usize, diff: ParamDiff },
}

impl Wire for Down {
    fn wire_bytes(&self) -> u64 {
        0
    }
}

// ---- wire codec (PR 5): every protocol message next to its type ----

impl WireCodec for StepMsg {
    fn encode(&self, w: &mut ByteWriter) {
        w.f64(self.loss);
        w.f64(self.acc);
        self.grads.encode(w);
        self.stats.encode(w);
        w.u64(self.sample_remote_bytes);
        self.span.encode(w);
        self.stages.encode(w);
        self.wall_fwd.encode(w);
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<StepMsg> {
        Ok(StepMsg {
            loss: r.f64()?,
            acc: r.f64()?,
            grads: WorkerGrads::decode(r)?,
            stats: FetchStats::decode(r)?,
            sample_remote_bytes: r.u64()?,
            span: WorkerSpan::decode(r)?,
            stages: StageTimes::decode(r)?,
            wall_fwd: <(f64, f64)>::decode(r)?,
        })
    }
}

impl WireCodec for Up {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Up::Marshaled { bi } => {
                w.u8(0);
                w.usize(*bi);
            }
            Up::Step { bi, msg } => {
                w.u8(1);
                w.usize(*bi);
                msg.encode(w);
            }
            Up::Failed { bi, msg } => {
                w.u8(2);
                w.usize(*bi);
                w.str(msg);
            }
            Up::Obs { blob } => {
                w.u8(3);
                blob.encode(w);
            }
            Up::NeedFull { bi, have, want } => {
                w.u8(4);
                w.usize(*bi);
                w.u64(*have);
                w.u64(*want);
            }
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<Up> {
        match r.u8()? {
            0 => Ok(Up::Marshaled { bi: r.usize()? }),
            1 => {
                let bi = r.usize()?;
                let msg = Box::new(StepMsg::decode(r)?);
                Ok(Up::Step { bi, msg })
            }
            2 => {
                let bi = r.usize()?;
                let msg = r.str()?;
                Ok(Up::Failed { bi, msg })
            }
            3 => Ok(Up::Obs { blob: crate::obs::TraceBlob::decode(r)? }),
            4 => {
                let bi = r.usize()?;
                let have = r.u64()?;
                let want = r.u64()?;
                Ok(Up::NeedFull { bi, have, want })
            }
            t => bail!("unknown vanilla worker-message tag {t}"),
        }
    }
}

impl WireCodec for Down {
    fn encode(&self, w: &mut ByteWriter) {
        match self {
            Down::Ready { bi, params } => {
                w.u8(0);
                w.usize(*bi);
                params.encode(w);
            }
            Down::Store { bi, delta } => {
                w.u8(1);
                w.usize(*bi);
                delta.encode(w);
            }
            Down::ReadyDiff { bi, diff } => {
                w.u8(2);
                w.usize(*bi);
                diff.encode(w);
            }
        }
    }

    fn decode(r: &mut ByteReader<'_>) -> Result<Down> {
        match r.u8()? {
            0 => {
                let bi = r.usize()?;
                let params = Arc::new(ParamSnapshot::decode(r)?);
                Ok(Down::Ready { bi, params })
            }
            1 => {
                let bi = r.usize()?;
                let delta = StoreDelta::decode(r)?;
                Ok(Down::Store { bi, delta })
            }
            2 => {
                let bi = r.usize()?;
                let diff = ParamDiff::decode(r)?;
                Ok(Down::ReadyDiff { bi, diff })
            }
            t => bail!("unknown vanilla leader-message tag {t}"),
        }
    }
}

/// The epoch's batch schedule (batches short of every worker's full
/// microbatch are dropped — static shapes). Derived from config seeds
/// only, so every process of a multi-process cluster computes the
/// identical schedule without exchanging a byte.
fn batch_schedule(g: &HetGraph, cfg: &Config, parts: usize, epoch: usize) -> Vec<Vec<NodeId>> {
    let b = cfg.train.batch_size;
    let vb = (b / parts).max(1);
    let mut train = g.train_nodes();
    let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
    shuffle_rng.shuffle(&mut train);
    let mut batches: Vec<Vec<NodeId>> = Vec::new();
    for c in train.chunks(b) {
        if c.len() < vb * parts {
            break;
        }
        batches.push(c.to_vec());
    }
    batches
}

/// Run one vanilla epoch on the cluster runtime.
pub fn run_epoch(
    plan: &BatchPlan,
    contexts: &mut [ExecContext],
    part: &NodePartition,
    gate: Option<&ExecGate>,
    sess: &mut Session,
    epoch: usize,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = part.num_parts;
    let b = cfg.train.batch_size;
    let vb = (b / parts).max(1);
    let pipeline = cfg.train.pipeline;
    // The staleness window rides the pipeline: with pipelining disabled
    // the runtime is the synchronous A/B baseline.
    let staleness = if pipeline { cfg.train.staleness } else { 0 };
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);

    let batches = batch_schedule(&g, &cfg, parts, epoch);
    if batches.is_empty() {
        // Nothing to release: spawning workers would race the initial
        // Ready broadcast against their immediate teardown.
        return Ok(EpochReport::empty(parts));
    }

    let world = EpochWorld {
        cfg: &cfg,
        g: &g,
        tree: &tree,
        store: &sess.store,
        gate,
        epoch_t0: Instant::now(),
    };
    let params = &mut sess.params;
    let adam_t = &mut sess.adam_t;

    let (hub, ports) = star::<Up, Down>(parts);
    let (bhub, bports) = star::<(), ()>(parts);

    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts);
        for ((ctx, port), bport) in contexts.iter_mut().zip(ports).zip(bports) {
            let world = &world;
            let batches = &batches;
            handles.push(s.spawn(move || {
                worker_loop(
                    ctx, plan, world, part, vb, epoch, batches, &port, &bport, pipeline, staleness,
                )
            }));
        }
        let led = leader_loop(
            hub, bhub, &world, params, adam_t, parts, vb, &batches, pipeline, staleness,
            false, // one shared store: nothing to replicate
        );
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e);
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        // The leader's error already embeds worker root causes (via
        // the `Failed` death notice), so it wins; worker errors cover
        // the remainder.
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    })
}

/// Runs the worker body; on error (or panic), ships a best-effort death
/// notice naming the in-flight batch so the leader's gather fails fast
/// with the root cause instead of blocking on a dead peer.
#[allow(clippy::too_many_arguments)]
fn worker_loop<EU, ED, BU, BD>(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    part: &NodePartition,
    vb: usize,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down, EU, ED>,
    bport: &Port<(), (), BU, BD>,
    pipeline: bool,
    staleness: usize,
) -> Result<()>
where
    EU: Transport<Up>,
    ED: Transport<Down>,
    BU: Transport<()>,
    BD: Transport<()>,
{
    let w = ctx.worker;
    // The batch cursor outlives a panic's unwinding, so the death
    // notice still names the batch in flight.
    let cur = AtomicUsize::new(NO_BATCH);
    run_contained(
        w,
        &cur,
        || {
            if staleness == 0 {
                worker_run_sync(
                    ctx, plan, world, part, vb, epoch, batches, port, bport, pipeline, &cur,
                )
            } else {
                worker_run_windowed(ctx, plan, world, part, vb, epoch, batches, port, bport, &cur)
            }
        },
        |bi, msg| {
            let _ = port.send(Up::Failed { bi, msg });
        },
    )
}

/// Receive the next batch release, transparently replaying store
/// deltas into this process's KV store (the TCP replication of the
/// leader's learnable-feature writes; never sent in-process). Per-lane
/// FIFO guarantees a delta lands before any batch the leader released
/// after the update that produced it.
fn recv_ready<EU: Transport<Up>, ED: Transport<Down>>(
    port: &Port<Up, Down, EU, ED>,
    world: &EpochWorld<'_>,
    chain: &mut SnapshotChain,
) -> Result<(usize, Arc<ParamSnapshot>)> {
    loop {
        match port.recv()? {
            Down::Store { bi, delta } => delta
                .apply(&mut world.store_mut())
                .with_context(|| format!("replaying batch {bi}'s learnable-feature delta"))?,
            Down::Ready { bi, params } => {
                // Full frames re-base the chain even when diffs are
                // off, so a mid-stream mode change can never desync.
                chain.note_full(&params);
                return Ok((bi, params));
            }
            Down::ReadyDiff { bi, diff } => {
                // A chain break ships the explicit NeedFull NACK
                // (best-effort — the leader's gather may already be
                // unwinding) and surfaces as an error naming the rank
                // and both versions; it never panics. The restarted
                // epoch's first frame is always full: that's the resync.
                let p = port.id();
                match chain.apply(p, &diff) {
                    Ok(params) => return Ok((bi, params)),
                    Err(e) => {
                        let have = chain.version().unwrap_or(u64::MAX);
                        let want = diff.from_version;
                        let _ = port.send(Up::NeedFull { bi, have, want });
                        return Err(e.context(format!(
                            "worker {p}, batch {bi}: {}",
                            need_full_msg(have, want)
                        )));
                    }
                }
            }
        }
    }
}

/// The synchronous (`staleness = 0`) worker: one fused step per
/// release, with the double-buffered sample prefetch when `pipeline`
/// is on. Byte-for-byte the pre-window protocol (no marshal notices).
#[allow(clippy::too_many_arguments)]
fn worker_run_sync<EU, ED, BU, BD>(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    part: &NodePartition,
    vb: usize,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down, EU, ED>,
    bport: &Port<(), (), BU, BD>,
    pipeline: bool,
    cur: &AtomicUsize,
) -> Result<()>
where
    EU: Transport<Up>,
    ED: Transport<Down>,
    BU: Transport<()>,
    BD: Transport<()>,
{
    bport.barrier()?;
    let w = ctx.worker;
    if world.cfg.train.trace {
        crate::obs::thread_register(w as u32, "worker");
    }
    // One snapshot chain per epoch, matching the leader's per-epoch
    // diff chain (the epoch's first frame is always full).
    let mut chain = SnapshotChain::new();
    let cache_base = crate::obs::cache_obs_base(ctx.cache.as_ref());
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let layers = cfg.model.layers;
    let parts = part.num_parts;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[w];
    // One arena serves every batch (the fused step has no backward to
    // keep staging alive for).
    let mut arena = BatchArena::new();
    // Per-thread dedup-frontier scratch; `spare` lets one frontier
    // allocation ping-pong with the double-buffered prefetch.
    let mut spare: Option<Frontier> = None;
    let mut prefetched: Option<(TreeSample, Option<Frontier>, f64)> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        cur.store(bi, Ordering::Relaxed);
        crate::obs::set_batch(bi as u64);
        port.maybe_fault(&cfg.train, epoch, bi)?;
        let (rbi, snapshot) = recv_ready(port, world, &mut chain)?;
        if rbi != bi {
            bail!("worker {w}: release for batch {rbi} arrived while expecting {bi}");
        }
        let micro = &chunk[w * vb..(w + 1) * vb];
        let batch_seed = cfg.train.batch_seed(epoch, bi);

        // -- sampling over the whole graph: remote hops are RPCs --
        let (sample, frontier, mut sample_s) = match prefetched.take() {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let s = sample_tree(
                    world.g,
                    world.tree,
                    &cfg.model.fanouts,
                    micro,
                    w * vb,
                    batch_seed,
                    |_| true,
                );
                let fr = cfg
                    .train
                    .dedup_fetch
                    .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
                (s, fr, t0.elapsed().as_secs_f64() * scale)
            }
        };
        let rstats = remote_counts(world.tree, &sample, part, w);
        // Remote neighbor lookups: id traffic + one RPC per hop per
        // remote machine; the byte count ships up for the leader-owned
        // ledger.
        sample_s += cfg.cost.xfer_time_msgs(
            Lane::Net,
            rstats.remote * 8,
            (layers * (parts - 1)).max(1) as u64,
        );

        // -- fused marshal + train step on this worker's own context --
        let step = wp.vanilla_step(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            part,
            &sample,
            frontier.as_ref(),
            micro,
            sample_s,
            &mut arena,
        )?;
        port.send(Up::Step {
            bi,
            msg: Box::new(StepMsg {
                loss: step.loss,
                acc: step.acc,
                grads: step.grads,
                stats: step.stats,
                sample_remote_bytes: rstats.remote * 8,
                span: step.span,
                stages: step.stages,
                wall_fwd: step.wall_fwd,
            }),
        })?;
        // This batch's frontier is done; recycle its allocation for the
        // prefetch below (ping-pong, no steady-state allocation).
        if let Some(f) = frontier {
            spare = Some(f);
        }

        // -- double-buffer: prefetch the next microbatch's sample (and
        // its dedup frontier, so the dedup work overlaps the leader
        // phase of batch `bi`) --
        if pipeline && bi + 1 < batches.len() {
            let nseed = cfg.train.batch_seed(epoch, bi + 1);
            let t = Instant::now();
            let s = sample_tree(
                world.g,
                world.tree,
                &cfg.model.fanouts,
                &batches[bi + 1][w * vb..(w + 1) * vb],
                w * vb,
                nseed,
                |_| true,
            );
            let fr = cfg
                .train
                .dedup_fetch
                .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
            prefetched = Some((s, fr, t.elapsed().as_secs_f64() * scale));
        }
    }
    // ---- flight-recorder exchange: publish this rank's cache deltas,
    // then ship the (possibly empty) trace blob leader-ward. Always
    // sent, so the protocol shape is identical tracing on or off. ----
    crate::obs::record_cache_obs(world.g, ctx.cache.as_ref(), cache_base.as_deref());
    port.send(Up::Obs { blob: crate::obs::TraceBlob::collect(w as u32) })?;
    Ok(())
}

/// The windowed (`staleness >= 1`) worker: per release, sample and
/// marshal the batch, announce `Marshaled` (the leader's store
/// barrier), then execute and ship the step results. Releases queue up
/// in the mailbox while the worker grinds, so no separate prefetch is
/// needed — the window itself provides the run-ahead.
#[allow(clippy::too_many_arguments)]
fn worker_run_windowed<EU, ED, BU, BD>(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    part: &NodePartition,
    vb: usize,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<Up, Down, EU, ED>,
    bport: &Port<(), (), BU, BD>,
    cur: &AtomicUsize,
) -> Result<()>
where
    EU: Transport<Up>,
    ED: Transport<Down>,
    BU: Transport<()>,
    BD: Transport<()>,
{
    bport.barrier()?;
    let w = ctx.worker;
    if world.cfg.train.trace {
        crate::obs::thread_register(w as u32, "worker");
    }
    let mut chain = SnapshotChain::new();
    let cache_base = crate::obs::cache_obs_base(ctx.cache.as_ref());
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let layers = cfg.model.layers;
    let parts = part.num_parts;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[w];
    let mut arena = BatchArena::new();
    let mut spare: Option<Frontier> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        cur.store(bi, Ordering::Relaxed);
        crate::obs::set_batch(bi as u64);
        port.maybe_fault(&cfg.train, epoch, bi)?;
        let (rbi, snapshot) = recv_ready(port, world, &mut chain)?;
        if rbi != bi {
            bail!("worker {w}: release for batch {rbi} arrived while expecting {bi}");
        }
        let micro = &chunk[w * vb..(w + 1) * vb];

        let t0 = Instant::now();
        let sample = sample_tree(
            world.g,
            world.tree,
            &cfg.model.fanouts,
            micro,
            w * vb,
            cfg.train.batch_seed(epoch, bi),
            |_| true,
        );
        let frontier = cfg
            .train
            .dedup_fetch
            .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &sample, ntypes, wp.needs_root));
        let mut sample_s = t0.elapsed().as_secs_f64() * scale;
        let rstats = remote_counts(world.tree, &sample, part, w);
        sample_s += cfg.cost.xfer_time_msgs(
            Lane::Net,
            rstats.remote * 8,
            (layers * (parts - 1)).max(1) as u64,
        );

        // Marshal, announce the store barrier, then execute — one
        // shared-session token brackets both halves, like the fused
        // synchronous stage.
        let step = {
            let _token = world.serialize();
            let m = wp.vanilla_marshal(
                ctx,
                world,
                ParamsView::Snapshot(&snapshot),
                part,
                &sample,
                frontier.as_ref(),
                micro,
                &mut arena,
            )?;
            port.send(Up::Marshaled { bi })?;
            wp.vanilla_execute(ctx, world, m, part, &sample, micro, sample_s, snapshot.version)?
        };
        port.send(Up::Step {
            bi,
            msg: Box::new(StepMsg {
                loss: step.loss,
                acc: step.acc,
                grads: step.grads,
                stats: step.stats,
                sample_remote_bytes: rstats.remote * 8,
                span: step.span,
                stages: step.stages,
                wall_fwd: step.wall_fwd,
            }),
        })?;
        if let Some(f) = frontier {
            spare = Some(f);
        }
    }
    // ---- flight-recorder exchange (see `worker_run_sync`) ----
    crate::obs::record_cache_obs(world.g, ctx.cache.as_ref(), cache_base.as_deref());
    port.send(Up::Obs { blob: crate::obs::TraceBlob::collect(w as u32) })?;
    Ok(())
}

/// Build batch `bi`'s release from the leader's diff chain: the full
/// snapshot when the chain is disabled or starting, else the
/// version-chained delta. Returns the store version the release
/// carries — identical in both modes, so `ready_versions` (which pins
/// every gradient fold) never depends on the wire format.
fn ready_release(chain: &mut DiffChain, params: &ParamStore, bi: usize) -> (u64, Down) {
    match chain.next(params) {
        SnapOrDiff::Full(snap) => {
            let v = snap.version;
            (v, Down::Ready { bi, params: snap })
        }
        SnapOrDiff::Diff(diff) => (diff.to_version, Down::ReadyDiff { bi, diff }),
    }
}

#[allow(clippy::too_many_arguments)]
fn leader_loop<EU, ED, BU, BD>(
    mut hub: Hub<Up, Down, EU, ED>,
    bhub: Hub<(), (), BU, BD>,
    world: &EpochWorld<'_>,
    params: &mut crate::runtime::ParamStore,
    adam_t: &mut i32,
    parts: usize,
    vb: usize,
    batches: &[Vec<NodeId>],
    pipeline: bool,
    staleness: usize,
    replicate: bool,
) -> Result<EpochReport>
where
    EU: Transport<Up>,
    ED: Transport<Down>,
    BU: Transport<()>,
    BD: Transport<()>,
{
    bhub.barrier()?;
    if world.cfg.train.trace {
        // The leader's rank id is `parts` — one past the worker ranks.
        crate::obs::thread_register(parts as u32, "leader");
    }
    let n = batches.len();
    let mut net = SimNet::new(parts, world.cfg.cost.clone());
    let mut timeline = EpochTimeline::new(parts);
    let mut stages = StageTimes::default();
    let mut worker_stages = vec![StageTimes::default(); parts];
    let mut wall = WallClock::new(parts);
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut batch_losses = Vec::with_capacity(n);
    let mut batches_done = 0usize;
    let mut fetch = FetchStats::default();

    // Prime the release window (k = 0 opens batch 0 only; a k-window
    // opens k batches — batch j's snapshot trails by j <= k updates),
    // recording each released snapshot's version: the fold of batch
    // bi's gradients is pinned to ready_versions[bi].
    // One diff chain per epoch (PR 8, `wire_snapshots = diff`): its
    // first frame is always a full snapshot, which also covers the
    // post-recovery restart — recovery re-enters this loop.
    let mut chain = DiffChain::new(world.cfg.train.wire_snapshots.is_diff());
    let mut ready_versions: Vec<u64> = Vec::with_capacity(n);
    let mut released = 0usize;
    for _ in 0..staleness.max(1).min(n) {
        // Consecutive primes see an unchanged store, so in diff mode
        // every prime after the first is an empty (from == to) diff.
        let (ver, msg) = ready_release(&mut chain, params, released);
        ready_versions.push(ver);
        hub.broadcast(msg)?;
        released += 1;
    }
    // Count of batches whose `Marshaled` barrier notice has been
    // consumed (windowed schedule only).
    let mut marshal_gathered = 0usize;

    for bi in 0..n {
        crate::obs::set_batch(bi as u64);
        let msgs = hub
            .gather_round(step_round(bi), up_tag)
            .with_context(|| format!("batch {bi}: collecting step results"))?;
        crate::obs::gauge_max("staleness.open", (released - bi) as f64);
        crate::obs::hist_observe(
            "grad.version_lag",
            params.version().saturating_sub(ready_versions[bi]) as f64,
        );
        let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
        let mut gacc = GradAccumulator::for_version(ready_versions[bi]);
        let mut batch_loss = 0.0f64;
        for (wid, up) in msgs.into_iter().enumerate() {
            let m = match up {
                Up::Step { bi: ubi, msg } => {
                    if ubi != bi {
                        bail!("protocol error: batch {ubi} step results in batch {bi}'s round");
                    }
                    msg
                }
                Up::Marshaled { bi: ubi } => {
                    bail!("protocol error: batch {ubi} marshal notice in batch {bi}'s step round")
                }
                Up::Failed { bi: fbi, msg } => bail!(
                    "batch {fbi} death notice escaped gather_round's abort path \
                     (protocol bug): {msg}"
                ),
                Up::Obs { .. } => {
                    bail!("protocol error: trace blob in batch {bi}'s step round")
                }
                Up::NeedFull { bi: nbi, have, want } => bail!(
                    "batch {nbi}: worker {wid}'s resync NACK escaped gather_round's \
                     abort path (protocol bug): worker {wid} {}",
                    need_full_msg(have, want)
                ),
            };
            let StepMsg {
                loss,
                acc,
                grads,
                stats,
                sample_remote_bytes,
                span,
                stages: wstages,
                wall_fwd,
            } = *m;
            // Charge the worker's remote traffic to its ledger — same
            // calls, same totals as the sequential engine.
            net.charge(wid, Lane::Net, sample_remote_bytes, 0.0)?;
            net.charge(wid, Lane::Net, stats.remote_bytes, 0.0)?;
            batch_loss += loss / parts as f64;
            acc_sum += acc;
            gacc.absorb(grads)
                .with_context(|| format!("batch {bi}, worker {wid}"))?;
            fetch.merge(stats);
            worker_spans.push(span);
            stages.merge(&wstages);
            worker_stages[wid].merge(&wstages);
            wall.record_forward(wid, wall_fwd);
        }
        loss_sum += batch_loss;
        batch_losses.push(batch_loss);

        // -- async release: batch bi+k goes out before this batch's
        // update, bounding its forward snapshot at k missing updates --
        if staleness >= 1 && released < n {
            let (ver, msg) = ready_release(&mut chain, params, released);
            ready_versions.push(ver);
            hub.broadcast(msg)?;
            released += 1;
        }
        // -- store barrier: before the update may write learnable rows,
        // every released batch must have finished marshalling (its
        // feature reads then deterministically precede this write) --
        if staleness >= 1 {
            while marshal_gathered < released {
                let mbi = marshal_gathered;
                hub.gather_round(marshal_round(mbi), up_tag)
                    .with_context(|| format!("batch {mbi}: store-barrier marshal notices"))?;
                marshal_gathered += 1;
            }
        }

        // -- all-reduce + model + learnable updates (shared stage) --
        let touched = if replicate { gacc.touched_rows() } else { Vec::new() };
        let upd = vanilla_apply_updates(world, params, adam_t, gacc, &mut net, parts)?;
        stages.add(Stage::GradSync, upd.allreduce_s);
        stages.add(Stage::Update, upd.update_s + upd.lf_s);
        // -- TCP only: replicate this update's learnable-row writes
        // into every worker process's store, before any later release
        // (per-lane FIFO then reproduces the shared-store visibility
        // order the `Marshaled` barrier pinned) --
        if replicate {
            let delta = {
                let store = world.store();
                StoreDelta::capture(&store, touched.iter().map(|(ty, ids)| (*ty, ids.as_slice())))
                    .with_context(|| format!("batch {bi}: capturing the learnable-row delta"))?
            };
            if !delta.is_empty() {
                hub.broadcast(Down::Store { bi, delta })?;
            }
        }

        timeline.push_batch(
            worker_spans,
            LeaderSpan {
                gather_s: upd.allreduce_s,
                leader_s: 0.0,
                scatter_s: 0.0,
                update_s: upd.update_s + upd.lf_s,
                sync_s: 0.0,
            },
        );
        batches_done += 1;
        // -- synchronous release: batch bi+1 waits for this update --
        if staleness == 0 && released < n {
            let (ver, msg) = ready_release(&mut chain, params, released);
            ready_versions.push(ver);
            hub.broadcast(msg)?;
            released += 1;
        }
    }

    // ---- flight-recorder exchange: every worker's last Up message is
    // its trace blob (empty when tracing is off — the gather happens
    // either way, keeping the protocol shape independent of the
    // flag). Merge them with the leader's own collection. ----
    let mut obs = crate::obs::ObsReport::default();
    for up in hub
        .gather_round(OBS_ROUND, up_tag)
        .context("collecting worker trace blobs")?
    {
        match up {
            Up::Obs { blob } => blob.merge_into(&mut obs),
            other => bail!("protocol error: {other:?} in the trace-blob round"),
        }
    }
    crate::obs::TraceBlob::collect(parts as u32).merge_into(&mut obs);

    let epoch_time_s = timeline.sequential_time();
    let critical_path_s = if staleness >= 1 {
        timeline.async_pipelined_time(staleness, AsyncShape::Vanilla)
    } else if pipeline {
        timeline.pipelined_time()
    } else {
        epoch_time_s
    };
    Ok(EpochReport {
        epoch_time_s,
        critical_path_s,
        worker_busy_s: timeline.worker_busy_s(),
        worker_stages,
        wall,
        stages,
        comm: net.total(),
        fetch,
        wire: Default::default(), // the in-process transports move no frames
        loss_mean: if batches_done > 0 {
            loss_sum / batches_done as f64
        } else {
            f64::NAN
        },
        accuracy: if batches_done > 0 {
            acc_sum / (batches_done * vb * parts) as f64
        } else {
            f64::NAN
        },
        batches: batches_done,
        batch_losses,
        obs,
    })
}

/// One process's typed socket lanes for this engine's protocol — the
/// shared [`Lanes`](super::Lanes) bundle instantiated with the
/// engine's private message enums. Opened once per training run and
/// reused across epochs.
pub struct TcpLanes(super::Lanes<Up, Down>);

impl TcpLanes {
    pub fn open(node: &TcpNode, parts: usize) -> Result<TcpLanes> {
        Ok(TcpLanes(super::Lanes::open(node, parts)?))
    }
}

/// Run one vanilla epoch of a **multi-process** cluster: this process
/// plays exactly the rank its [`TcpLanes`] were opened for over the
/// socket star. Worker ranks return an empty report (plus their wire
/// traffic); the leader's report carries the losses and is
/// byte-identical to the in-process channel transport at any fixed
/// staleness.
#[allow(clippy::too_many_arguments)]
pub fn run_epoch_tcp(
    plan: &BatchPlan,
    contexts: &mut [ExecContext],
    part: &NodePartition,
    gate: Option<&ExecGate>,
    sess: &mut Session,
    epoch: usize,
    lanes: &TcpLanes,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = part.num_parts;
    let vb = (cfg.train.batch_size / parts).max(1);
    let pipeline = cfg.train.pipeline;
    let staleness = if pipeline { cfg.train.staleness } else { 0 };
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);
    let batches = batch_schedule(&g, &cfg, parts, epoch);
    if batches.is_empty() {
        // Every rank computes the same empty schedule and skips the
        // epoch without touching the wire.
        return Ok(EpochReport::empty(parts));
    }
    let world = EpochWorld {
        cfg: &cfg,
        g: &g,
        tree: &tree,
        store: &sess.store,
        gate,
        epoch_t0: Instant::now(),
    };
    let wire0 = lanes.0.traffic();

    match lanes.0.role {
        Role::Leader => {
            let hub = Hub::from_endpoints(&lanes.0.up, &lanes.0.down, parts);
            let bhub = Hub::from_endpoints(&lanes.0.bar_up, &lanes.0.bar_down, parts);
            let mut rep = leader_loop(
                hub,
                bhub,
                &world,
                &mut sess.params,
                &mut sess.adam_t,
                parts,
                vb,
                &batches,
                pipeline,
                staleness,
                true, // every worker process owns a store replica
            )?;
            rep.wire = lanes.0.traffic().since(&wire0);
            Ok(rep)
        }
        Role::Worker(w) => {
            let ctx = contexts
                .get_mut(w)
                .ok_or_else(|| anyhow!("worker rank {w} outside the {parts}-partition plan"))?;
            let port = Port::from_endpoints(&lanes.0.up, &lanes.0.down, parts);
            let bport = Port::from_endpoints(&lanes.0.bar_up, &lanes.0.bar_down, parts);
            worker_loop(
                ctx, plan, &world, part, vb, epoch, &batches, &port, &bport, pipeline, staleness,
            )?;
            let mut rep = EpochReport::empty(parts);
            rep.wire = lanes.0.traffic().since(&wire0);
            Ok(rep)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::codec::{decode_message, encode_message};

    fn step_fixture() -> Box<StepMsg> {
        Box::new(StepMsg {
            loss: 0.693,
            acc: 12.0,
            grads: WorkerGrads {
                wgrads: vec![("w".into(), vec![0.5, 0.25])],
                row_grads: vec![(2, vec![1, 1, 8], vec![0.1; 6])],
                gx: vec![],
                learnable_rows: vec![(2, 3, 1)],
                param_version: 5,
            },
            stats: FetchStats { rows: 9, bytes: 144, remote_rows: 2, remote_bytes: 32 },
            sample_remote_bytes: 88,
            span: WorkerSpan { sample_s: 0.5, fetch_lr_s: 0.25, ..Default::default() },
            stages: StageTimes { secs: [0.1; 7] },
            wall_fwd: (3.0, 4.5),
        })
    }

    #[test]
    fn vanilla_up_messages_round_trip() {
        let msgs = [
            Up::Marshaled { bi: 6 },
            Up::Step { bi: 2, msg: step_fixture() },
            Up::Failed { bi: usize::MAX, msg: "before its first batch".into() },
            // `have = u64::MAX` is the no-snapshot-yet sentinel.
            Up::NeedFull { bi: 4, have: u64::MAX, want: 8 },
            Up::NeedFull { bi: 5, have: 6, want: 8 },
            Up::Obs {
                blob: crate::obs::TraceBlob {
                    rank: 0,
                    tracks: Vec::new(),
                    metrics: crate::obs::MetricsSnapshot {
                        gauges: vec![("staleness.open".into(), 2.0)],
                        ..Default::default()
                    },
                },
            },
        ];
        for m in msgs {
            let bytes = encode_message(&m);
            let back: Up = decode_message(&bytes).unwrap();
            assert_eq!(back, m);
            assert_eq!(m.wire_bytes(), 0, "vanilla up-traffic is modeled by the all-reduce");
        }
    }

    #[test]
    fn vanilla_down_messages_round_trip() {
        let params = Arc::new(ParamSnapshot::from_tensors(
            3,
            vec![("dense".into(), vec![0.0, 1.0, -1.0])],
        ));
        let msgs = [
            Down::Ready { bi: 1, params },
            Down::Store {
                bi: 0,
                delta: StoreDelta { rows: vec![(0, vec![2], vec![9.0, 9.5])] },
            },
            Down::ReadyDiff {
                bi: 2,
                diff: ParamDiff::from_tensors(3, 5, vec![("dense".into(), vec![0.5, -0.0])]),
            },
        ];
        for m in msgs {
            let bytes = encode_message(&m);
            let back: Down = decode_message(&bytes).unwrap();
            assert_eq!(back, m);
        }
    }

    #[test]
    fn vanilla_corrupt_frames_are_rejected() {
        let mut bytes = encode_message(&Up::Marshaled { bi: 3 });
        bytes[0] = 0x7E;
        assert!(decode_message::<Up>(&bytes).is_err(), "unknown tag rejected");
        let bytes = encode_message(&Up::Step { bi: 2, msg: step_fixture() });
        for cut in 0..bytes.len() {
            assert!(
                decode_message::<Up>(&bytes[..cut]).is_err(),
                "truncation at {cut} must error, not panic"
            );
        }
    }
}
