//! The vanilla (DGL/GraphLearn-style) engine on the cluster runtime.
//!
//! Data parallelism: each worker thread samples the full k-hop tree for
//! its microbatch and runs the fused `vanilla` train-step artifact on
//! its **own** execution context — concurrently with every other
//! worker; the leader prices the ring all-reduce, applies the mean
//! gradients and the sparse learnable-feature updates, then releases
//! the next batch with a fresh parameter snapshot. With
//! `train.pipeline` on, workers prefetch batch `i+1`'s sample while the
//! leader runs batch `i`'s all-reduce + update phase.
//!
//! The runtime is lock-free: workers charge nothing to shared ledgers —
//! they ship their remote-byte counts up with the step results, and the
//! leader (the only owner of the [`SimNet`]) charges them in worker-id
//! order, exactly matching the sequential engine's totals.
//!
//! As with the RAF port, every reduction folds in (worker, output)
//! order, so losses and parameter trajectories are byte-identical to
//! the sequential vanilla engine.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::comm::{Lane, SimNet};
use crate::config::Config;
use crate::coordinator::common::Session;
use crate::exec::plan::vanilla_apply_updates;
use crate::exec::{BatchPlan, EpochWorld, ExecContext, ExecGate, GradAccumulator, ParamsView};
use crate::hetgraph::NodeId;
use crate::kvstore::FetchStats;
use crate::metrics::timeline::{EpochTimeline, LeaderSpan, WallClock, WorkerSpan};
use crate::metrics::{EpochReport, Stage, StageTimes};
use crate::partition::NodePartition;
use crate::runtime::ParamSnapshot;
use crate::sampling::{remote_counts, sample_tree, Frontier, TreeSample};
use crate::util::rng::Rng;

use super::collective::{star, Hub, Port};
use super::mailbox::Wire;

/// Worker → leader message: one fused train step's results.
struct StepMsg {
    loss: f64,
    acc: f64,
    /// Unreduced gradient outputs (leader folds in worker order).
    grads: crate::exec::WorkerGrads,
    /// KV-store fetch accounting of this worker's input build (unique
    /// rows per batch when dedup gather is on; `remote_bytes` is what
    /// the leader charges to this worker's network ledger).
    stats: FetchStats,
    /// Remote-neighbor-lookup id traffic of the sampling stage, charged
    /// by the leader (workers own no ledgers — the runtime is lock-free).
    sample_remote_bytes: u64,
    span: WorkerSpan,
    stages: StageTimes,
    wall_fwd: (f64, f64),
}

impl Wire for StepMsg {
    fn wire_bytes(&self) -> u64 {
        // Dense gradients move via the ring all-reduce the leader
        // charges to every worker ledger (the modeled system never
        // ships raw per-worker grads to a coordinator).
        0
    }
}

/// `Err` is a worker's best-effort death notice: without it a leader
/// gathering from a dead worker would block forever while live workers
/// keep the channel connected.
type StepResult = std::result::Result<StepMsg, String>;

/// Batch release carrying the post-update parameter snapshot every
/// replica applies identically (data parallelism); snapshot
/// distribution is an in-process artifact of the single-machine
/// harness — the all-reduce already priced the gradient exchange.
#[derive(Clone)]
struct ReadyMsg {
    params: Arc<ParamSnapshot>,
}

impl Wire for ReadyMsg {
    fn wire_bytes(&self) -> u64 {
        0
    }
}

/// Run one vanilla epoch on the cluster runtime.
pub fn run_epoch(
    plan: &BatchPlan,
    contexts: &mut [ExecContext],
    part: &NodePartition,
    gate: Option<&ExecGate>,
    sess: &mut Session,
    epoch: usize,
) -> Result<EpochReport> {
    let cfg = sess.cfg.clone();
    let parts = part.num_parts;
    let b = cfg.train.batch_size;
    let vb = (b / parts).max(1);
    let pipeline = cfg.train.pipeline;
    let g = Arc::clone(&sess.g);
    let tree = Arc::clone(&sess.tree);

    let mut train = sess.g.train_nodes();
    let mut shuffle_rng = Rng::new(cfg.train.shuffle_seed(epoch));
    shuffle_rng.shuffle(&mut train);
    let mut batches: Vec<Vec<NodeId>> = Vec::new();
    for c in train.chunks(b) {
        if c.len() < vb * parts {
            break;
        }
        batches.push(c.to_vec());
    }
    if batches.is_empty() {
        // Nothing to release: spawning workers would race the initial
        // Ready broadcast against their immediate teardown.
        return Ok(EpochReport::empty(parts));
    }

    let world = EpochWorld {
        cfg: &cfg,
        g: &g,
        tree: &tree,
        store: &sess.store,
        gate,
        epoch_t0: Instant::now(),
    };
    let params = &mut sess.params;
    let adam_t = &mut sess.adam_t;

    let (hub, ports) = star::<StepResult, ReadyMsg>(parts);
    let (bhub, bports) = star::<(), ()>(parts);

    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(parts);
        for ((ctx, port), bport) in contexts.iter_mut().zip(ports).zip(bports) {
            let world = &world;
            let batches = &batches;
            handles.push(s.spawn(move || {
                worker_loop(
                    ctx, plan, world, part, vb, epoch, batches, &port, &bport, pipeline,
                )
            }));
        }
        let led = leader_loop(
            hub, bhub, &world, params, adam_t, parts, vb, &batches, pipeline,
        );
        let mut worker_err: Option<anyhow::Error> = None;
        for h in handles {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if worker_err.is_none() {
                        worker_err = Some(e);
                    }
                }
                Err(_) => {
                    if worker_err.is_none() {
                        worker_err = Some(anyhow!("worker thread panicked"));
                    }
                }
            }
        }
        // The leader's error already embeds worker root causes (via
        // the `Err` death notice), so it wins; worker errors cover the
        // remainder.
        match (led, worker_err) {
            (Ok(rep), None) => Ok(rep),
            (Err(e), _) => Err(e),
            (Ok(_), Some(we)) => Err(we),
        }
    })
}

/// Runs the worker body; on error, ships a best-effort death notice so
/// the leader's gather fails fast instead of blocking on a dead peer.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    part: &NodePartition,
    vb: usize,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<StepResult, ReadyMsg>,
    bport: &Port<(), ()>,
    pipeline: bool,
) -> Result<()> {
    // Contain panics too: a panicked worker that never notified the
    // leader would leave the gather blocked while live peers keep the
    // channel connected.
    let w = ctx.worker;
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        worker_run(ctx, plan, world, part, vb, epoch, batches, port, bport, pipeline)
    }));
    let r = caught.unwrap_or_else(|_| Err(anyhow!("worker {w} panicked")));
    if let Err(e) = &r {
        let _ = port.send(Err(format!("{e:#}")));
    }
    r
}

#[allow(clippy::too_many_arguments)]
fn worker_run(
    ctx: &mut ExecContext,
    plan: &BatchPlan,
    world: &EpochWorld<'_>,
    part: &NodePartition,
    vb: usize,
    epoch: usize,
    batches: &[Vec<NodeId>],
    port: &Port<StepResult, ReadyMsg>,
    bport: &Port<(), ()>,
    pipeline: bool,
) -> Result<()> {
    bport.barrier()?;
    let w = ctx.worker;
    let cfg: &Config = world.cfg;
    let scale = cfg.cost.compute_scale;
    let layers = cfg.model.layers;
    let parts = part.num_parts;
    let ntypes = world.g.schema.node_types.len();
    let wp = &plan.workers[w];
    // Per-thread dedup-frontier scratch; `spare` lets one frontier
    // allocation ping-pong with the double-buffered prefetch.
    let mut spare: Option<Frontier> = None;
    let mut prefetched: Option<(TreeSample, Option<Frontier>, f64)> = None;

    for (bi, chunk) in batches.iter().enumerate() {
        let snapshot = port.recv()?.params;
        let micro = &chunk[w * vb..(w + 1) * vb];
        let batch_seed = cfg.train.batch_seed(epoch, bi);

        // -- sampling over the whole graph: remote hops are RPCs --
        let (sample, frontier, mut sample_s) = match prefetched.take() {
            Some(s) => s,
            None => {
                let t0 = Instant::now();
                let s = sample_tree(
                    world.g,
                    world.tree,
                    &cfg.model.fanouts,
                    micro,
                    w * vb,
                    batch_seed,
                    |_| true,
                );
                let fr = cfg
                    .train
                    .dedup_fetch
                    .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
                (s, fr, t0.elapsed().as_secs_f64() * scale)
            }
        };
        let rstats = remote_counts(world.tree, &sample, part, w);
        // Remote neighbor lookups: id traffic + one RPC per hop per
        // remote machine; the byte count ships up for the leader-owned
        // ledger.
        sample_s += cfg.cost.xfer_time_msgs(
            Lane::Net,
            rstats.remote * 8,
            (layers * (parts - 1)).max(1) as u64,
        );

        // -- fused marshal + train step on this worker's own context --
        let step = wp.vanilla_step(
            ctx,
            world,
            ParamsView::Snapshot(&snapshot),
            part,
            &sample,
            frontier.as_ref(),
            micro,
            sample_s,
        )?;
        port.send(Ok(StepMsg {
            loss: step.loss,
            acc: step.acc,
            grads: step.grads,
            stats: step.stats,
            sample_remote_bytes: rstats.remote * 8,
            span: step.span,
            stages: step.stages,
            wall_fwd: step.wall_fwd,
        }))?;
        // This batch's frontier is done; recycle its allocation for the
        // prefetch below (ping-pong, no steady-state allocation).
        if let Some(f) = frontier {
            spare = Some(f);
        }

        // -- double-buffer: prefetch the next microbatch's sample (and
        // its dedup frontier, so the dedup work overlaps the leader
        // phase of batch `bi`) --
        if pipeline && bi + 1 < batches.len() {
            let nseed = cfg.train.batch_seed(epoch, bi + 1);
            let t = Instant::now();
            let s = sample_tree(
                world.g,
                world.tree,
                &cfg.model.fanouts,
                &batches[bi + 1][w * vb..(w + 1) * vb],
                w * vb,
                nseed,
                |_| true,
            );
            let fr = cfg
                .train
                .dedup_fetch
                .then(|| Frontier::take_rebuilt(&mut spare, world.tree, &s, ntypes, wp.needs_root));
            prefetched = Some((s, fr, t.elapsed().as_secs_f64() * scale));
        }
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn leader_loop(
    hub: Hub<StepResult, ReadyMsg>,
    bhub: Hub<(), ()>,
    world: &EpochWorld<'_>,
    params: &mut crate::runtime::ParamStore,
    adam_t: &mut i32,
    parts: usize,
    vb: usize,
    batches: &[Vec<NodeId>],
    pipeline: bool,
) -> Result<EpochReport> {
    bhub.barrier()?;
    let mut net = SimNet::new(parts, world.cfg.cost.clone());
    let mut timeline = EpochTimeline::new(parts);
    let mut stages = StageTimes::default();
    let mut worker_stages = vec![StageTimes::default(); parts];
    let mut wall = WallClock::new(parts);
    let mut loss_sum = 0.0f64;
    let mut acc_sum = 0.0f64;
    let mut batches_done = 0usize;
    let mut fetch = FetchStats::default();

    // Release batch 0 with the initial weights.
    hub.broadcast(ReadyMsg {
        params: Arc::new(params.snapshot()),
    })?;

    for bi in 0..batches.len() {
        let msgs = hub.gather()?;
        let mut worker_spans: Vec<WorkerSpan> = Vec::with_capacity(parts);
        let mut gacc = GradAccumulator::default();
        for (wid, m) in msgs.into_iter().enumerate() {
            let m = match m {
                Ok(m) => m,
                Err(e) => bail!("worker {wid} failed: {e}"),
            };
            // Charge the worker's remote traffic to its ledger — same
            // calls, same totals as the sequential engine.
            net.charge(wid, Lane::Net, m.sample_remote_bytes, 0.0)?;
            net.charge(wid, Lane::Net, m.stats.remote_bytes, 0.0)?;
            loss_sum += m.loss / parts as f64;
            acc_sum += m.acc;
            gacc.absorb(m.grads);
            fetch.merge(m.stats);
            worker_spans.push(m.span);
            stages.merge(&m.stages);
            worker_stages[wid].merge(&m.stages);
            wall.record_forward(wid, m.wall_fwd);
        }

        // -- all-reduce + model + learnable updates (shared stage) --
        let upd = vanilla_apply_updates(world, params, adam_t, gacc, &mut net, parts)?;
        stages.add(Stage::GradSync, upd.allreduce_s);
        stages.add(Stage::Update, upd.update_s + upd.lf_s);

        timeline.push_batch(
            worker_spans,
            LeaderSpan {
                gather_s: upd.allreduce_s,
                leader_s: 0.0,
                scatter_s: 0.0,
                update_s: upd.update_s + upd.lf_s,
                sync_s: 0.0,
            },
        );
        batches_done += 1;
        if bi + 1 < batches.len() {
            hub.broadcast(ReadyMsg {
                params: Arc::new(params.snapshot()),
            })?;
        }
    }

    let epoch_time_s = timeline.sequential_time();
    let critical_path_s = if pipeline {
        timeline.pipelined_time()
    } else {
        epoch_time_s
    };
    Ok(EpochReport {
        epoch_time_s,
        critical_path_s,
        worker_busy_s: timeline.worker_busy_s(),
        worker_stages,
        wall,
        stages,
        comm: net.total(),
        fetch,
        loss_mean: if batches_done > 0 {
            loss_sum / batches_done as f64
        } else {
            f64::NAN
        },
        accuracy: if batches_done > 0 {
            acc_sum / (batches_done * vb * parts) as f64
        } else {
            f64::NAN
        },
        batches: batches_done,
    })
}
