//! Configuration system: experiment configs (`configs/*.json`) shared by
//! the Rust coordinator and the Python AOT compile path, plus the
//! **artifact plan** — the Rust-emitted JSON contract
//! (`artifacts/<config>/plan.json`) that tells `python/compile/aot.py`
//! exactly which padded block shapes, partitions and model dimensions to
//! lower. Rust owns all schema/partitioning logic; Python owns all model
//! math; the plan is the only interface between them.

use anyhow::{bail, Context, Result};

use crate::comm::CostModel;
use crate::datagen::{GenParams, Preset};
use crate::hetgraph::{HetGraph, MetaTree};
use crate::partition::MetaPartition;
use crate::util::json::{parse, Json};

/// Model architecture (paper §8.1: R-GCN, R-GAT, HGT).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    RGcn,
    RGat,
    Hgt,
}

impl Arch {
    pub fn parse(s: &str) -> Option<Arch> {
        match s {
            "rgcn" | "r-gcn" => Some(Arch::RGcn),
            "rgat" | "r-gat" => Some(Arch::RGat),
            "hgt" => Some(Arch::Hgt),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Arch::RGcn => "rgcn",
            Arch::RGat => "rgat",
            Arch::Hgt => "hgt",
        }
    }
}

#[derive(Debug, Clone)]
pub struct DatasetConfig {
    pub preset: Preset,
    pub scale: f64,
    pub gen: GenParams,
}

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub arch: Arch,
    pub hidden: usize,
    pub layers: usize,
    pub fanouts: Vec<usize>,
    pub heads: usize,
}

/// Which worker runtime executes an epoch (`train.runtime` in configs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuntimeKind {
    /// One thread plays every worker in sequence (the seed behaviour);
    /// kept for A/B against the cluster runtime.
    Sequential,
    /// Thread-per-partition cluster runtime (`crate::cluster`): typed
    /// mailbox transport, channel collectives, and the double-buffered
    /// minibatch pipeline.
    Cluster,
}

impl RuntimeKind {
    pub fn parse(s: &str) -> Option<RuntimeKind> {
        match s {
            "sequential" | "seq" => Some(RuntimeKind::Sequential),
            "cluster" | "threads" => Some(RuntimeKind::Cluster),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuntimeKind::Sequential => "sequential",
            RuntimeKind::Cluster => "cluster",
        }
    }
}

/// Which transport the cluster runtime rides on (`train.transport`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process channels: every rank is a thread of one process (the
    /// default).
    Channel,
    /// The socket star of `crate::net::tcp`: one OS process per rank.
    /// Per-process identity (`--rank`, `--peers`) comes from the CLI —
    /// the config only selects the transport, since every process
    /// shares one config file.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "channel" | "channels" | "thread" => Some(TransportKind::Channel),
            "tcp" | "socket" => Some(TransportKind::Tcp),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// How the leader ships parameters down the Ready/Grads lane
/// (`train.wire_snapshots`, PR 8). Either way workers reconstruct the
/// **bit-identical** snapshot, so losses never depend on this knob —
/// only the bytes on the wire do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireSnapshots {
    /// Every release carries the complete parameter snapshot — the
    /// pre-PR-8 behaviour, kept for A/B byte accounting.
    Full,
    /// Version-chained deltas (the default): after an epoch's first
    /// full snapshot, each release carries only the tensors that
    /// advanced since the previous one
    /// ([`crate::runtime::ParamDiff`]). A chain break is an error that
    /// aborts the epoch; the restarted epoch's first frame is full
    /// again — that *is* the resync.
    Diff,
}

impl WireSnapshots {
    pub fn parse(s: &str) -> Option<WireSnapshots> {
        match s {
            "full" | "snapshot" => Some(WireSnapshots::Full),
            "diff" | "delta" => Some(WireSnapshots::Diff),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WireSnapshots::Full => "full",
            WireSnapshots::Diff => "diff",
        }
    }

    pub fn is_diff(&self) -> bool {
        matches!(self, WireSnapshots::Diff)
    }
}

/// How the RAF partial aggregation travels (`train.wire_exchange`,
/// PR 8). Fold order is identical either way (worker-id order starting
/// from zeros), so losses are byte-identical; only which link carries
/// the 2·[B,H] tensors changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireExchange {
    /// Every worker ships its partials up the leader star (the
    /// default, and the pre-PR-8 behaviour).
    Star,
    /// Workers fold partials peer-to-peer along the rank chain
    /// (worker 0 → 1 → … → K−1) on the mesh lane; only the last worker
    /// ships the folded sums to the leader. Under TCP this needs the
    /// mesh-built star (`dial_mesh_with`/`listen_mesh_with`); the
    /// in-process runtime uses a channel mesh. The vanilla engine has
    /// no partial exchange and ignores the knob.
    Mesh,
}

impl WireExchange {
    pub fn parse(s: &str) -> Option<WireExchange> {
        match s {
            "star" | "leader" => Some(WireExchange::Star),
            "mesh" | "p2p" | "peer" => Some(WireExchange::Mesh),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WireExchange::Star => "star",
            WireExchange::Mesh => "mesh",
        }
    }

    pub fn is_mesh(&self) -> bool {
        matches!(self, WireExchange::Mesh)
    }
}

/// What a deterministically injected fault does when it fires
/// (`--fail rank:batch:kind[:epoch]`, see [`FaultSpec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The faulted rank's epoch errors out immediately (process exit
    /// under `heta launch`; an epoch error under the loopback harness).
    Exit,
    /// The faulted rank pauses its heartbeats and wedges past the
    /// leader's timeout, so recovery goes through failure *detection*
    /// rather than a clean error.
    Stall,
    /// The faulted rank shuts down its sockets mid-epoch: both sides
    /// see reader hangups instead of a protocol-level failure.
    DropConn,
    /// The faulted rank bit-flips the body of its next outbound TCP
    /// frame; the receiver's total decode must reject it. The faulted
    /// rank itself keeps running — this exercises the codec path.
    CorruptFrame,
}

impl FaultKind {
    pub fn parse(s: &str) -> Option<FaultKind> {
        match s {
            "exit" => Some(FaultKind::Exit),
            "stall" => Some(FaultKind::Stall),
            "drop-conn" => Some(FaultKind::DropConn),
            "corrupt-frame" => Some(FaultKind::CorruptFrame),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Exit => "exit",
            FaultKind::Stall => "stall",
            FaultKind::DropConn => "drop-conn",
            FaultKind::CorruptFrame => "corrupt-frame",
        }
    }
}

/// One deterministically injected fault: launch rank `rank` (1..=K —
/// workers only; 0 is the leader and not a valid target) misbehaves the
/// first time it reaches batch `batch` of epoch `epoch`. Parsed from
/// `--fail rank:batch:kind[:epoch]`; the epoch field defaults to 0.
/// Faults fire at most once per process, so a respawned rank (which is
/// launched without `--fail`) runs clean.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    pub rank: usize,
    pub batch: usize,
    pub epoch: usize,
    pub kind: FaultKind,
}

impl FaultSpec {
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 && parts.len() != 4 {
            bail!("--fail wants rank:batch:kind[:epoch], got {s:?}");
        }
        let rank: usize = parts[0]
            .parse()
            .with_context(|| format!("--fail rank {:?} is not a number", parts[0]))?;
        if rank == 0 {
            bail!("--fail rank must be a worker rank (1..=K); rank 0 is the leader");
        }
        let batch: usize = parts[1]
            .parse()
            .with_context(|| format!("--fail batch {:?} is not a number", parts[1]))?;
        let kind = FaultKind::parse(parts[2]).with_context(|| {
            format!(
                "--fail kind {:?} is not one of exit|stall|drop-conn|corrupt-frame",
                parts[2]
            )
        })?;
        let epoch: usize = match parts.get(3) {
            Some(e) => e
                .parse()
                .with_context(|| format!("--fail epoch {e:?} is not a number"))?,
            None => 0,
        };
        Ok(FaultSpec {
            rank,
            batch,
            epoch,
            kind,
        })
    }
}

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub batch_size: usize,
    pub lr: f64,
    pub num_partitions: usize,
    pub gpus_per_machine: usize,
    pub cache_bytes_per_gpu: u64,
    pub cache_policy: crate::cache::Policy,
    pub seed: u64,
    /// Worker runtime (`"sequential"` default, `"cluster"` for the
    /// thread-per-partition runtime).
    pub runtime: RuntimeKind,
    /// Double-buffered prefetch in the cluster runtime (default true);
    /// `false` runs the cluster runtime without overlap, isolating the
    /// pipelining gain for A/B benches.
    pub pipeline: bool,
    /// Deduplicated-frontier feature gather (default true): each batch
    /// fetches every distinct node id once into a staging buffer and
    /// scatters padded blocks in memory, with cache hit/miss ledgers
    /// advancing once per unique id. `false` reproduces the seed's
    /// per-slot gather and per-occurrence cache accounting for A/B
    /// comparisons; losses are byte-identical either way.
    pub dedup_fetch: bool,
    /// Escape hatch (default false): serialize every marshal+execute
    /// stage on one token, reproducing the pre-exec-layer behavior
    /// where all artifact executions shared a single session. With the
    /// default per-worker execution contexts, cluster workers execute
    /// their artifacts genuinely concurrently. Losses are byte-identical
    /// either way (reductions fold in worker-id order); only wall-clock
    /// overlap changes — the A/B lever of `benches/exec_overlap.rs`.
    pub shared_session: bool,
    /// Bounded-staleness window of the async 1F1B pipeline (default 0).
    /// `0` is the synchronous protocol: batch `i+1` is released only
    /// after batch `i`'s update, and losses are byte-identical across
    /// every runtime. `k >= 1` lets the cluster runtime keep up to `k`
    /// extra batches in flight: batch `i+k` is released right after
    /// batch `i`'s forward results land, so its marshal+forward runs
    /// against a parameter snapshot missing at most `k` updates while
    /// batch `i`'s backward/update are still in progress. The schedule
    /// stays deterministic (releases and gradient folds keep a fixed
    /// order), but the trajectory legitimately differs from staleness 0
    /// — that is the semantics of bounded-staleness training. Requires
    /// `dedup_fetch` (the backward rebuild reuses the forward's staged
    /// rows; re-gathering per slot would read rows newer than the
    /// forward used). The sequential runtime has no overlap to exploit
    /// and always runs synchronously; with `pipeline = false` the
    /// cluster runtime does too.
    pub staleness: usize,
    /// Transport of the cluster runtime (`"channel"` default,
    /// `"tcp"` for one-process-per-rank socket training — requires
    /// `runtime = "cluster"`; per-process identity comes from the
    /// CLI's `--rank`/`--peers`). Losses are byte-identical across
    /// both transports at any fixed staleness.
    pub transport: TransportKind,
    /// Arm the flight recorder ([`crate::obs`], default false): span
    /// recording in the stage bodies / collectives / TCP readers, the
    /// metrics registry, and epoch-end cross-rank collection into
    /// `EpochReport.obs` (exported by the CLI's `--trace out.json`).
    /// Zero-cost when off; losses are byte-identical either way —
    /// observability is passive.
    pub trace: bool,
    /// Deterministic fault injection (CLI `--fail rank:batch:kind[:epoch]`,
    /// default none): the named worker rank misbehaves the first time it
    /// reaches that batch of that epoch. Test/CI plumbing — never set in
    /// config files, and ignored outside the cluster runtime.
    pub fail: Option<FaultSpec>,
    /// TCP heartbeat send period in milliseconds (workers → leader on
    /// the reserved heartbeat lane; default 500).
    pub hb_interval_ms: u64,
    /// Leader-side heartbeat timeout in milliseconds (default 5000):
    /// a worker silent this long is declared dead and its connection is
    /// shut down, failing the epoch instead of hanging it.
    pub hb_timeout_ms: u64,
    /// Parameter distribution on the down lane (`"diff"` default —
    /// version-chained deltas; `"full"` ships the whole snapshot every
    /// release). See [`WireSnapshots`]; losses are byte-identical
    /// either way.
    pub wire_snapshots: WireSnapshots,
    /// RAF partial-aggregation topology (`"star"` default; `"mesh"`
    /// folds peer-to-peer along the rank chain). See [`WireExchange`];
    /// losses are byte-identical either way.
    pub wire_exchange: WireExchange,
}

impl TrainConfig {
    /// Seed of the epoch-level batch shuffle. Single source of truth:
    /// every runtime (and the determinism tests) must derive the same
    /// batch order for Prop. 1 to hold across runtimes.
    pub fn shuffle_seed(&self, epoch: usize) -> u64 {
        self.seed ^ (epoch as u64) << 32 ^ 0xE9
    }

    /// Per-batch sampling seed — the key of the per-(edge, slot, node)
    /// deterministic RNG contract. Same single-source-of-truth rule.
    pub fn batch_seed(&self, epoch: usize, bi: usize) -> u64 {
        self.seed ^ ((epoch * 7919 + bi) as u64) << 8
    }
}

#[derive(Debug, Clone)]
pub struct Config {
    pub name: String,
    pub dataset: DatasetConfig,
    pub model: ModelConfig,
    pub train: TrainConfig,
    pub cost: CostModel,
}

impl Config {
    pub fn from_json(j: &Json) -> Result<Config> {
        let name = j
            .req("name")?
            .as_str()
            .context("name must be a string")?
            .to_string();
        let d = j.req("dataset")?;
        let preset_name = d.req("preset")?.as_str().context("preset")?;
        let preset =
            Preset::parse(preset_name).with_context(|| format!("unknown preset {preset_name}"))?;
        let dataset = DatasetConfig {
            preset,
            scale: d.req("scale")?.as_f64().context("scale")?,
            gen: GenParams {
                seed: d.get("seed").as_u64().unwrap_or(42),
                avg_degree: d.get("avg_degree").as_f64().unwrap_or(8.0),
                zipf_alpha: d.get("zipf_alpha").as_f64().unwrap_or(1.05),
                train_frac: d.get("train_frac").as_f64().unwrap_or(0.6),
            },
        };
        let m = j.req("model")?;
        let arch_name = m.req("arch")?.as_str().context("arch")?;
        let fanouts: Vec<usize> = m
            .req("fanouts")?
            .as_arr()
            .context("fanouts")?
            .iter()
            .map(|f| f.as_usize().unwrap_or(0))
            .collect();
        let layers = m.get("layers").as_usize().unwrap_or(fanouts.len());
        if layers != fanouts.len() {
            bail!("layers ({layers}) must equal len(fanouts) ({})", fanouts.len());
        }
        let model = ModelConfig {
            arch: Arch::parse(arch_name).with_context(|| format!("unknown arch {arch_name}"))?,
            hidden: m.req("hidden")?.as_usize().context("hidden")?,
            layers,
            fanouts,
            heads: m.get("heads").as_usize().unwrap_or(2),
        };
        let t = j.req("train")?;
        let policy_name = t.get("cache_policy").as_str().unwrap_or("heta").to_string();
        let runtime_name = t.get("runtime").as_str().unwrap_or("sequential").to_string();
        let train = TrainConfig {
            batch_size: t.req("batch_size")?.as_usize().context("batch_size")?,
            lr: t.get("lr").as_f64().unwrap_or(0.01),
            num_partitions: t.get("num_partitions").as_usize().unwrap_or(2),
            gpus_per_machine: t.get("gpus_per_machine").as_usize().unwrap_or(1),
            cache_bytes_per_gpu: t.get("cache_bytes_per_gpu").as_u64().unwrap_or(4 << 20),
            cache_policy: crate::cache::Policy::parse(&policy_name)
                .with_context(|| format!("unknown cache policy {policy_name}"))?,
            seed: t.get("seed").as_u64().unwrap_or(7),
            runtime: RuntimeKind::parse(&runtime_name)
                .with_context(|| format!("unknown runtime {runtime_name}"))?,
            pipeline: t.get("pipeline").as_bool().unwrap_or(true),
            dedup_fetch: t.get("dedup_fetch").as_bool().unwrap_or(true),
            shared_session: t.get("shared_session").as_bool().unwrap_or(false),
            staleness: t.get("staleness").as_usize().unwrap_or(0),
            transport: {
                let name = t.get("transport").as_str().unwrap_or("channel").to_string();
                TransportKind::parse(&name)
                    .with_context(|| format!("unknown transport {name} (channel|tcp)"))?
            },
            trace: t.get("trace").as_bool().unwrap_or(false),
            fail: None,
            hb_interval_ms: t.get("hb_interval_ms").as_u64().unwrap_or(500),
            hb_timeout_ms: t.get("hb_timeout_ms").as_u64().unwrap_or(5000),
            wire_snapshots: {
                let name = t.get("wire_snapshots").as_str().unwrap_or("diff").to_string();
                WireSnapshots::parse(&name)
                    .with_context(|| format!("unknown wire_snapshots {name} (full|diff)"))?
            },
            wire_exchange: {
                let name = t.get("wire_exchange").as_str().unwrap_or("star").to_string();
                WireExchange::parse(&name)
                    .with_context(|| format!("unknown wire_exchange {name} (star|mesh)"))?
            },
        };
        if train.transport == TransportKind::Tcp {
            // Same guard (and wording) every tcp entry point shares.
            crate::net::require_cluster_runtime(train.runtime)?;
        }
        if train.staleness > 0 && !train.dedup_fetch {
            bail!(
                "train.staleness = {} requires train.dedup_fetch: the backward pass \
                 rebuilds its inputs from the forward's staged rows, which is what keeps \
                 it consistent while the window overlaps feature updates",
                train.staleness
            );
        }
        let mut cost = CostModel::default();
        if let Some(c) = j.get("cost").as_obj() {
            if let Some(v) = c.get("net_gbps").and_then(|v| v.as_f64()) {
                cost.bandwidth[0] = v * 1e9 / 8.0;
            }
            if let Some(v) = c.get("pcie_gbs").and_then(|v| v.as_f64()) {
                cost.bandwidth[1] = v * 1e9;
            }
            if let Some(v) = c.get("dram_gbs").and_then(|v| v.as_f64()) {
                cost.bandwidth[2] = v * 1e9;
            }
            if let Some(v) = c.get("p2p_gbs").and_then(|v| v.as_f64()) {
                cost.bandwidth[3] = v * 1e9;
            }
            if let Some(v) = c.get("compute_scale").and_then(|v| v.as_f64()) {
                cost.compute_scale = v;
            }
        }
        Ok(Config {
            name,
            dataset,
            model,
            train,
            cost,
        })
    }

    pub fn load(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Config::from_json(&j)
    }

    /// Generate the dataset this config describes.
    pub fn build_graph(&self) -> HetGraph {
        crate::datagen::generate(self.dataset.preset, self.dataset.scale, &self.dataset.gen)
    }

    /// Per-machine batch for the vanilla data-parallel engine.
    pub fn vanilla_batch(&self) -> usize {
        (self.train.batch_size / self.train.num_partitions).max(1)
    }
}

/// Build the AOT artifact plan for a config: metatree topology, padded
/// block shapes for the RAF batch and the vanilla microbatch, and the
/// relation→partition assignment. Consumed by `python/compile/aot.py`.
pub fn build_plan(
    cfg: &Config,
    g: &HetGraph,
    tree: &MetaTree,
    mp: &MetaPartition,
) -> Json {
    let sizes = crate::sampling::vertex_sizes(tree, &cfg.model.fanouts, cfg.train.batch_size);
    let schema = &g.schema;

    let vertices: Vec<Json> = tree
        .vertices
        .iter()
        .enumerate()
        .map(|(v, vert)| {
            let t = &schema.node_types[vert.ty];
            Json::from_pairs(vec![
                ("id", Json::num(v as f64)),
                ("type", Json::num(vert.ty as f64)),
                ("type_name", Json::str(t.name.clone())),
                ("depth", Json::num(vert.depth as f64)),
                ("size", Json::num(sizes[v] as f64)),
                ("feat_dim", Json::num(t.feat_dim as f64)),
                ("learnable", Json::Bool(t.learnable)),
            ])
        })
        .collect();

    let edges: Vec<Json> = tree
        .edges
        .iter()
        .enumerate()
        .map(|(ei, e)| {
            let rel = &schema.relations[e.rel];
            let d = tree.vertices[e.parent].depth;
            Json::from_pairs(vec![
                ("id", Json::num(ei as f64)),
                ("parent", Json::num(e.parent as f64)),
                ("child", Json::num(e.child as f64)),
                ("depth", Json::num(d as f64)),
                ("rel", Json::num(e.rel as f64)),
                ("rel_name", Json::str(rel.name.clone())),
                ("k", Json::num(cfg.model.fanouts[d] as f64)),
                ("f_src", Json::num(schema.node_types[rel.src].feat_dim as f64)),
                ("src_type", Json::num(rel.src as f64)),
                (
                    "src_type_name",
                    Json::str(schema.node_types[rel.src].name.clone()),
                ),
                ("src_learnable", Json::Bool(schema.node_types[rel.src].learnable)),
            ])
        })
        .collect();

    // RAF partitions: tree-edge ids per partition (a tree edge belongs to
    // the partition owning its relation — dedup in Step 4 means each
    // partition materializes each of its relations once, but the *tree*
    // may use a relation at several positions; all those positions belong
    // to that partition).
    let partitions: Vec<Json> = (0..mp.num_parts)
        .map(|p| {
            let edge_ids: Vec<Json> = tree
                .edges
                .iter()
                .enumerate()
                .filter(|(_, e)| {
                    // Edge is in partition p if its sub-metatree was
                    // assigned there.
                    edge_partition(tree, mp, e) == p
                })
                .map(|(ei, _)| Json::num(ei as f64))
                .collect();
            Json::from_pairs(vec![("edges", Json::Arr(edge_ids))])
        })
        .collect();

    let tt = &schema.node_types[schema.target];
    Json::from_pairs(vec![
        ("config", Json::str(cfg.name.clone())),
        ("arch", Json::str(cfg.model.arch.name())),
        ("hidden", Json::num(cfg.model.hidden as f64)),
        ("heads", Json::num(cfg.model.heads as f64)),
        ("num_classes", Json::num(schema.num_classes as f64)),
        ("batch", Json::num(cfg.train.batch_size as f64)),
        ("vanilla_batch", Json::num(cfg.vanilla_batch() as f64)),
        (
            "fanouts",
            Json::Arr(cfg.model.fanouts.iter().map(|&f| Json::num(f as f64)).collect()),
        ),
        (
            "target",
            Json::from_pairs(vec![
                ("type", Json::num(schema.target as f64)),
                ("type_name", Json::str(tt.name.clone())),
                ("feat_dim", Json::num(tt.feat_dim as f64)),
                ("learnable", Json::Bool(tt.learnable)),
            ]),
        ),
        ("vertices", Json::Arr(vertices)),
        ("edges", Json::Arr(edges)),
        ("partitions", Json::Arr(partitions)),
    ])
}

/// Which partition a metatree edge belongs to: the partition of the
/// sub-metatree containing it.
pub fn edge_partition(
    tree: &MetaTree,
    mp: &MetaPartition,
    edge: &crate::hetgraph::MetaTreeEdge,
) -> usize {
    // Walk up to the root-child ancestor; its sub-metatree index = order
    // among root children.
    let mut v = edge.child;
    loop {
        let parent = tree.vertices[v].parent.expect("edge child has a parent");
        if parent == 0 {
            break;
        }
        v = parent;
    }
    let sub_idx = tree
        .edges
        .iter()
        .filter(|e| e.parent == 0)
        .position(|e| e.child == v)
        .expect("root child subtree");
    mp.assignment[sub_idx]
}

/// Index of a metatree edge's partition, as a convenience for the RAF
/// engine's edge filters.
pub fn partition_edge_filter<'a>(
    tree: &'a MetaTree,
    mp: &'a MetaPartition,
    part: usize,
) -> impl Fn(usize) -> bool + 'a {
    move |ei: usize| edge_partition(tree, mp, &tree.edges[ei]) == part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::meta::meta_partition;

    pub const TINY: &str = r#"{
        "name": "mag-tiny",
        "dataset": {"preset": "mag", "scale": 1e-4, "seed": 42},
        "model": {"arch": "rgcn", "hidden": 32, "fanouts": [4, 3]},
        "train": {"batch_size": 32, "num_partitions": 2}
    }"#;

    #[test]
    fn parses_minimal_config() {
        let cfg = Config::from_json(&parse(TINY).unwrap()).unwrap();
        assert_eq!(cfg.name, "mag-tiny");
        assert_eq!(cfg.model.hidden, 32);
        assert_eq!(cfg.model.layers, 2);
        assert_eq!(cfg.train.num_partitions, 2);
        assert_eq!(cfg.vanilla_batch(), 16);
        assert_eq!(cfg.train.cache_policy, crate::cache::Policy::HotnessMissPenalty);
        assert_eq!(cfg.train.runtime, RuntimeKind::Sequential);
        assert!(cfg.train.pipeline);
        assert!(cfg.train.dedup_fetch, "dedup gather must default on");
        assert!(
            !cfg.train.shared_session,
            "per-worker execution contexts must default on"
        );
    }

    #[test]
    fn parses_shared_session_flag() {
        let text = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "shared_session": true}
        }"#;
        let cfg = Config::from_json(&parse(text).unwrap()).unwrap();
        assert!(cfg.train.shared_session);
    }

    #[test]
    fn parses_dedup_fetch_flag() {
        let text = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "dedup_fetch": false}
        }"#;
        let cfg = Config::from_json(&parse(text).unwrap()).unwrap();
        assert!(!cfg.train.dedup_fetch);
    }

    #[test]
    fn parses_staleness_and_rejects_it_without_dedup() {
        let cfg = Config::from_json(&parse(TINY).unwrap()).unwrap();
        assert_eq!(cfg.train.staleness, 0, "synchronous by default");
        let text = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "runtime": "cluster", "staleness": 2}
        }"#;
        let cfg = Config::from_json(&parse(text).unwrap()).unwrap();
        assert_eq!(cfg.train.staleness, 2);
        let bad = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "staleness": 1, "dedup_fetch": false}
        }"#;
        let err = Config::from_json(&parse(bad).unwrap()).unwrap_err();
        assert!(
            err.to_string().contains("dedup_fetch"),
            "staleness without dedup must explain itself: {err}"
        );
    }

    #[test]
    fn parses_transport_and_rejects_tcp_without_cluster() {
        let cfg = Config::from_json(&parse(TINY).unwrap()).unwrap();
        assert_eq!(cfg.train.transport, TransportKind::Channel, "channel by default");
        let text = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "runtime": "cluster", "transport": "tcp"}
        }"#;
        let cfg = Config::from_json(&parse(text).unwrap()).unwrap();
        assert_eq!(cfg.train.transport, TransportKind::Tcp);
        let bad = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "transport": "tcp"}
        }"#;
        let err = Config::from_json(&parse(bad).unwrap()).unwrap_err();
        assert!(
            err.to_string().contains("cluster"),
            "tcp without the cluster runtime must explain itself: {err}"
        );
        assert!(TransportKind::parse("carrier-pigeon").is_none());
        assert_eq!(TransportKind::Tcp.name(), "tcp");
    }

    #[test]
    fn parses_cluster_runtime_flag() {
        let text = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "runtime": "cluster", "pipeline": false}
        }"#;
        let cfg = Config::from_json(&parse(text).unwrap()).unwrap();
        assert_eq!(cfg.train.runtime, RuntimeKind::Cluster);
        assert!(!cfg.train.pipeline);
        assert!(RuntimeKind::parse("bogus").is_none());
    }

    #[test]
    fn parses_fault_specs() {
        let f = FaultSpec::parse("1:2:exit").unwrap();
        assert_eq!(
            f,
            FaultSpec {
                rank: 1,
                batch: 2,
                epoch: 0,
                kind: FaultKind::Exit
            }
        );
        let f = FaultSpec::parse("2:0:drop-conn:1").unwrap();
        assert_eq!(f.rank, 2);
        assert_eq!(f.epoch, 1);
        assert_eq!(f.kind, FaultKind::DropConn);
        assert_eq!(FaultSpec::parse("1:3:stall").unwrap().kind, FaultKind::Stall);
        assert_eq!(
            FaultSpec::parse("1:3:corrupt-frame").unwrap().kind,
            FaultKind::CorruptFrame
        );
        for bad in ["", "1:2", "1:2:explode", "x:2:exit", "1:y:exit", "1:2:exit:z", "0:2:exit"] {
            assert!(FaultSpec::parse(bad).is_err(), "{bad:?} must be rejected");
        }
        assert_eq!(FaultKind::Exit.name(), "exit");
    }

    #[test]
    fn parses_wire_knobs() {
        let cfg = Config::from_json(&parse(TINY).unwrap()).unwrap();
        assert_eq!(cfg.train.wire_snapshots, WireSnapshots::Diff, "diff by default");
        assert_eq!(cfg.train.wire_exchange, WireExchange::Star, "star by default");
        let text = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "runtime": "cluster",
                      "wire_snapshots": "full", "wire_exchange": "mesh"}
        }"#;
        let cfg = Config::from_json(&parse(text).unwrap()).unwrap();
        assert_eq!(cfg.train.wire_snapshots, WireSnapshots::Full);
        assert!(!cfg.train.wire_snapshots.is_diff());
        assert_eq!(cfg.train.wire_exchange, WireExchange::Mesh);
        assert!(cfg.train.wire_exchange.is_mesh());
        let bad = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "wire_snapshots": "sparse"}
        }"#;
        let err = Config::from_json(&parse(bad).unwrap()).unwrap_err();
        assert!(err.to_string().contains("wire_snapshots"), "{err}");
        assert!(WireSnapshots::parse("carrier-pigeon").is_none());
        assert!(WireExchange::parse("ring").is_none());
        assert_eq!(WireSnapshots::Diff.name(), "diff");
        assert_eq!(WireExchange::Mesh.name(), "mesh");
    }

    #[test]
    fn parses_heartbeat_knobs() {
        let cfg = Config::from_json(&parse(TINY).unwrap()).unwrap();
        assert_eq!(cfg.train.hb_interval_ms, 500);
        assert_eq!(cfg.train.hb_timeout_ms, 5000);
        assert!(cfg.train.fail.is_none(), "faults are CLI-only");
        let text = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2]},
            "train": {"batch_size": 8, "hb_interval_ms": 100, "hb_timeout_ms": 400}
        }"#;
        let cfg = Config::from_json(&parse(text).unwrap()).unwrap();
        assert_eq!(cfg.train.hb_interval_ms, 100);
        assert_eq!(cfg.train.hb_timeout_ms, 400);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(Config::from_json(&parse(r#"{"name":"x"}"#).unwrap()).is_err());
        let bad_layers = r#"{
            "name": "x",
            "dataset": {"preset": "mag", "scale": 1e-4},
            "model": {"arch": "rgcn", "hidden": 8, "fanouts": [2], "layers": 3},
            "train": {"batch_size": 4}
        }"#;
        assert!(Config::from_json(&parse(bad_layers).unwrap()).is_err());
    }

    #[test]
    fn plan_has_consistent_topology() {
        let cfg = Config::from_json(&parse(TINY).unwrap()).unwrap();
        let g = cfg.build_graph();
        let (mp, tree) = meta_partition(&g, cfg.train.num_partitions, cfg.model.layers, None);
        let plan = build_plan(&cfg, &g, &tree, &mp);
        let edges = plan.get("edges").as_arr().unwrap();
        assert_eq!(edges.len(), tree.edges.len());
        // Every edge appears in exactly one partition.
        let mut seen = vec![0usize; edges.len()];
        for part in plan.get("partitions").as_arr().unwrap() {
            for e in part.get("edges").as_arr().unwrap() {
                seen[e.as_usize().unwrap()] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "partition cover: {seen:?}");
        // Sizes multiply along the tree.
        let verts = plan.get("vertices").as_arr().unwrap();
        assert_eq!(verts[0].get("size").as_usize().unwrap(), 32);
        for e in edges {
            let p = e.get("parent").as_usize().unwrap();
            let c = e.get("child").as_usize().unwrap();
            let k = e.get("k").as_usize().unwrap();
            assert_eq!(
                verts[c].get("size").as_usize().unwrap(),
                verts[p].get("size").as_usize().unwrap() * k
            );
        }
    }

    #[test]
    fn edge_partition_respects_subtree_assignment() {
        let cfg = Config::from_json(&parse(TINY).unwrap()).unwrap();
        let g = cfg.build_graph();
        let (mp, tree) = meta_partition(&g, 2, 2, None);
        // All edges of a sub-metatree map to the same partition.
        for (si, sub) in tree.sub_metatrees().iter().enumerate() {
            for &ei in sub {
                assert_eq!(
                    edge_partition(&tree, &mp, &tree.edges[ei]),
                    mp.assignment[si]
                );
            }
        }
    }
}

#[cfg(test)]
pub use tests::TINY;
