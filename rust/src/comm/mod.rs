//! Communication substrate: an explicit cost model for the data-movement
//! lanes of the paper's testbed (100 Gbps network, PCIe H2D/D2H, host
//! DRAM random access, intra-machine GPU p2p) and a simulated transport
//! with per-worker byte/time ledgers plus the collectives both engines
//! use (gather-to-leader, ring all-reduce, broadcast).
//!
//! The real multi-machine cluster is unavailable (see DESIGN.md,
//! substitutions); every transfer in the system is charged through this
//! model, so communication *volumes* are exact and times follow one
//! consistent model for Heta and the baselines alike.

use anyhow::{ensure, Result};

/// Transfer lanes with distinct latency/bandwidth profiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Inter-machine network (paper: 100 Gbps).
    Net,
    /// Host DRAM → GPU over PCIe (paper: T4, PCIe 3.0 x16).
    Pcie,
    /// Random-access host DRAM read/write (learnable-feature updates).
    Dram,
    /// Intra-machine GPU peer-to-peer (non-replicative cache, §6).
    P2p,
}

pub const LANES: [Lane; 4] = [Lane::Net, Lane::Pcie, Lane::Dram, Lane::P2p];

impl Lane {
    pub fn index(self) -> usize {
        match self {
            Lane::Net => 0,
            Lane::Pcie => 1,
            Lane::Dram => 2,
            Lane::P2p => 3,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Lane::Net => "net",
            Lane::Pcie => "pcie",
            Lane::Dram => "dram",
            Lane::P2p => "p2p",
        }
    }
}

/// Latency + bandwidth per lane. Defaults approximate the paper's
/// g4dn.metal testbed; all values are configurable from `configs/*.json`.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Per-message latency (seconds) per lane.
    pub latency_s: [f64; 4],
    /// Bandwidth (bytes/second) per lane.
    pub bandwidth: [f64; 4],
    /// Multiplier applied to *measured* CPU compute time to translate it
    /// to the modeled accelerator (the paper's T4 GPUs): this testbed
    /// executes the PJRT artifacts on one CPU core, so simulated epoch
    /// times scale compute by this factor to keep the compute:data-
    /// movement ratio representative. 1.0 = report raw CPU time.
    pub compute_scale: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            //            net      pcie     dram     p2p
            latency_s: [30e-6, 10e-6, 0.3e-6, 5e-6],
            bandwidth: [
                100e9 / 8.0, // 100 Gbps network
                12e9,        // PCIe 3.0 x16 effective
                18e9,        // random-access DRAM effective
                40e9,        // NVLink-ish / PCIe p2p
            ],
            compute_scale: 1.0,
        }
    }
}

impl CostModel {
    /// Modeled time for one message of `bytes` on `lane`.
    #[inline]
    pub fn xfer_time(&self, lane: Lane, bytes: u64) -> f64 {
        let i = lane.index();
        self.latency_s[i] + bytes as f64 / self.bandwidth[i]
    }

    /// Time for `msgs` messages totalling `bytes` (latency per message,
    /// bandwidth shared) — models small-transfer overhead, the mechanism
    /// behind the paper's Fig. 7 (small feature dims ⇒ high per-byte
    /// penalty).
    #[inline]
    pub fn xfer_time_msgs(&self, lane: Lane, bytes: u64, msgs: u64) -> f64 {
        let i = lane.index();
        msgs as f64 * self.latency_s[i] + bytes as f64 / self.bandwidth[i]
    }

    /// One batched host→device staging transfer (paper §6: "batches miss
    /// rows into one staging transfer"): `rows` random DRAM touches
    /// assemble the staging buffer (per-row DRAM latency, shared
    /// bandwidth), then a single PCIe copy moves all `bytes` at once —
    /// the per-row PCIe latency amortizes away. Shared by the no-cache
    /// fetch path and the cache's batched-miss accounting so both price
    /// staging identically.
    #[inline]
    pub fn staging_time(&self, bytes: u64, rows: u64) -> f64 {
        self.xfer_time_msgs(Lane::Dram, bytes, rows) + self.xfer_time(Lane::Pcie, bytes)
    }
}

/// Byte/time/message ledger per lane; one per worker plus one global.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    pub bytes: [u64; 4],
    pub time_s: [f64; 4],
    pub msgs: [u64; 4],
}

impl Ledger {
    pub fn charge(&mut self, lane: Lane, bytes: u64, time_s: f64) {
        let i = lane.index();
        self.bytes[i] += bytes;
        self.time_s[i] += time_s;
        self.msgs[i] += 1;
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    pub fn total_time(&self) -> f64 {
        self.time_s.iter().sum()
    }

    pub fn merge(&mut self, other: &Ledger) {
        for i in 0..4 {
            self.bytes[i] += other.bytes[i];
            self.time_s[i] += other.time_s[i];
            self.msgs[i] += other.msgs[i];
        }
    }
}

/// Simulated cluster transport: `w` workers (one per machine/partition)
/// with per-worker ledgers. All sizes in bytes; all ops return the
/// modeled wall time they add to the *critical path*.
#[derive(Debug, Clone)]
pub struct SimNet {
    pub cost: CostModel,
    pub ledgers: Vec<Ledger>,
}

impl SimNet {
    pub fn new(workers: usize, cost: CostModel) -> Self {
        SimNet {
            cost,
            ledgers: vec![Ledger::default(); workers],
        }
    }

    pub fn workers(&self) -> usize {
        self.ledgers.len()
    }

    /// Point-to-point send (`from` pays the send, `to` is implicit).
    /// Errors (rather than panicking) on an out-of-range worker so a
    /// cluster worker thread can surface the fault as `anyhow::Error`.
    pub fn send(&mut self, from: usize, to: usize, bytes: u64) -> Result<f64> {
        ensure!(
            from < self.ledgers.len() && to < self.ledgers.len(),
            "send {from}->{to} outside {}-worker net",
            self.ledgers.len()
        );
        let t = self.cost.xfer_time(Lane::Net, bytes);
        self.ledgers[from].charge(Lane::Net, bytes, t);
        Ok(t)
    }

    /// Gather `bytes_per_worker[i]` from every worker i≠root to `root`.
    /// Senders transmit in parallel; the root's NIC serializes reception,
    /// so critical path = max(sender times) bounded below by total/bw.
    pub fn gather(&mut self, root: usize, bytes_per_worker: &[u64]) -> Result<f64> {
        ensure!(
            root < self.ledgers.len() && bytes_per_worker.len() <= self.ledgers.len(),
            "gather to {root} over {} senders exceeds {}-worker net",
            bytes_per_worker.len(),
            self.ledgers.len()
        );
        let mut max_sender = 0f64;
        let mut total = 0u64;
        for (i, &b) in bytes_per_worker.iter().enumerate() {
            if i == root || b == 0 {
                continue;
            }
            let t = self.cost.xfer_time(Lane::Net, b);
            self.ledgers[i].charge(Lane::Net, b, t);
            total += b;
            max_sender = max_sender.max(t);
        }
        let recv_bound = total as f64 / self.cost.bandwidth[Lane::Net.index()];
        Ok(max_sender.max(recv_bound))
    }

    /// Broadcast `bytes` from `root` to all other workers.
    pub fn broadcast(&mut self, root: usize, bytes: u64) -> Result<f64> {
        let n = self.workers();
        ensure!(root < n, "broadcast root {root} outside {n}-worker net");
        if n <= 1 || bytes == 0 {
            return Ok(0.0);
        }
        // Tree broadcast: ⌈log2 n⌉ rounds.
        let rounds = (n as f64).log2().ceil();
        let t = self.cost.xfer_time(Lane::Net, bytes) * rounds;
        self.ledgers[root].charge(Lane::Net, bytes * (n as u64 - 1), t);
        Ok(t)
    }

    /// Ring all-reduce of `bytes` across all workers: each worker sends
    /// and receives `2·(n−1)/n · bytes` (the vanilla engine's gradient
    /// synchronization).
    pub fn allreduce(&mut self, bytes: u64) -> f64 {
        let n = self.workers();
        if n <= 1 || bytes == 0 {
            return 0.0;
        }
        let per_worker = (2 * bytes * (n as u64 - 1)) / n as u64;
        let steps = 2 * (n - 1);
        let t = self
            .cost
            .xfer_time_msgs(Lane::Net, per_worker, steps as u64);
        for l in &mut self.ledgers {
            l.charge(Lane::Net, per_worker, t);
        }
        t
    }

    /// Charge bytes (with a precomputed time) to one worker's ledger —
    /// the entry point the cluster transport uses so mailbox traffic
    /// lands in the same ledgers as the sequential engines'.
    pub fn charge(&mut self, worker: usize, lane: Lane, bytes: u64, time_s: f64) -> Result<()> {
        ensure!(
            worker < self.ledgers.len(),
            "charge to worker {worker} outside {}-worker net",
            self.ledgers.len()
        );
        self.ledgers[worker].charge(lane, bytes, time_s);
        Ok(())
    }

    /// Charge a host-local transfer (PCIe copy, DRAM access, p2p) to a
    /// worker, modelling `msgs` distinct transactions.
    pub fn local(&mut self, worker: usize, lane: Lane, bytes: u64, msgs: u64) -> Result<f64> {
        ensure!(
            worker < self.ledgers.len(),
            "local charge to worker {worker} outside {}-worker net",
            self.ledgers.len()
        );
        let t = self.cost.xfer_time_msgs(lane, bytes, msgs);
        let i = lane.index();
        self.ledgers[worker].bytes[i] += bytes;
        self.ledgers[worker].time_s[i] += t;
        self.ledgers[worker].msgs[i] += msgs;
        Ok(t)
    }

    /// Aggregate ledger across workers.
    pub fn total(&self) -> Ledger {
        let mut l = Ledger::default();
        for w in &self.ledgers {
            l.merge(w);
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xfer_time_has_latency_floor() {
        let c = CostModel::default();
        let tiny = c.xfer_time(Lane::Net, 1);
        assert!(tiny >= 30e-6);
        let big = c.xfer_time(Lane::Net, 125_000_000); // 1 Gbit
        assert!(big > tiny * 100.0);
    }

    #[test]
    fn msgs_multiply_latency() {
        let c = CostModel::default();
        let one = c.xfer_time_msgs(Lane::Pcie, 1024, 1);
        let many = c.xfer_time_msgs(Lane::Pcie, 1024, 100);
        assert!(many > one * 50.0);
    }

    #[test]
    fn staging_beats_per_row_transfers() {
        // One staged transfer of r rows must undercut r row-sized PCIe
        // messages (that's the amortization the batched path models),
        // while still charging every DRAM row touch.
        let c = CostModel::default();
        let (rows, row_bytes) = (512u64, 256u64);
        let staged = c.staging_time(rows * row_bytes, rows);
        let per_row: f64 = (0..rows)
            .map(|_| c.xfer_time(Lane::Dram, row_bytes) + c.xfer_time(Lane::Pcie, row_bytes))
            .sum();
        assert!(staged < per_row, "staged {staged} vs per-row {per_row}");
        let expected = c.xfer_time_msgs(Lane::Dram, rows * row_bytes, rows)
            + c.xfer_time(Lane::Pcie, rows * row_bytes);
        assert!((staged - expected).abs() < 1e-15);
    }

    #[test]
    fn gather_charges_senders_not_root() {
        let mut net = SimNet::new(3, CostModel::default());
        let t = net.gather(0, &[0, 1000, 2000]).unwrap();
        assert!(t > 0.0);
        assert!(net.gather(7, &[0, 0, 0]).is_err());
        assert_eq!(net.ledgers[0].bytes[Lane::Net.index()], 0);
        assert_eq!(net.ledgers[1].bytes[Lane::Net.index()], 1000);
        assert_eq!(net.ledgers[2].bytes[Lane::Net.index()], 2000);
    }

    #[test]
    fn allreduce_volume_formula() {
        let mut net = SimNet::new(4, CostModel::default());
        net.allreduce(4000);
        // 2·(n−1)/n·bytes = 2·3/4·4000 = 6000 per worker.
        for l in &net.ledgers {
            assert_eq!(l.bytes[Lane::Net.index()], 6000);
        }
    }

    #[test]
    fn single_worker_collectives_are_free() {
        let mut net = SimNet::new(1, CostModel::default());
        assert_eq!(net.allreduce(1_000_000), 0.0);
        assert_eq!(net.broadcast(0, 1_000_000).unwrap(), 0.0);
    }

    #[test]
    fn ledgers_merge() {
        let mut a = Ledger::default();
        a.charge(Lane::Net, 10, 1.0);
        let mut b = Ledger::default();
        b.charge(Lane::Net, 5, 0.5);
        b.charge(Lane::Dram, 7, 0.1);
        a.merge(&b);
        assert_eq!(a.bytes[Lane::Net.index()], 15);
        assert_eq!(a.bytes[Lane::Dram.index()], 7);
        assert!((a.total_time() - 1.6).abs() < 1e-12);
        assert_eq!(a.total_bytes(), 22);
    }
}
