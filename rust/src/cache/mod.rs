//! GPU feature cache (paper §6): miss-penalty-aware cache-size
//! allocation, hotness-ranked fill, hit/miss ledgers, and the
//! non-replicative hash-split design for mutable learnable features +
//! optimizer state.
//!
//! The GPU itself is simulated (DESIGN.md): a cache *hit* costs nothing
//! extra (data already on-device), a *miss* charges the transfer lanes of
//! [`crate::comm::CostModel`] — PCIe H2D for read-only features; DRAM
//! read + PCIe H2D + PCIe D2H + DRAM write for learnable features and
//! their optimizer state (the read-modify-write path of Fig. 3 step 5).
//! The *miss-penalty ratio* `o_a` (µs per byte, Fig. 7) is profiled from
//! this model exactly like the paper profiles its hardware before
//! training.

use std::sync::Arc;

use crate::comm::{CostModel, Lane};
use crate::hetgraph::NodeId;

/// Cache-size allocation policy (Fig. 11's ablation arms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// No cache at all.
    None,
    /// Allocate by node hotness only (PaGraph/GNNLab-style).
    HotnessOnly,
    /// Heta: allocate ∝ hotness × miss-penalty ratio (§6).
    HotnessMissPenalty,
}

impl Policy {
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "none" | "no-cache" => Some(Policy::None),
            "hotness" | "hotness-only" => Some(Policy::HotnessOnly),
            "heta" | "hotness+miss-penalty" | "miss-penalty" => Some(Policy::HotnessMissPenalty),
            _ => None,
        }
    }
}

/// Per-type static description the cache needs.
#[derive(Debug, Clone)]
pub struct TypeProfile {
    pub name: String,
    pub count: usize,
    pub feat_dim: usize,
    pub learnable: bool,
}

/// Profile the miss-penalty ratio `o_a` (seconds per byte of feature
/// data) of one node type: the time to service a single-row cache miss
/// divided by the row's feature bytes. Learnable rows pay the full
/// read-modify-write path — random DRAM reads of the row and its Adam
/// moments (three separate transactions), H2D, D2H, and the scattered
/// write-back — so their ratio exceeds a read-only row of the same
/// dimension (Fig. 7b). Small rows have a higher ratio because the
/// per-transaction latency amortizes over fewer bytes (Fig. 7a). The 3×
/// capacity footprint of learnable rows (weight + m + v) is accounted in
/// [`TypeCache::row_bytes`], not here.
pub fn miss_penalty_ratio(cost: &CostModel, dim: usize, learnable: bool) -> f64 {
    let row_bytes = (dim * 4) as u64;
    if learnable {
        let state_bytes = row_bytes * 3; // weight + m + v move together
        // 3 random DRAM reads + H2D + D2H + 3 random DRAM writes.
        let t = 3.0 * cost.xfer_time(Lane::Dram, state_bytes / 3)
            + cost.xfer_time(Lane::Pcie, state_bytes)
            + cost.xfer_time(Lane::Pcie, state_bytes)
            + 3.0 * cost.xfer_time(Lane::Dram, state_bytes / 3);
        t / row_bytes as f64
    } else {
        let t = cost.xfer_time(Lane::Dram, row_bytes) + cost.xfer_time(Lane::Pcie, row_bytes);
        t / row_bytes as f64
    }
}

/// Per-type cache state: the hottest `capacity_rows` node ids (by
/// pre-sampled visit count) are resident.
#[derive(Debug, Clone)]
pub struct TypeCache {
    pub capacity_rows: usize,
    pub row_bytes: u64,
    pub learnable: bool,
    pub penalty_ratio: f64,
    /// Bitmap: `resident[id]` = cached. Immutable after [`FeatureCache::build`]
    /// (hotness-ranked static fill), hence `Arc`-shared between a cache
    /// and its [`FeatureCache::fork_ledger`] views — only the hit/miss
    /// ledgers are per-view.
    resident: Arc<Vec<bool>>,
    pub hits: u64,
    pub misses: u64,
}

impl TypeCache {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The per-machine feature cache across all node types.
pub struct FeatureCache {
    pub policy: Policy,
    pub types: Vec<TypeCache>,
    /// Number of GPUs sharing the non-replicative split (hash by id).
    pub num_gpus: usize,
    pub total_bytes: u64,
}

impl FeatureCache {
    /// Build a cache. `hotness[ty][node]` comes from pre-sampling
    /// (paper §6); `total_bytes` is the per-GPU budget × `num_gpus`
    /// (non-replicative split pools capacity). Allocation:
    /// `share_a = count_a · o_a / Σ count_a' · o_a'` (hotness ×
    /// miss-penalty), or hotness only, per policy.
    pub fn build(
        policy: Policy,
        profiles: &[TypeProfile],
        hotness: &[Vec<u32>],
        cost: &CostModel,
        total_bytes: u64,
        num_gpus: usize,
    ) -> FeatureCache {
        let ratios: Vec<f64> = profiles
            .iter()
            .map(|p| miss_penalty_ratio(cost, p.feat_dim, p.learnable))
            .collect();
        let visit_totals: Vec<f64> = hotness
            .iter()
            .map(|h| h.iter().map(|&c| c as f64).sum())
            .collect();
        let scores: Vec<f64> = match policy {
            Policy::None => vec![0.0; profiles.len()],
            Policy::HotnessOnly => visit_totals.clone(),
            Policy::HotnessMissPenalty => visit_totals
                .iter()
                .zip(&ratios)
                .map(|(&v, &r)| v * r * 1e6)
                .collect(),
        };
        let score_sum: f64 = scores.iter().sum();

        let types: Vec<TypeCache> = profiles
            .iter()
            .enumerate()
            .map(|(ty, p)| {
                let row_bytes = (p.feat_dim * 4) as u64 * if p.learnable { 3 } else { 1 };
                let budget = if score_sum > 0.0 {
                    (total_bytes as f64 * scores[ty] / score_sum) as u64
                } else {
                    0
                };
                let capacity_rows = ((budget / row_bytes.max(1)) as usize).min(p.count);
                // Fill with the hottest nodes: select top-capacity ids by
                // visit count (stable by id for determinism).
                let mut resident = vec![false; p.count];
                if capacity_rows > 0 {
                    let mut order: Vec<u32> = (0..p.count as u32).collect();
                    order.sort_by_key(|&id| {
                        (std::cmp::Reverse(hotness[ty][id as usize]), id)
                    });
                    for &id in order.iter().take(capacity_rows) {
                        resident[id as usize] = true;
                    }
                }
                TypeCache {
                    capacity_rows,
                    row_bytes,
                    learnable: p.learnable,
                    penalty_ratio: ratios[ty],
                    resident: Arc::new(resident),
                    hits: 0,
                    misses: 0,
                }
            })
            .collect();
        FeatureCache {
            policy,
            types,
            num_gpus,
            total_bytes,
        }
    }

    /// Account one access to `(ty, id)` from GPU `gpu`. Returns the
    /// modeled extra time this access costs (0 for a local hit; p2p for a
    /// hit on a peer GPU under the non-replicative split; the full miss
    /// penalty otherwise). `write` marks a learnable update access
    /// (read-modify-write path).
    pub fn access(
        &mut self,
        cost: &CostModel,
        ty: usize,
        id: NodeId,
        gpu: usize,
        write: bool,
    ) -> f64 {
        let tc = &mut self.types[ty];
        if self.policy != Policy::None && tc.resident[id as usize] {
            tc.hits += 1;
            // Non-replicative split: learnable rows live on GPU
            // `id % num_gpus` (paper §6 Cache Consistency); peer access
            // goes over p2p. Read-only rows are replicated per GPU.
            if tc.learnable && self.num_gpus > 1 && (id as usize) % self.num_gpus != gpu {
                let factor = if write { 2 } else { 1 };
                return cost.xfer_time(Lane::P2p, tc.row_bytes * factor);
            }
            return 0.0;
        }
        tc.misses += 1;
        // Miss: per-row random DRAM access + H2D at PCIe *bandwidth* —
        // the runtime batches miss rows into one staging transfer per
        // block, so the per-transaction PCIe latency amortizes away
        // (matching the no-cache fetch path's batched accounting).
        let b = tc.row_bytes;
        let pcie_bw = cost.bandwidth[Lane::Pcie.index()];
        if tc.learnable {
            let mut t = cost.xfer_time(Lane::Dram, b) + b as f64 / pcie_bw;
            if write {
                t += b as f64 / pcie_bw + cost.xfer_time(Lane::Dram, b);
            }
            t
        } else {
            cost.xfer_time(Lane::Dram, b) + b as f64 / pcie_bw
        }
    }

    /// Account one batch's **deduplicated** read accesses to `ty` in a
    /// single call: `ids` are the batch frontier's distinct ids, so the
    /// hit/miss ledgers advance exactly once per unique id per batch, and
    /// all missed rows are charged as one batched DRAM→staging→PCIe
    /// transfer ([`CostModel::staging_time`]) instead of per-row messages
    /// — the §6 runtime's "batch miss rows into one staging transfer".
    /// Peer-GPU hits under the non-replicative split still pay p2p per
    /// row (they bypass staging entirely). Returns the modeled seconds.
    pub fn access_unique(
        &mut self,
        cost: &CostModel,
        ty: usize,
        ids: &[NodeId],
        gpu: usize,
    ) -> f64 {
        let tc = &mut self.types[ty];
        let mut t = 0.0f64;
        let mut miss_rows = 0u64;
        for &id in ids {
            if self.policy != Policy::None && tc.resident[id as usize] {
                tc.hits += 1;
                if tc.learnable && self.num_gpus > 1 && (id as usize) % self.num_gpus != gpu {
                    t += cost.xfer_time(Lane::P2p, tc.row_bytes);
                }
            } else {
                tc.misses += 1;
                miss_rows += 1;
            }
        }
        if miss_rows > 0 {
            t += cost.staging_time(miss_rows * tc.row_bytes, miss_rows);
        }
        t
    }

    /// A zero-ledger view of this cache sharing the (immutable, static)
    /// residency bitmaps. The RAF leader role uses forks to price its
    /// target-row fetches and update-phase write-backs against a
    /// partition's cache **without** holding any reference to the worker
    /// thread that owns the primary — residency is shared, so every
    /// access returns byte-identical modeled times, and
    /// [`FeatureCache::absorb_ledger`] folds the view's hit/miss counts
    /// back into the owner once the epoch's worker threads are done.
    pub fn fork_ledger(&self) -> FeatureCache {
        FeatureCache {
            policy: self.policy,
            types: self
                .types
                .iter()
                .map(|t| TypeCache {
                    capacity_rows: t.capacity_rows,
                    row_bytes: t.row_bytes,
                    learnable: t.learnable,
                    penalty_ratio: t.penalty_ratio,
                    resident: Arc::clone(&t.resident),
                    hits: 0,
                    misses: 0,
                })
                .collect(),
            num_gpus: self.num_gpus,
            total_bytes: self.total_bytes,
        }
    }

    /// Fold a [`FeatureCache::fork_ledger`] view's hit/miss counts back
    /// into this (owning) cache, keeping epoch-level hit rates identical
    /// to the single-owner accounting.
    pub fn absorb_ledger(&mut self, fork: &FeatureCache) {
        debug_assert_eq!(self.types.len(), fork.types.len(), "ledger shape mismatch");
        for (t, f) in self.types.iter_mut().zip(&fork.types) {
            t.hits += f.hits;
            t.misses += f.misses;
        }
    }

    /// Bytes actually allocated (≤ total budget).
    pub fn used_bytes(&self) -> u64 {
        self.types
            .iter()
            .map(|t| t.capacity_rows as u64 * t.row_bytes)
            .sum()
    }

    pub fn hit_rates(&self) -> Vec<f64> {
        self.types.iter().map(|t| t.hit_rate()).collect()
    }
}

/// Accounting for one serving run: what the request stream cost end to
/// end, across every reuse layer (embedding cache, in-batch target
/// dedup, frontier fetch dedup). The serve A/B bench compares ledgers
/// between the reuse and no-reuse arms — `rows_per_request` is the
/// headline number (fetched feature rows per served request).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeLedger {
    pub requests: u64,
    pub batches: u64,
    /// Targets that actually went through the forward plan (after
    /// embed-cache hits and in-batch dedup).
    pub computed_targets: u64,
    /// Requests folded away because the same target already appeared
    /// earlier in the same microbatch.
    pub batch_dups: u64,
    pub embed_hits: u64,
    pub embed_misses: u64,
    pub embed_invalidations: u64,
    /// Feature rows gathered from the KV store across all workers.
    pub fetched_rows: u64,
    pub fetched_bytes: u64,
}

impl ServeLedger {
    pub fn merge(&mut self, other: &ServeLedger) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.computed_targets += other.computed_targets;
        self.batch_dups += other.batch_dups;
        self.embed_hits += other.embed_hits;
        self.embed_misses += other.embed_misses;
        self.embed_invalidations += other.embed_invalidations;
        self.fetched_rows += other.fetched_rows;
        self.fetched_bytes += other.fetched_bytes;
    }

    /// Embedding-cache hit rate over all lookups (NaN when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.embed_hits + self.embed_misses;
        if total == 0 {
            return f64::NAN;
        }
        self.embed_hits as f64 / total as f64
    }

    /// The A/B headline: KV rows fetched per served request (NaN when
    /// idle). Reuse layers push this down without changing the bytes
    /// served.
    pub fn rows_per_request(&self) -> f64 {
        if self.requests == 0 {
            return f64::NAN;
        }
        self.fetched_rows as f64 / self.requests as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    fn profiles() -> Vec<TypeProfile> {
        vec![
            TypeProfile { name: "paper".into(), count: 1000, feat_dim: 128, learnable: false },
            TypeProfile { name: "author".into(), count: 800, feat_dim: 64, learnable: true },
            TypeProfile { name: "tag".into(), count: 500, feat_dim: 8, learnable: false },
        ]
    }

    fn skewed_hotness(profiles: &[TypeProfile], seed: u64) -> Vec<Vec<u32>> {
        let mut rng = Rng::new(seed);
        profiles
            .iter()
            .map(|p| {
                (0..p.count)
                    .map(|i| (1000 / (i + 1)) as u32 + rng.below(3) as u32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn small_dims_have_larger_penalty_ratio() {
        // Fig. 7a: smaller feature dimensions ⇒ larger per-byte penalty.
        let c = CostModel::default();
        let small = miss_penalty_ratio(&c, 7, false);
        let large = miss_penalty_ratio(&c, 789, false);
        assert!(small > 3.0 * large, "small {small} vs large {large}");
    }

    #[test]
    fn learnable_penalty_exceeds_readonly() {
        // Fig. 7b: learnable features pay the write-back path.
        let c = CostModel::default();
        let ro = miss_penalty_ratio(&c, 128, false);
        let lr = miss_penalty_ratio(&c, 128, true);
        assert!(lr > ro, "learnable {lr} vs read-only {ro}");
    }

    #[test]
    fn policy_none_allocates_nothing_and_always_misses() {
        let p = profiles();
        let h = skewed_hotness(&p, 1);
        let c = CostModel::default();
        let mut cache = FeatureCache::build(Policy::None, &p, &h, &c, 1 << 20, 1);
        assert_eq!(cache.used_bytes(), 0);
        let t = cache.access(&c, 0, 0, 0, false);
        assert!(t > 0.0);
        assert_eq!(cache.types[0].misses, 1);
    }

    #[test]
    fn hottest_nodes_are_resident() {
        let p = profiles();
        let h = skewed_hotness(&p, 2);
        let c = CostModel::default();
        let mut cache = FeatureCache::build(Policy::HotnessOnly, &p, &h, &c, 64 << 10, 1);
        // Node 0 is hottest in every type; it must hit if the type got
        // any budget.
        for ty in 0..p.len() {
            if cache.types[ty].capacity_rows > 0 {
                let t = cache.access(&c, ty, 0, 0, false);
                assert_eq!(t, 0.0, "hot node missed in type {ty}");
            }
        }
    }

    #[test]
    fn miss_penalty_policy_shifts_budget_to_penalized_types() {
        // Two types, identical dim/count/hotness, but one is learnable:
        // hotness-only splits the budget evenly, while the miss-penalty-
        // aware policy must give the learnable type (higher o_a) more
        // cache bytes — the core §6 mechanism.
        let p = vec![
            TypeProfile { name: "ro".into(), count: 1000, feat_dim: 128, learnable: false },
            TypeProfile { name: "lr".into(), count: 1000, feat_dim: 128, learnable: true },
        ];
        let h: Vec<Vec<u32>> = vec![vec![5; 1000], vec![5; 1000]];
        let c = CostModel::default();
        let ho = FeatureCache::build(Policy::HotnessOnly, &p, &h, &c, 256 << 10, 1);
        let mp = FeatureCache::build(Policy::HotnessMissPenalty, &p, &h, &c, 256 << 10, 1);
        let ho_bytes = ho.types[1].capacity_rows as u64 * ho.types[1].row_bytes;
        let mp_bytes = mp.types[1].capacity_rows as u64 * mp.types[1].row_bytes;
        assert!(
            mp_bytes > ho_bytes,
            "heta gave learnable type {mp_bytes} B vs hotness-only {ho_bytes} B"
        );
    }

    #[test]
    fn p2p_charged_for_peer_gpu_learnable_hits() {
        let p = profiles();
        let h = skewed_hotness(&p, 4);
        let c = CostModel::default();
        let mut cache =
            FeatureCache::build(Policy::HotnessMissPenalty, &p, &h, &c, 1 << 22, 4);
        // Node id 1 lives on GPU 1; access from GPU 0 → p2p time > 0.
        assert!(cache.types[1].resident[1]);
        let t = cache.access(&c, 1, 1, 0, false);
        assert!(t > 0.0 && t < miss_penalty_ratio(&c, 64, true) * cache.types[1].row_bytes as f64 * 2.0);
        // Same id from its home GPU: free.
        let t_home = cache.access(&c, 1, 1, 1, false);
        assert_eq!(t_home, 0.0);
    }

    #[test]
    fn access_unique_counts_each_id_once_and_batches_misses() {
        let p = profiles();
        let h = skewed_hotness(&p, 8);
        let c = CostModel::default();
        let mut cache = FeatureCache::build(Policy::HotnessOnly, &p, &h, &c, 64 << 10, 1);
        // Mostly-cold distinct ids (the hotness-ranked fill keeps only
        // the lowest ids resident under this tiny budget).
        let ids: Vec<NodeId> = (0..40).map(|i| i * 25).collect();
        let t = cache.access_unique(&c, 0, &ids, 0);
        // Exactly one ledger entry per unique id.
        assert_eq!(cache.types[0].hits + cache.types[0].misses, ids.len() as u64);
        let misses = cache.types[0].misses;
        assert!(misses >= 30, "spread ids must mostly miss, got {misses}");
        // All misses fold into exactly one staging transfer.
        let row_bytes = cache.types[0].row_bytes;
        let hit_t = 0.0; // read-only hits are free on a 1-GPU split
        let expected = hit_t + c.staging_time(misses * row_bytes, misses);
        assert!((t - expected).abs() < 1e-15, "t={t} expected={expected}");
        // Against the seed's per-occurrence accounting of a duplicated
        // slot list (every id sampled three times): the dedup'd batched
        // path consults residency a third as often and is strictly
        // cheaper even though it pays the one staging-transfer latency.
        let mut per_occ = FeatureCache::build(Policy::HotnessOnly, &p, &h, &c, 64 << 10, 1);
        let mut t_occ = 0.0;
        for &id in ids.iter().chain(ids.iter()).chain(ids.iter()) {
            t_occ += per_occ.access(&c, 0, id, 0, false);
        }
        assert_eq!(per_occ.types[0].misses, 3 * misses, "occurrences triple-count");
        assert!(t < t_occ, "dedup'd {t} not below per-occurrence {t_occ}");
    }

    #[test]
    fn fork_ledger_shares_residency_and_absorbs_counts() {
        let p = profiles();
        let h = skewed_hotness(&p, 6);
        let c = CostModel::default();
        let mut owner = FeatureCache::build(Policy::HotnessOnly, &p, &h, &c, 64 << 10, 1);
        let mut fork = owner.fork_ledger();
        // Identical residency ⇒ identical modeled time for any access.
        for id in [0u32, 3, 400, 999] {
            assert_eq!(
                owner.access(&c, 0, id, 0, false),
                fork.access(&c, 0, id, 0, false),
                "fork priced id {id} differently"
            );
        }
        let (oh, om) = (owner.types[0].hits, owner.types[0].misses);
        assert_eq!((fork.types[0].hits, fork.types[0].misses), (oh, om));
        owner.absorb_ledger(&fork);
        assert_eq!(owner.types[0].hits, 2 * oh);
        assert_eq!(owner.types[0].misses, 2 * om);
    }

    #[test]
    fn serve_ledger_merges_and_rates() {
        let mut a = ServeLedger {
            requests: 10,
            batches: 2,
            computed_targets: 6,
            batch_dups: 1,
            embed_hits: 3,
            embed_misses: 7,
            embed_invalidations: 0,
            fetched_rows: 120,
            fetched_bytes: 4800,
        };
        let b = ServeLedger { requests: 10, embed_hits: 7, embed_misses: 3, ..a };
        a.merge(&b);
        assert_eq!(a.requests, 20);
        assert_eq!(a.embed_hits + a.embed_misses, 20);
        assert!((a.hit_rate() - 0.5).abs() < 1e-12);
        assert!((a.rows_per_request() - 12.0).abs() < 1e-12);
        let idle = ServeLedger::default();
        assert!(idle.hit_rate().is_nan());
        assert!(idle.rows_per_request().is_nan());
    }

    #[test]
    fn prop_budget_never_exceeded_and_capacity_bounded() {
        proptest::run("cache_budget_invariant", |rng, _| {
            let p = profiles();
            let h = skewed_hotness(&p, rng.next_u64());
            let c = CostModel::default();
            let budget = 1u64 << (10 + rng.below(14));
            let policy = [Policy::HotnessOnly, Policy::HotnessMissPenalty][rng.below(2)];
            let cache = FeatureCache::build(policy, &p, &h, &c, budget, 1 + rng.below(8));
            crate::prop_assert!(
                cache.used_bytes() <= budget,
                "used {} > budget {}",
                cache.used_bytes(),
                budget
            );
            for (ty, tc) in cache.types.iter().enumerate() {
                crate::prop_assert!(
                    tc.capacity_rows <= p[ty].count,
                    "capacity exceeds population"
                );
            }
            Ok(())
        });
    }
}
