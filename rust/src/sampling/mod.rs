//! Neighbor sampling along the metatree.
//!
//! Both execution models compute the same HGNN over the same sampled
//! aggregation tree (that is what makes Prop. 1's equivalence testable):
//! for a minibatch of `B` target nodes, every metatree edge with fanout
//! `K` samples up to `K` distinct in-neighbors per parent slot, producing
//! **padded, fixed-shape blocks** (`[S_parent × K]` node ids plus a
//! validity mask) — the static shapes the AOT-compiled HLO requires.
//!
//! Sampling is *per-slot deterministic*: the RNG for a given (edge,
//! parent-slot, parent-node) triple is derived from the batch seed, so
//! the RAF engine (each partition sampling only its own relations) and
//! the vanilla engine (one worker sampling the full tree) reproduce
//! byte-identical neighbor sets — the basis of the equivalence test.

pub mod frontier;

pub use frontier::{Frontier, NO_ROW};

use crate::hetgraph::{HetGraph, MetaTree, NodeId};
use crate::util::rng::Rng;

/// Sentinel id marking a padded (invalid) slot.
pub const PAD: NodeId = NodeId::MAX;

/// The sampled tree for one minibatch: per metatree vertex, a padded id
/// array (root = the batch itself); slot `i*K + j` of a child vertex is
/// the j-th sampled neighbor of the parent's slot `i`.
#[derive(Debug, Clone)]
pub struct TreeSample {
    /// Node ids per metatree vertex (padded with [`PAD`]).
    pub ids: Vec<Vec<NodeId>>,
    /// Fanout used at each metatree edge.
    pub fanouts: Vec<usize>,
}

impl TreeSample {
    /// Number of valid (non-pad) ids at a vertex. O(slots) rescan — hot
    /// paths that already carry a [`Frontier`] should read its cached
    /// `valid_counts[vertex]` instead.
    pub fn valid_count(&self, vertex: usize) -> usize {
        self.ids[vertex].iter().filter(|&&id| id != PAD).count()
    }
}

/// Expected (padded) slot count per metatree vertex for batch size `b`.
pub fn vertex_sizes(tree: &MetaTree, fanouts: &[usize], b: usize) -> Vec<usize> {
    let mut sizes = vec![0usize; tree.vertices.len()];
    sizes[0] = b;
    // Vertices are in BFS order; parents precede children.
    for e in &tree.edges {
        let d = tree.vertices[e.parent].depth;
        sizes[e.child] = sizes[e.parent] * fanouts[d];
    }
    sizes
}

#[inline]
fn slot_rng(seed: u64, edge: usize, slot: usize, parent: NodeId) -> Rng {
    let mut h = seed ^ 0xD6E8_FEB8_6659_FD93;
    for v in [edge as u64 + 1, slot as u64 + 1, parent as u64 + 1] {
        h ^= v.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h = h.rotate_left(29).wrapping_mul(0x94D0_49BB_1331_11EB);
    }
    Rng::new(h)
}

/// Sample the full tree (vanilla engine) or a filtered subset of tree
/// edges (RAF engine: `edge_filter` keeps only the partition's edges;
/// unsampled vertices stay fully padded). `seed` identifies the batch.
///
/// `root_offset` is the global index of `batch[0]` within the full
/// minibatch: the per-slot RNG keys on *global* slot positions, so a
/// data-parallel microbatch (vanilla engine, worker `w` sampling rows
/// `[w·vb, (w+1)·vb)`) reproduces byte-identical neighbor sets to the
/// RAF engine's full-batch sample — the substrate of the Prop. 1
/// equivalence test.
pub fn sample_tree(
    g: &HetGraph,
    tree: &MetaTree,
    fanouts: &[usize],
    batch: &[NodeId],
    root_offset: usize,
    seed: u64,
    edge_filter: impl Fn(usize) -> bool,
) -> TreeSample {
    let sizes = vertex_sizes(tree, fanouts, batch.len());
    let mut ids: Vec<Vec<NodeId>> = sizes.iter().map(|&s| vec![PAD; s]).collect();
    ids[0][..batch.len()].copy_from_slice(batch);
    // Global-slot multiplier per vertex: Π fanouts along the path.
    let mult: Vec<usize> = sizes.iter().map(|&s| s / batch.len().max(1)).collect();

    // BFS order: metatree edges are already ordered parent-before-child.
    // Scratch for Floyd sampling, reused across every slot of every edge.
    let mut picks: Vec<usize> = Vec::new();
    for (ei, e) in tree.edges.iter().enumerate() {
        if !edge_filter(ei) {
            continue;
        }
        let k = fanouts[tree.vertices[e.parent].depth];
        let csr = g.csr(e.rel);
        // Parent ids may themselves be padded (or unsampled for this
        // partition — for RAF that cannot happen: meta-partitioning keeps
        // a child and its descendants in one partition). Vertices are in
        // BFS order, so `e.parent < e.child` always holds and a split
        // borrow reads the parent slots while writing the child's.
        let (head, tail) = ids.split_at_mut(e.child);
        let parent_ids: &[NodeId] = &head[e.parent];
        let global_base = root_offset * mult[e.parent];
        let child = &mut tail[0];
        for (slot, &p) in parent_ids.iter().enumerate() {
            if p == PAD {
                continue;
            }
            let nbrs = csr.neighbors(p);
            if nbrs.is_empty() {
                continue;
            }
            let mut rng = slot_rng(seed, ei, global_base + slot, p);
            let base = slot * k;
            if nbrs.len() <= k {
                for (j, &u) in nbrs.iter().enumerate() {
                    child[base + j] = u;
                }
            } else {
                rng.sample_distinct_into(nbrs.len(), k, &mut picks);
                for (j, &idx) in picks.iter().enumerate() {
                    child[base + j] = nbrs[idx];
                }
            }
        }
    }
    TreeSample {
        ids,
        fanouts: fanouts.to_vec(),
    }
}

/// Pre-sampling hotness profiler (paper §6: sample for `epochs` epochs
/// before training, recording per-node visit counts). Returns
/// `counts[type][node]`.
///
/// Counts flow through the batch [`Frontier`]: one frontier (recycled
/// across batches) collapses each sampled tree to distinct ids with
/// occurrence multiplicities, so the per-node accumulation touches each
/// distinct id once per batch — the counts are identical to a per-slot
/// rescan, by the frontier's multiplicity invariant
/// (`tests/test_gather_dedup.rs` pins the equality). The frontier build
/// pays a sort/dedup the old direct count did not, but this runs once
/// at profiling time, off the training hot path, and exercises the same
/// machinery the gather path depends on.
pub fn presample_hotness(
    g: &HetGraph,
    tree: &MetaTree,
    fanouts: &[usize],
    batch_size: usize,
    epochs: usize,
    seed: u64,
) -> Vec<Vec<u32>> {
    let num_types = g.schema.node_types.len();
    let mut counts: Vec<Vec<u32>> = g
        .schema
        .node_types
        .iter()
        .map(|t| vec![0u32; t.count])
        .collect();
    let mut train = g.train_nodes();
    let mut rng = Rng::new(seed);
    let mut fr = Frontier::default();
    for epoch in 0..epochs {
        rng.shuffle(&mut train);
        for (bi, chunk) in train.chunks(batch_size).enumerate() {
            let s = sample_tree(g, tree, fanouts, chunk, 0, seed ^ ((epoch * 131 + bi) as u64), |_| true);
            fr.rebuild(tree, &s, num_types, true);
            for (ty, uniq) in fr.unique.iter().enumerate() {
                for (u, &id) in uniq.iter().enumerate() {
                    counts[ty][id as usize] += fr.multiplicity[ty][u];
                }
            }
        }
    }
    counts
}

/// Count sampled nodes that are *remote* under an edge-cut partition map,
/// from the perspective of worker `me` — the vanilla engine's
/// feature-fetching communication driver (paper §4's 92.3 MB example).
pub fn remote_counts(
    tree: &MetaTree,
    sample: &TreeSample,
    owner: &crate::partition::NodePartition,
    me: usize,
) -> RemoteStats {
    let mut stats = RemoteStats::default();
    for (v, vertex_ids) in sample.ids.iter().enumerate() {
        let ty = tree.vertices[v].ty;
        for &id in vertex_ids {
            if id == PAD {
                continue;
            }
            stats.total += 1;
            if owner.owner_of(ty, id) != me {
                stats.remote += 1;
            }
        }
    }
    stats
}

#[derive(Debug, Clone, Copy, Default)]
pub struct RemoteStats {
    pub total: u64,
    pub remote: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};
    use crate::hetgraph::MetaTree;
    use crate::util::proptest;

    fn setup() -> (HetGraph, MetaTree) {
        let g = generate(Preset::Mag, 1e-4, &GenParams::default());
        let t = MetaTree::build(&g.schema, 2);
        (g, t)
    }

    #[test]
    fn vertex_sizes_multiply() {
        let (_, t) = setup();
        let sizes = vertex_sizes(&t, &[4, 3], 8);
        assert_eq!(sizes[0], 8);
        for e in &t.edges {
            let d = t.vertices[e.parent].depth;
            assert_eq!(sizes[e.child], sizes[e.parent] * [4, 3][d]);
        }
    }

    #[test]
    fn sampled_ids_are_real_neighbors() {
        let (g, t) = setup();
        let batch: Vec<NodeId> = (0..8).collect();
        let s = sample_tree(&g, &t, &[4, 3], &batch, 0, 7, |_| true);
        for (ei, e) in t.edges.iter().enumerate() {
            let k = s.fanouts[t.vertices[e.parent].depth];
            for (slot, &p) in s.ids[e.parent].iter().enumerate() {
                let children = &s.ids[e.child][slot * k..(slot + 1) * k];
                if p == PAD {
                    assert!(children.iter().all(|&c| c == PAD), "edge {ei}");
                } else {
                    let nbrs = g.csr(e.rel).neighbors(p);
                    for &c in children.iter().filter(|&&c| c != PAD) {
                        assert!(nbrs.contains(&c), "edge {ei}: {c} not a neighbor of {p}");
                    }
                }
            }
        }
    }

    #[test]
    fn sampling_is_deterministic_and_partition_consistent() {
        // RAF (filtered) sampling must reproduce exactly the slots the
        // full-tree sample produced for those edges — Prop. 1's substrate.
        let (g, t) = setup();
        let batch: Vec<NodeId> = (3..19).collect();
        let full = sample_tree(&g, &t, &[4, 3], &batch, 0, 99, |_| true);
        let keep = |ei: usize| ei % 2 == 0;
        let part = sample_tree(&g, &t, &[4, 3], &batch, 0, 99, keep);
        for (ei, e) in t.edges.iter().enumerate() {
            if keep(ei) && keep_ancestors(&t, ei, &keep) {
                assert_eq!(part.ids[e.child], full.ids[e.child], "edge {ei} diverged");
            }
        }
    }

    fn keep_ancestors(t: &MetaTree, ei: usize, keep: &impl Fn(usize) -> bool) -> bool {
        // An edge's sample matches the full tree only if all ancestor
        // edges were also sampled.
        let mut v = t.edges[ei].parent;
        while let Some(p) = t.vertices[v].parent {
            let pe = t
                .edges
                .iter()
                .position(|e| e.child == v)
                .expect("parent edge");
            if !keep(pe) {
                return false;
            }
            v = p;
        }
        true
    }

    #[test]
    fn no_duplicate_neighbors_per_slot() {
        let (g, t) = setup();
        let batch: Vec<NodeId> = (0..16).collect();
        let s = sample_tree(&g, &t, &[4, 3], &batch, 0, 1, |_| true);
        for e in &t.edges {
            let k = s.fanouts[t.vertices[e.parent].depth];
            for slot in 0..s.ids[e.parent].len() {
                let chunk: Vec<_> = s.ids[e.child][slot * k..(slot + 1) * k]
                    .iter()
                    .filter(|&&c| c != PAD)
                    .collect();
                let set: std::collections::HashSet<_> = chunk.iter().collect();
                assert_eq!(set.len(), chunk.len(), "duplicates in slot");
            }
        }
    }

    #[test]
    fn presample_counts_are_populated_and_skewed() {
        let (g, t) = setup();
        let counts = presample_hotness(&g, &t, &[4, 3], 16, 1, 5);
        assert_eq!(counts.len(), g.schema.node_types.len());
        // Author type (Zipf sources) must show skew: max count >> median.
        let mut author: Vec<u32> = counts[1].clone();
        author.sort_unstable_by(|a, b| b.cmp(a));
        assert!(author[0] > 0);
        assert!(author[0] >= 4 * author[author.len() / 2].max(1));
    }

    #[test]
    fn prop_padded_slots_have_padded_subtrees() {
        proptest::run("sampling_pad_closure", |rng, _| {
            let g = generate(
                Preset::Mag240m,
                5e-5,
                &GenParams { seed: rng.next_u64(), avg_degree: 3.0, ..Default::default() },
            );
            let t = MetaTree::build(&g.schema, 2);
            let b = 4 + rng.below(12);
            let batch: Vec<NodeId> = (0..b as u32).collect();
            let s = sample_tree(&g, &t, &[3, 2], &batch, 0, rng.next_u64(), |_| true);
            for e in &t.edges {
                let k = s.fanouts[t.vertices[e.parent].depth];
                for (slot, &p) in s.ids[e.parent].iter().enumerate() {
                    if p == PAD {
                        let child = &s.ids[e.child][slot * k..(slot + 1) * k];
                        crate::prop_assert!(
                            child.iter().all(|&c| c == PAD),
                            "pad slot has sampled children"
                        );
                    }
                }
            }
            Ok(())
        });
    }
}
