//! Per-batch deduplicated fetch frontier.
//!
//! A sampled tree addresses features through *padded slots*: the same
//! node id typically occupies many slots (a hot author is sampled under
//! hundreds of papers), yet each slot used to trigger its own feature
//! read and cache consultation. The [`Frontier`] collapses one
//! [`TreeSample`](super::TreeSample) into, per node type, the **sorted
//! distinct** ids it touches plus an inverse index from every padded
//! slot back to its unique row. Downstream, the KV store gathers each
//! distinct row once into a staging buffer
//! ([`FeatureStore::gather_unique`](crate::kvstore::FeatureStore::gather_unique)),
//! the cache model is consulted once per unique id
//! ([`FeatureCache::access_unique`](crate::cache::FeatureCache::access_unique)),
//! and padded block literals are produced by an in-memory scatter
//! ([`scatter_rows`](crate::kvstore::scatter_rows)) — the unique-row
//! staging-then-scatter pipeline of the paper's §6 runtime.
//!
//! The frontier also caches per-vertex valid-slot counts and per-unique
//! occurrence multiplicities, so hotness profiling and communication
//! accounting reuse the same single pass over the slots.
//!
//! Frontiers are designed to be **recycled**: [`Frontier::rebuild`]
//! refills an existing instance, reusing every interior allocation, so
//! the per-batch cost is the sort/dedup itself, not the allocator.

use crate::hetgraph::{MetaTree, NodeId};

use super::{TreeSample, PAD};

/// Sentinel in [`Frontier::slot_to_unique`] marking a padded slot.
pub const NO_ROW: u32 = u32::MAX;

/// The deduplicated fetch set of one sampled tree.
#[derive(Debug, Clone, Default)]
pub struct Frontier {
    /// Per node type: sorted distinct non-[`PAD`] ids across every
    /// metatree vertex of that type (the root batch joins only when the
    /// frontier was built with `include_root` — see [`Frontier::build`]).
    pub unique: Vec<Vec<NodeId>>,
    /// Per node type: how many padded slots reference each unique id
    /// (aligned with `unique`). Σ multiplicity = valid slots of the
    /// type's indexed vertices (all of them under `include_root`).
    pub multiplicity: Vec<Vec<u32>>,
    /// Per metatree vertex: padded slot → index into `unique[ty]`
    /// (`NO_ROW` for padded slots).
    pub slot_to_unique: Vec<Vec<u32>>,
    /// Per metatree vertex: number of valid (non-pad) slots — the cached
    /// answer to [`TreeSample::valid_count`].
    pub valid_counts: Vec<usize>,
}

impl Frontier {
    /// Build a fresh frontier for one sampled tree. `include_root`
    /// selects whether vertex 0 (the target batch itself) joins the
    /// fetch set: pass `true` when the consuming artifact gathers
    /// target features (the vanilla engine, hotness profiling) and
    /// `false` for RAF worker builds, whose artifacts only reference
    /// child vertices — staging root rows there would fetch and charge
    /// rows the leader gathers separately.
    pub fn build(
        tree: &MetaTree,
        sample: &TreeSample,
        num_types: usize,
        include_root: bool,
    ) -> Frontier {
        let mut f = Frontier::default();
        f.rebuild(tree, sample, num_types, include_root);
        f
    }

    /// Recompute this frontier for a new sample, recycling all interior
    /// allocations (the per-batch arena contract: no steady-state
    /// allocation in the hot path). See [`Frontier::build`] for
    /// `include_root`; `valid_counts` always covers every vertex.
    pub fn rebuild(
        &mut self,
        tree: &MetaTree,
        sample: &TreeSample,
        num_types: usize,
        include_root: bool,
    ) {
        if self.unique.len() < num_types {
            self.unique.resize_with(num_types, Vec::new);
            self.multiplicity.resize_with(num_types, Vec::new);
        }
        if self.slot_to_unique.len() < sample.ids.len() {
            self.slot_to_unique.resize_with(sample.ids.len(), Vec::new);
        }
        self.slot_to_unique.truncate(sample.ids.len());
        for u in &mut self.unique {
            u.clear();
        }
        self.valid_counts.clear();
        self.valid_counts.resize(sample.ids.len(), 0);

        // Pass 1: collect valid ids per type and count valid slots.
        for (v, ids) in sample.ids.iter().enumerate() {
            let ty = tree.vertices[v].ty;
            let bucket = &mut self.unique[ty];
            let mut valid = 0usize;
            for &id in ids {
                if id != PAD {
                    if v > 0 || include_root {
                        bucket.push(id);
                    }
                    valid += 1;
                }
            }
            self.valid_counts[v] = valid;
        }
        for u in &mut self.unique {
            u.sort_unstable();
            u.dedup();
        }
        for (ty, m) in self.multiplicity.iter_mut().enumerate() {
            m.clear();
            m.resize(self.unique[ty].len(), 0);
        }

        // Pass 2: inverse index (slot → unique row) + multiplicities.
        let unique = &self.unique;
        let mult = &mut self.multiplicity;
        for (v, ids) in sample.ids.iter().enumerate() {
            let ty = tree.vertices[v].ty;
            let bucket = &unique[ty];
            let inv = &mut self.slot_to_unique[v];
            inv.clear();
            inv.reserve(ids.len());
            if v == 0 && !include_root {
                // Excluded root: keep the shape invariant, map no slot.
                inv.resize(ids.len(), NO_ROW);
                continue;
            }
            for &id in ids {
                if id == PAD {
                    inv.push(NO_ROW);
                    continue;
                }
                let u = bucket
                    .binary_search(&id)
                    .expect("frontier pass 1 indexed every valid id") as u32;
                inv.push(u);
                mult[ty][u as usize] += 1;
            }
        }
    }

    /// Cluster-worker ping-pong: take the recycled frontier out of
    /// `spare` (or allocate the first one), rebuild it for `sample`,
    /// and return it. Single source of truth for the four worker-side
    /// build sites, so the rebuild arguments and recycling protocol
    /// cannot drift apart per engine.
    pub fn take_rebuilt(
        spare: &mut Option<Frontier>,
        tree: &MetaTree,
        sample: &TreeSample,
        num_types: usize,
        include_root: bool,
    ) -> Frontier {
        let mut f = spare.take().unwrap_or_default();
        f.rebuild(tree, sample, num_types, include_root);
        f
    }

    /// Distinct rows of one node type.
    pub fn rows(&self, ty: usize) -> &[NodeId] {
        &self.unique[ty]
    }

    /// Index of `id` within `unique[ty]`, if the batch touches it.
    pub fn unique_index(&self, ty: usize, id: NodeId) -> Option<usize> {
        self.unique.get(ty)?.binary_search(&id).ok()
    }

    /// Total distinct rows across all types (the dedup'd fetch volume).
    pub fn total_unique_rows(&self) -> usize {
        self.unique.iter().map(|u| u.len()).sum()
    }

    /// Total valid slots across all vertices (the pre-dedup volume).
    pub fn total_valid_slots(&self) -> usize {
        self.valid_counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};
    use crate::sampling::sample_tree;

    fn setup() -> (crate::hetgraph::HetGraph, MetaTree, TreeSample) {
        let g = generate(Preset::Mag, 1e-4, &GenParams::default());
        let t = MetaTree::build(&g.schema, 2);
        let batch: Vec<NodeId> = (0..16).collect();
        let s = sample_tree(&g, &t, &[4, 3], &batch, 0, 13, |_| true);
        (g, t, s)
    }

    #[test]
    fn unique_ids_sorted_distinct_and_complete() {
        let (g, t, s) = setup();
        let f = Frontier::build(&t, &s, g.schema.node_types.len(), true);
        for u in &f.unique {
            assert!(u.windows(2).all(|w| w[0] < w[1]), "not sorted-distinct");
            assert!(u.iter().all(|&id| id != PAD));
        }
        // Every valid slot id appears in its type's unique set.
        for (v, ids) in s.ids.iter().enumerate() {
            let ty = t.vertices[v].ty;
            for &id in ids.iter().filter(|&&id| id != PAD) {
                assert!(f.unique_index(ty, id).is_some(), "id {id} missing");
            }
        }
    }

    #[test]
    fn inverse_index_roundtrips_slots() {
        let (g, t, s) = setup();
        let f = Frontier::build(&t, &s, g.schema.node_types.len(), true);
        for (v, ids) in s.ids.iter().enumerate() {
            let ty = t.vertices[v].ty;
            assert_eq!(f.slot_to_unique[v].len(), ids.len());
            for (slot, &id) in ids.iter().enumerate() {
                let u = f.slot_to_unique[v][slot];
                if id == PAD {
                    assert_eq!(u, NO_ROW);
                } else {
                    assert_eq!(f.unique[ty][u as usize], id, "vertex {v} slot {slot}");
                }
            }
        }
    }

    #[test]
    fn valid_counts_and_multiplicity_agree_with_rescan() {
        let (g, t, s) = setup();
        let f = Frontier::build(&t, &s, g.schema.node_types.len(), true);
        for v in 0..s.ids.len() {
            assert_eq!(f.valid_counts[v], s.valid_count(v), "vertex {v}");
        }
        // Multiplicities sum to the valid-slot count per type.
        let mut per_ty = vec![0usize; g.schema.node_types.len()];
        for (v, &c) in f.valid_counts.iter().enumerate() {
            per_ty[t.vertices[v].ty] += c;
        }
        for (ty, m) in f.multiplicity.iter().enumerate() {
            assert_eq!(m.iter().map(|&c| c as usize).sum::<usize>(), per_ty[ty]);
        }
        assert_eq!(f.total_valid_slots(), per_ty.iter().sum::<usize>());
        assert!(f.total_unique_rows() <= f.total_valid_slots());
    }

    #[test]
    fn excluding_root_drops_only_root_only_ids() {
        let (g, t, s) = setup();
        let full = Frontier::build(&t, &s, g.schema.node_types.len(), true);
        let worker = Frontier::build(&t, &s, g.schema.node_types.len(), false);
        // Root slots map to nothing in the worker view…
        assert_eq!(worker.slot_to_unique[0].len(), s.ids[0].len());
        assert!(worker.slot_to_unique[0].iter().all(|&u| u == NO_ROW));
        // …but valid counts still cover every vertex.
        assert_eq!(worker.valid_counts, full.valid_counts);
        // Non-root vertices are indexed identically (same distinct ids).
        for (v, ids) in s.ids.iter().enumerate().skip(1) {
            let ty = t.vertices[v].ty;
            for (slot, &id) in ids.iter().enumerate() {
                if id != PAD {
                    assert_eq!(worker.unique[ty][worker.slot_to_unique[v][slot] as usize], id);
                }
            }
        }
        // The worker view never exceeds the full fetch set.
        for ty in 0..g.schema.node_types.len() {
            assert!(worker.unique[ty].len() <= full.unique[ty].len());
            assert!(worker.unique[ty].iter().all(|id| full.unique[ty].contains(id)));
        }
    }

    #[test]
    fn rebuild_recycles_and_matches_fresh_build() {
        let (g, t, s1) = setup();
        let batch: Vec<NodeId> = (20..44).collect();
        let s2 = sample_tree(&g, &t, &[4, 3], &batch, 0, 99, |_| true);
        let mut f = Frontier::build(&t, &s1, g.schema.node_types.len(), true);
        f.rebuild(&t, &s2, g.schema.node_types.len(), true);
        let fresh = Frontier::build(&t, &s2, g.schema.node_types.len(), true);
        assert_eq!(f.unique, fresh.unique);
        assert_eq!(f.multiplicity, fresh.multiplicity);
        assert_eq!(f.slot_to_unique, fresh.slot_to_unique);
        assert_eq!(f.valid_counts, fresh.valid_counts);
    }
}
