//! Optimizers in Rust: dense Adam for model weights and **sparse
//! (row-wise) Adam** for learnable feature tables — the update stage
//! whose DRAM random read/write cost the paper identifies as 24–35% of
//! epoch time (Fig. 4, challenge 3). Duplicate rows within a batch are
//! grad-accumulated before a single row update, matching DGL's sparse
//! Adam semantics.

use std::collections::HashMap;

use crate::hetgraph::NodeId;

/// Adam hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct AdamParams {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamParams {
    fn default() -> Self {
        AdamParams {
            lr: 1e-2,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

/// Dense Adam state for one parameter tensor.
#[derive(Debug, Clone)]
pub struct Adam {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: i32,
    pub hp: AdamParams,
}

impl Adam {
    pub fn new(len: usize, hp: AdamParams) -> Adam {
        Adam {
            m: vec![0.0; len],
            v: vec![0.0; len],
            t: 0,
            hp,
        }
    }

    /// One Adam step over the full tensor.
    pub fn step(&mut self, param: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(param.len(), grad.len());
        self.t += 1;
        let hp = self.hp;
        let bc1 = 1.0 - hp.beta1.powi(self.t);
        let bc2 = 1.0 - hp.beta2.powi(self.t);
        for i in 0..param.len() {
            let g = grad[i];
            self.m[i] = hp.beta1 * self.m[i] + (1.0 - hp.beta1) * g;
            self.v[i] = hp.beta2 * self.v[i] + (1.0 - hp.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            param[i] -= hp.lr * mhat / (vhat.sqrt() + hp.eps);
        }
    }
}

/// Accumulate per-row gradients: `(ids, grads)` where row `i` of `grads`
/// (width `dim`) belongs to node `ids[i]`; padded ids are skipped.
/// Returns deduplicated (id → summed gradient) pairs sorted by id.
pub fn accumulate_rows(
    ids: &[NodeId],
    grads: &[f32],
    dim: usize,
    pad: NodeId,
) -> Vec<(NodeId, Vec<f32>)> {
    debug_assert!(grads.len() >= ids.len() * dim);
    let mut acc: HashMap<NodeId, Vec<f32>> = HashMap::new();
    for (i, &id) in ids.iter().enumerate() {
        if id == pad {
            continue;
        }
        let g = &grads[i * dim..(i + 1) * dim];
        match acc.get_mut(&id) {
            Some(row) => {
                for (a, &b) in row.iter_mut().zip(g) {
                    *a += b;
                }
            }
            None => {
                acc.insert(id, g.to_vec());
            }
        }
    }
    let mut rows: Vec<(NodeId, Vec<f32>)> = acc.into_iter().collect();
    rows.sort_by_key(|(id, _)| *id);
    rows
}

/// Sparse Adam: apply one step to only the touched rows of a learnable
/// table. `step_t` is the shared timestep (bias correction), `weight`/
/// `m`/`v` are the full tables (row-major, width `dim`). Returns the
/// number of rows updated (→ DRAM traffic accounting).
#[allow(clippy::too_many_arguments)]
pub fn sparse_adam_step(
    rows: &[(NodeId, Vec<f32>)],
    weight: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    dim: usize,
    step_t: i32,
    hp: AdamParams,
) -> usize {
    let bc1 = 1.0 - hp.beta1.powi(step_t);
    let bc2 = 1.0 - hp.beta2.powi(step_t);
    for (id, grad) in rows {
        let base = *id as usize * dim;
        for c in 0..dim {
            let g = grad[c];
            let i = base + c;
            m[i] = hp.beta1 * m[i] + (1.0 - hp.beta1) * g;
            v[i] = hp.beta2 * v[i] + (1.0 - hp.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            weight[i] -= hp.lr * mhat / (vhat.sqrt() + hp.eps);
        }
    }
    rows.len()
}

/// Plain SGD step (used in tests as a reference optimizer).
pub fn sgd_step(param: &mut [f32], grad: &[f32], lr: f32) {
    for (p, &g) in param.iter_mut().zip(grad) {
        *p -= lr * g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_reduces_quadratic_loss() {
        // Minimize f(x) = ||x - 3||² with Adam; must converge near 3.
        let mut x = vec![0.0f32; 4];
        let mut adam = Adam::new(4, AdamParams { lr: 0.1, ..Default::default() });
        for _ in 0..300 {
            let grad: Vec<f32> = x.iter().map(|&xi| 2.0 * (xi - 3.0)).collect();
            adam.step(&mut x, &grad);
        }
        for xi in x {
            assert!((xi - 3.0).abs() < 0.05, "xi={xi}");
        }
    }

    #[test]
    fn accumulate_dedups_and_sums() {
        let ids = [2u32, 5, 2, u32::MAX];
        let grads = [1.0f32, 1.0, /* id5 */ 2.0, 2.0, /* id2 again */ 3.0, 3.0, /* pad */ 9.0, 9.0];
        let rows = accumulate_rows(&ids, &grads, 2, u32::MAX);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, 2);
        assert_eq!(rows[0].1, vec![4.0, 4.0]);
        assert_eq!(rows[1].0, 5);
        assert_eq!(rows[1].1, vec![2.0, 2.0]);
    }

    #[test]
    fn sparse_adam_only_touches_given_rows() {
        let dim = 3;
        let mut w = vec![1.0f32; 5 * dim];
        let mut m = vec![0.0f32; 5 * dim];
        let mut v = vec![0.0f32; 5 * dim];
        let rows = vec![(1u32, vec![1.0, 1.0, 1.0])];
        let n = sparse_adam_step(&rows, &mut w, &mut m, &mut v, dim, 1, AdamParams::default());
        assert_eq!(n, 1);
        assert!(w[dim..2 * dim].iter().all(|&x| x < 1.0));
        assert!(w[..dim].iter().all(|&x| x == 1.0));
        assert!(w[2 * dim..].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn sparse_matches_dense_on_touched_rows() {
        // A sparse step over all rows must equal a dense step.
        let dim = 2;
        let n = 4;
        let grad: Vec<f32> = (0..n * dim).map(|i| (i as f32) * 0.1 - 0.3).collect();
        let mut dense_w = vec![0.5f32; n * dim];
        let mut sparse_w = dense_w.clone();
        let mut adam = Adam::new(n * dim, AdamParams::default());
        adam.step(&mut dense_w, &grad);

        let rows: Vec<(NodeId, Vec<f32>)> = (0..n)
            .map(|i| (i as u32, grad[i * dim..(i + 1) * dim].to_vec()))
            .collect();
        let mut m = vec![0.0f32; n * dim];
        let mut v = vec![0.0f32; n * dim];
        sparse_adam_step(&rows, &mut sparse_w, &mut m, &mut v, dim, 1, AdamParams::default());
        for (a, b) in dense_w.iter().zip(&sparse_w) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let mut p = vec![1.0f32, -1.0];
        sgd_step(&mut p, &[0.5, -0.5], 0.1);
        assert!((p[0] - 0.95).abs() < 1e-7);
        assert!((p[1] + 0.95).abs() < 1e-7);
    }
}
