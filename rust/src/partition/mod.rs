//! Graph partitioning: Heta's meta-partitioning (paper §5, Algorithm 2)
//! and the baselines it is compared against in Table 2 / Figs. 8–9 —
//! random edge-cut (DGL-Random), a from-scratch METIS-like multilevel
//! edge-cut partitioner (DGL-METIS), and GraphLearn-style per-type random
//! partitioning. Also partition-quality metrics (cut edges, boundary
//! nodes, balance) used by the Prop. 2/3 property tests.

pub mod meta;
pub mod edgecut;
pub mod metis_like;
pub mod quality;

use crate::hetgraph::{HetGraph, RelId};

/// An edge-cut partitioning: every node of every type is owned by exactly
/// one partition. (Used by the vanilla execution model.)
#[derive(Debug, Clone)]
pub struct NodePartition {
    pub num_parts: usize,
    /// `owner[type][node]` = partition id.
    pub owner: Vec<Vec<u8>>,
    pub method: &'static str,
    /// Wall-clock partitioning time (seconds).
    pub elapsed_s: f64,
    /// Approximate peak auxiliary memory used while partitioning (bytes),
    /// for Table 2.
    pub peak_mem_bytes: u64,
}

impl NodePartition {
    #[inline]
    pub fn owner_of(&self, ty: usize, node: u32) -> usize {
        self.owner[ty][node as usize] as usize
    }

    /// Per-partition node counts (all types), for balance checks.
    pub fn part_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.num_parts];
        for tymap in &self.owner {
            for &p in tymap {
                sizes[p as usize] += 1;
            }
        }
        sizes
    }
}

/// A meta-partitioning: relations (mono-relation subgraphs) are assigned
/// to partitions; every partition holds all target nodes (paper §5,
/// Step 2) plus the nodes of the types its relations touch.
#[derive(Debug, Clone)]
pub struct MetaPartition {
    pub num_parts: usize,
    /// Deduplicated relations per partition (Algorithm 2, Step 4).
    pub rels_per_part: Vec<Vec<RelId>>,
    /// For relations present in several partitions (metagraph cycles),
    /// the unique owner that applies optimizer updates to its weights.
    pub rel_owner: Vec<usize>,
    /// Sub-metatree → partition assignment (LPT), for inspection.
    pub assignment: Vec<usize>,
    /// Sub-metatree weights (sum of vertex+link weights, Algorithm 2 l.8).
    pub sub_weights: Vec<u64>,
    pub elapsed_s: f64,
    pub peak_mem_bytes: u64,
}

impl MetaPartition {
    /// Node types present in a partition (types touched by its relations,
    /// plus the target type which every partition holds).
    pub fn types_in_part(&self, g: &HetGraph, part: usize) -> Vec<usize> {
        let mut present = vec![false; g.schema.node_types.len()];
        present[g.schema.target] = true;
        for &r in &self.rels_per_part[part] {
            present[g.schema.relations[r].src] = true;
            present[g.schema.relations[r].dst] = true;
        }
        (0..present.len()).filter(|&t| present[t]).collect()
    }

    /// Per-partition load = Σ (nodes of types present) + Σ (edges of
    /// relations present); used for the balance property test.
    pub fn part_load(&self, g: &HetGraph, part: usize) -> u64 {
        let nodes: u64 = self
            .types_in_part(g, part)
            .iter()
            .map(|&t| g.schema.node_types[t].count as u64)
            .sum();
        let edges: u64 = self.rels_per_part[part]
            .iter()
            .map(|&r| g.rels[r].num_edges() as u64)
            .sum();
        nodes + edges
    }

    /// Bytes needed to store a partition's topology (complete
    /// mono-relation subgraphs) — Table 2 memory accounting.
    pub fn part_topology_bytes(&self, g: &HetGraph, part: usize) -> u64 {
        self.rels_per_part[part]
            .iter()
            .map(|&r| g.rels[r].mem_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};

    #[test]
    fn node_partition_sizes_sum() {
        let g = generate(Preset::Mag, 1e-4, &GenParams::default());
        let p = edgecut::random(&g, 4, 1);
        assert_eq!(p.part_sizes().iter().sum::<usize>(), g.num_nodes());
    }
}
