//! Partition-quality metrics: cross-partition edge cut, boundary-node
//! counts, and balance — the quantities in the paper's communication
//! analysis (Props. 2–3: `max_i |B(G_i)| ≤ E(G_1, G_2)`) and in the
//! partitioning objective (Eq. 2).

use std::collections::HashSet;

use crate::hetgraph::HetGraph;

use super::{MetaPartition, NodePartition};

/// Number of edges whose endpoints live in different partitions
/// (the vanilla execution model's communication driver).
pub fn edge_cut(g: &HetGraph, p: &NodePartition) -> u64 {
    let mut cut = 0u64;
    for rel in &g.rels {
        let (sty, dty) = {
            let r = &g.schema.relations[rel.rel];
            (r.src, r.dst)
        };
        for dst in 0..(rel.offsets.len() - 1) as u32 {
            let dp = p.owner_of(dty, dst);
            for &src in rel.neighbors(dst) {
                if p.owner_of(sty, src) != dp {
                    cut += 1;
                }
            }
        }
    }
    cut
}

/// Boundary nodes per partition: nodes with at least one neighbor in a
/// different partition (`B(G_i)` in the paper).
pub fn boundary_nodes(g: &HetGraph, p: &NodePartition) -> Vec<u64> {
    let mut boundary: Vec<HashSet<(usize, u32)>> = vec![HashSet::new(); p.num_parts];
    for rel in &g.rels {
        let (sty, dty) = {
            let r = &g.schema.relations[rel.rel];
            (r.src, r.dst)
        };
        for dst in 0..(rel.offsets.len() - 1) as u32 {
            let dp = p.owner_of(dty, dst);
            for &src in rel.neighbors(dst) {
                let sp = p.owner_of(sty, src);
                if sp != dp {
                    boundary[sp].insert((sty, src));
                    boundary[dp].insert((dty, dst));
                }
            }
        }
    }
    boundary.iter().map(|b| b.len() as u64).collect()
}

/// Boundary nodes of a meta-partitioning: by construction confined to the
/// target nodes — every partition holds all target nodes, and a target
/// node is a boundary node iff some other partition computes partials for
/// it (i.e. whenever there is more than one partition). Returns the
/// per-partition bound (|targets|) actually attained.
pub fn meta_boundary_nodes(g: &HetGraph, mp: &MetaPartition) -> Vec<u64> {
    let targets = g.schema.node_types[g.schema.target].count as u64;
    (0..mp.num_parts)
        .map(|_| if mp.num_parts > 1 { targets } else { 0 })
        .collect()
}

/// Balance (max/mean) of per-partition node counts.
pub fn node_balance(p: &NodePartition) -> f64 {
    let sizes: Vec<f64> = p.part_sizes().iter().map(|&s| s as f64).collect();
    crate::util::stats::imbalance(&sizes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};
    use crate::partition::{edgecut, meta::meta_partition};
    use crate::util::proptest;

    fn graph(seed: u64) -> HetGraph {
        generate(Preset::Mag, 8e-5, &GenParams { seed, ..Default::default() })
    }

    #[test]
    fn prop3_boundary_le_cut() {
        // Proposition 3: max_i |B(G_i)| ≤ E(G_1, G_2) for edge-cut
        // partitions, across random graphs and partitioners.
        proptest::run("prop3_boundary_le_cut", |rng, _| {
            let g = graph(rng.next_u64());
            let p = if rng.below(2) == 0 {
                edgecut::random(&g, 2, rng.next_u64())
            } else {
                edgecut::by_type(&g, 2, rng.next_u64())
            };
            let cut = edge_cut(&g, &p);
            let bounds = boundary_nodes(&g, &p);
            let maxb = *bounds.iter().max().unwrap();
            crate::prop_assert!(
                maxb <= cut,
                "Prop 3 violated: max|B|={maxb} > cut={cut}"
            );
            Ok(())
        });
    }

    #[test]
    fn meta_boundary_bounded_by_targets() {
        // §5 Step 2: boundary nodes of meta-partitioning are confined to
        // target nodes — upper bound |V_target| for every partition.
        let g = graph(3);
        let (mp, _) = meta_partition(&g, 3, 2, None);
        let targets = g.schema.node_types[g.schema.target].count as u64;
        for b in meta_boundary_nodes(&g, &mp) {
            assert!(b <= targets);
        }
    }

    #[test]
    fn meta_boundary_usually_below_edgecut_boundary() {
        // The motivating comparison: with skewed multi-hop expansion the
        // number of random-partition boundary nodes far exceeds the target
        // count that bounds meta-partitioning.
        let g = graph(4);
        let p = edgecut::random(&g, 2, 9);
        let rb = boundary_nodes(&g, &p);
        let (mp, _) = meta_partition(&g, 2, 2, None);
        let mb = meta_boundary_nodes(&g, &mp);
        assert!(
            mb.iter().max().unwrap() < rb.iter().max().unwrap(),
            "meta {mb:?} vs random {rb:?}"
        );
    }

    #[test]
    fn edge_cut_zero_for_single_partition() {
        let g = graph(5);
        let p = edgecut::random(&g, 1, 1);
        assert_eq!(edge_cut(&g, &p), 0);
        assert_eq!(boundary_nodes(&g, &p), vec![0]);
    }
}
