//! Edge-cut baselines: DGL-Random (uniform node assignment over the
//! homogenized graph) and GraphLearn-style per-type random assignment.
//! Both perform the expensive "split the original HetG and shuffle
//! nodes/edges" work the paper attributes their Table-2 cost to — we
//! materialize per-partition edge lists to model it honestly.

use std::time::Instant;

use crate::hetgraph::HetGraph;
use crate::util::rng::Rng;

use super::NodePartition;

/// DGL-Random: every node (of every type) is assigned to a uniformly
/// random partition.
pub fn random(g: &HetGraph, num_parts: usize, seed: u64) -> NodePartition {
    let start = Instant::now();
    let mut rng = Rng::new(seed);
    let owner: Vec<Vec<u8>> = g
        .schema
        .node_types
        .iter()
        .map(|t| (0..t.count).map(|_| rng.below(num_parts) as u8).collect())
        .collect();
    let peak = materialize_cost(g, &owner, num_parts);
    NodePartition {
        num_parts,
        owner,
        method: "random",
        elapsed_s: start.elapsed().as_secs_f64(),
        peak_mem_bytes: peak,
    }
}

/// GraphLearn-style: random partitioning applied independently per node
/// type (equal split of each type's id range after a shuffle).
pub fn by_type(g: &HetGraph, num_parts: usize, seed: u64) -> NodePartition {
    let start = Instant::now();
    let mut rng = Rng::new(seed);
    let owner: Vec<Vec<u8>> = g
        .schema
        .node_types
        .iter()
        .map(|t| {
            // Balanced per-type split: shuffle ids, deal them round-robin.
            let mut ids: Vec<u32> = (0..t.count as u32).collect();
            rng.shuffle(&mut ids);
            let mut map = vec![0u8; t.count];
            for (i, &id) in ids.iter().enumerate() {
                map[id as usize] = (i % num_parts) as u8;
            }
            map
        })
        .collect();
    let peak = materialize_cost(g, &owner, num_parts)
        + g.schema
            .node_types
            .iter()
            .map(|t| t.count as u64 * 4)
            .sum::<u64>(); // the shuffle buffers
    NodePartition {
        num_parts,
        owner,
        method: "graphlearn",
        elapsed_s: start.elapsed().as_secs_f64(),
        peak_mem_bytes: peak,
    }
}

/// Materialize per-partition edge lists (dst-owner placement), returning
/// the bytes of auxiliary memory this requires. This is the dominant cost
/// of edge-cut partitioning in DGL (Table 2) — splitting and reshuffling
/// the whole graph — and we actually perform it so measured times are
/// honest.
pub(crate) fn materialize_cost(g: &HetGraph, owner: &[Vec<u8>], num_parts: usize) -> u64 {
    let mut per_part_edges: Vec<Vec<(u32, u32)>> = vec![Vec::new(); num_parts];
    for rel in &g.rels {
        let dst_ty = g.schema.relations[rel.rel].dst;
        for dst in 0..(rel.offsets.len() - 1) as u32 {
            let p = owner[dst_ty][dst as usize] as usize;
            for &src in rel.neighbors(dst) {
                per_part_edges[p].push((src, dst));
            }
        }
    }
    let bytes: u64 = per_part_edges
        .iter()
        .map(|v| (v.capacity() * std::mem::size_of::<(u32, u32)>()) as u64)
        .sum();
    // Keep the optimizer from removing the materialization.
    std::hint::black_box(&per_part_edges);
    bytes + g.mem_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};
    use crate::util::proptest;

    fn g() -> HetGraph {
        generate(Preset::Mag, 1e-4, &GenParams::default())
    }

    #[test]
    fn random_assigns_every_node() {
        let graph = g();
        let p = random(&graph, 3, 1);
        assert_eq!(p.owner.len(), graph.schema.node_types.len());
        for (ty, map) in p.owner.iter().enumerate() {
            assert_eq!(map.len(), graph.schema.node_types[ty].count);
            assert!(map.iter().all(|&o| (o as usize) < 3));
        }
    }

    #[test]
    fn by_type_is_balanced_within_each_type() {
        let graph = g();
        let p = by_type(&graph, 4, 1);
        for map in &p.owner {
            let mut counts = [0usize; 4];
            for &o in map {
                counts[o as usize] += 1;
            }
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(max - min <= 1, "per-type imbalance: {counts:?}");
        }
    }

    #[test]
    fn random_roughly_balanced_overall() {
        let graph = generate(Preset::Mag, 1e-3, &GenParams::default());
        let p = random(&graph, 2, 7);
        let sizes = p.part_sizes();
        let imb = sizes[0] as f64 / sizes[1] as f64;
        assert!(imb > 0.85 && imb < 1.18, "sizes {sizes:?}");
    }

    #[test]
    fn prop_partition_ids_always_valid() {
        proptest::run("edgecut_valid_ids", |rng, _| {
            let graph = generate(
                Preset::Donor,
                5e-5,
                &GenParams { seed: rng.next_u64(), ..Default::default() },
            );
            let parts = 1 + rng.below(6);
            let p = if rng.below(2) == 0 {
                random(&graph, parts, rng.next_u64())
            } else {
                by_type(&graph, parts, rng.next_u64())
            };
            for map in &p.owner {
                crate::prop_assert!(
                    map.iter().all(|&o| (o as usize) < parts),
                    "invalid owner id"
                );
            }
            crate::prop_assert!(
                p.part_sizes().iter().sum::<usize>() == graph.num_nodes(),
                "sizes don't sum to |V|"
            );
            Ok(())
        });
    }
}
