//! From-scratch METIS-like multilevel edge-cut partitioner (the paper's
//! DGL-METIS baseline; METIS itself is unavailable offline).
//!
//! Classic three-phase multilevel scheme (Karypis & Kumar 1998):
//!   1. **Coarsening** — repeated heavy-edge matching (HEM) collapses
//!      matched vertex pairs, accumulating vertex/edge weights;
//!   2. **Initial partitioning** — greedy graph growing on the coarsest
//!      graph into `k` balanced parts;
//!   3. **Uncoarsening + refinement** — project the partition back level
//!      by level, applying a boundary FM/KL pass (move boundary vertices
//!      to the partition where they have most edge weight, subject to a
//!      balance constraint) at each level.
//!
//! Like METIS it homogenizes the HetG first (one adjacency over all
//! relations, ignoring types) — exactly the behaviour the paper calls
//! suboptimal for HGNNs — and its cost is O(V + E) time and memory on
//! the *full* graph, reproducing Table 2's time/memory gap against
//! meta-partitioning.

use std::time::Instant;

use crate::hetgraph::HetGraph;
use crate::util::rng::Rng;

use super::NodePartition;

/// Homogenized undirected weighted graph in CSR form.
struct WGraph {
    xadj: Vec<u32>,
    adj: Vec<u32>,
    /// Edge weights (parallel to `adj`).
    ew: Vec<u32>,
    /// Vertex weights (collapsed multiplicity).
    vw: Vec<u32>,
}

impl WGraph {
    fn n(&self) -> usize {
        self.vw.len()
    }
    fn neighbors(&self, v: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let lo = self.xadj[v as usize] as usize;
        let hi = self.xadj[v as usize + 1] as usize;
        self.adj[lo..hi].iter().copied().zip(self.ew[lo..hi].iter().copied())
    }
    fn mem_bytes(&self) -> u64 {
        ((self.xadj.len() + self.adj.len() + self.ew.len() + self.vw.len()) * 4) as u64
    }
}

/// Build the homogenized graph: global ids are per-type offsets; every
/// relation edge becomes an undirected unit-weight edge (duplicates merged
/// with weight accumulation).
fn homogenize(g: &HetGraph) -> (WGraph, Vec<usize>) {
    let mut offsets = Vec::with_capacity(g.schema.node_types.len() + 1);
    let mut acc = 0usize;
    for t in &g.schema.node_types {
        offsets.push(acc);
        acc += t.count;
    }
    offsets.push(acc);
    let n = acc;

    // Collect undirected edges (both directions), then sort-dedup per
    // vertex via counting into CSR.
    let mut deg = vec![0u32; n + 1];
    for rel in &g.rels {
        let (sty, dty) = {
            let r = &g.schema.relations[rel.rel];
            (r.src, r.dst)
        };
        for dst in 0..(rel.offsets.len() - 1) {
            for &src in rel.neighbors(dst as u32) {
                let gs = (offsets[sty] + src as usize) as u32;
                let gd = (offsets[dty] + dst) as u32;
                if gs == gd {
                    continue;
                }
                deg[gs as usize + 1] += 1;
                deg[gd as usize + 1] += 1;
            }
        }
    }
    for i in 1..deg.len() {
        deg[i] += deg[i - 1];
    }
    let xadj = deg.clone();
    let mut adj = vec![0u32; xadj[n] as usize];
    let mut cursor = deg;
    for rel in &g.rels {
        let (sty, dty) = {
            let r = &g.schema.relations[rel.rel];
            (r.src, r.dst)
        };
        for dst in 0..(rel.offsets.len() - 1) {
            for &src in rel.neighbors(dst as u32) {
                let gs = (offsets[sty] + src as usize) as u32;
                let gd = (offsets[dty] + dst) as u32;
                if gs == gd {
                    continue;
                }
                adj[cursor[gs as usize] as usize] = gd;
                cursor[gs as usize] += 1;
                adj[cursor[gd as usize] as usize] = gs;
                cursor[gd as usize] += 1;
            }
        }
    }
    let ew = vec![1u32; adj.len()];
    (
        WGraph {
            xadj,
            adj,
            ew,
            vw: vec![1u32; n],
        },
        offsets,
    )
}

/// One heavy-edge-matching coarsening step. Returns (coarse graph,
/// fine→coarse map) or None if it can no longer shrink usefully.
fn coarsen(g: &WGraph, rng: &mut Rng) -> Option<(WGraph, Vec<u32>)> {
    let n = g.n();
    let mut matched = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut coarse_n = 0u32;
    for &v in &order {
        if matched[v as usize] != u32::MAX {
            continue;
        }
        // Heaviest unmatched neighbor.
        let mut best: Option<(u32, u32)> = None;
        for (u, w) in g.neighbors(v) {
            if matched[u as usize] == u32::MAX && u != v {
                if best.map_or(true, |(_, bw)| w > bw) {
                    best = Some((u, w));
                }
            }
        }
        match best {
            Some((u, _)) => {
                matched[v as usize] = coarse_n;
                matched[u as usize] = coarse_n;
            }
            None => {
                matched[v as usize] = coarse_n;
            }
        }
        coarse_n += 1;
    }
    if (coarse_n as usize) as f64 > n as f64 * 0.95 {
        return None; // not shrinking — stop coarsening
    }

    // Build the coarse graph by merging adjacency (hash-combine per coarse
    // vertex).
    let cn = coarse_n as usize;
    let mut vw = vec![0u32; cn];
    for v in 0..n {
        vw[matched[v] as usize] += g.vw[v];
    }
    let mut edges: Vec<std::collections::HashMap<u32, u32>> =
        vec![std::collections::HashMap::new(); cn];
    for v in 0..n as u32 {
        let cv = matched[v as usize];
        for (u, w) in g.neighbors(v) {
            let cu = matched[u as usize];
            if cu != cv {
                *edges[cv as usize].entry(cu).or_insert(0) += w;
            }
        }
    }
    let mut xadj = Vec::with_capacity(cn + 1);
    let mut adj = Vec::new();
    let mut ew = Vec::new();
    xadj.push(0u32);
    for e in &edges {
        for (&u, &w) in e {
            adj.push(u);
            ew.push(w);
        }
        xadj.push(adj.len() as u32);
    }
    Some((WGraph { xadj, adj, ew, vw }, matched))
}

/// Greedy graph-growing initial k-way partition on the coarsest graph.
fn initial_partition(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<u8> {
    let n = g.n();
    let total_w: u64 = g.vw.iter().map(|&w| w as u64).sum();
    let target = (total_w as f64 / k as f64).ceil() as u64;
    let mut part = vec![u8::MAX; n];
    let mut part_w = vec![0u64; k];
    for p in 0..k {
        // Grow partition p from a random unassigned seed via BFS until the
        // target weight is reached.
        let mut frontier = std::collections::VecDeque::new();
        while part_w[p] < target {
            let v = match frontier.pop_front() {
                Some(v) => v,
                None => {
                    // New seed: first unassigned vertex (random start).
                    let start = rng.below(n);
                    match (0..n).map(|i| (i + start) % n).find(|&i| part[i] == u8::MAX) {
                        Some(s) => s as u32,
                        None => break,
                    }
                }
            };
            if part[v as usize] != u8::MAX {
                continue;
            }
            part[v as usize] = p as u8;
            part_w[p] += g.vw[v as usize] as u64;
            for (u, _) in g.neighbors(v) {
                if part[u as usize] == u8::MAX {
                    frontier.push_back(u);
                }
            }
        }
    }
    // Any stragglers go to the lightest partition.
    for v in 0..n {
        if part[v] == u8::MAX {
            let p = (0..k).min_by_key(|&p| part_w[p]).unwrap();
            part[v] = p as u8;
            part_w[p] += g.vw[v] as u64;
        }
    }
    part
}

/// One FM-style boundary refinement pass: move boundary vertices to the
/// neighboring partition with the largest edge-weight gain, subject to a
/// (1 + ε) balance constraint.
fn refine(g: &WGraph, part: &mut [u8], k: usize, epsilon: f64) {
    let total_w: u64 = g.vw.iter().map(|&w| w as u64).sum();
    let max_w = ((total_w as f64 / k as f64) * (1.0 + epsilon)) as u64;
    let mut part_w = vec![0u64; k];
    for v in 0..g.n() {
        part_w[part[v] as usize] += g.vw[v] as u64;
    }
    for v in 0..g.n() as u32 {
        let cur = part[v as usize] as usize;
        // Edge weight towards each partition.
        let mut towards = vec![0u64; k];
        for (u, w) in g.neighbors(v) {
            towards[part[u as usize] as usize] += w as u64;
        }
        let (best, &bw) = towards
            .iter()
            .enumerate()
            .max_by_key(|(_, &w)| w)
            .unwrap();
        if best != cur
            && bw > towards[cur]
            && part_w[best] + g.vw[v as usize] as u64 <= max_w
        {
            part_w[cur] -= g.vw[v as usize] as u64;
            part_w[best] += g.vw[v as usize] as u64;
            part[v as usize] = best as u8;
        }
    }
}

/// Run the multilevel partitioner. Returns a [`NodePartition`] over the
/// original typed node ids.
pub fn metis_like(g: &HetGraph, num_parts: usize, seed: u64) -> NodePartition {
    let start = Instant::now();
    let mut rng = Rng::new(seed);
    let (g0, offsets) = homogenize(g);
    let mut peak = g0.mem_bytes() + g.mem_bytes();

    // Coarsening hierarchy.
    let coarse_target = (num_parts * 64).max(256);
    let mut levels: Vec<WGraph> = vec![];
    let mut maps: Vec<Vec<u32>> = vec![];
    let mut cur = g0;
    while cur.n() > coarse_target {
        match coarsen(&cur, &mut rng) {
            Some((coarser, map)) => {
                peak += coarser.mem_bytes() + (map.len() * 4) as u64;
                maps.push(map);
                levels.push(std::mem::replace(&mut cur, coarser));
            }
            None => break,
        }
    }

    // Initial partition on the coarsest level + refinement.
    let mut part = initial_partition(&cur, num_parts, &mut rng);
    refine(&cur, &mut part, num_parts, 0.05);

    // Uncoarsen with refinement at every level.
    while let (Some(fine), Some(map)) = (levels.pop(), maps.pop()) {
        let mut fine_part = vec![0u8; fine.n()];
        for v in 0..fine.n() {
            fine_part[v] = part[map[v] as usize];
        }
        refine(&fine, &mut fine_part, num_parts, 0.05);
        part = fine_part;
        cur = fine;
    }
    let _ = cur;

    // Back to typed ids.
    let owner: Vec<Vec<u8>> = g
        .schema
        .node_types
        .iter()
        .enumerate()
        .map(|(ty, t)| {
            (0..t.count)
                .map(|i| part[offsets[ty] + i])
                .collect::<Vec<u8>>()
        })
        .collect();
    // The vanilla pipeline also pays the edge-list materialization.
    peak += super::edgecut::materialize_cost(g, &owner, num_parts);

    NodePartition {
        num_parts,
        owner,
        method: "metis-like",
        elapsed_s: start.elapsed().as_secs_f64(),
        peak_mem_bytes: peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate, GenParams, Preset};
    use crate::partition::{edgecut, quality};
    use crate::util::proptest;

    fn graph() -> HetGraph {
        generate(Preset::Mag, 1e-4, &GenParams::default())
    }

    #[test]
    fn produces_valid_balanced_partition() {
        let g = graph();
        let p = metis_like(&g, 2, 3);
        let sizes = p.part_sizes();
        assert_eq!(sizes.iter().sum::<usize>(), g.num_nodes());
        let imb = crate::util::stats::imbalance(&sizes.iter().map(|&s| s as f64).collect::<Vec<_>>());
        assert!(imb < 1.35, "imbalance {imb}: {sizes:?}");
    }

    #[test]
    fn cuts_fewer_edges_than_random() {
        let g = graph();
        let pm = metis_like(&g, 2, 3);
        let pr = edgecut::random(&g, 2, 3);
        let cm = quality::edge_cut(&g, &pm);
        let cr = quality::edge_cut(&g, &pr);
        assert!(
            cm < cr,
            "metis-like cut {cm} should beat random cut {cr}"
        );
    }

    #[test]
    fn homogenize_is_symmetric() {
        let g = graph();
        let (wg, _) = homogenize(&g);
        // Total degree = 2 × undirected edge instances.
        assert_eq!(wg.adj.len() % 2, 0);
        assert_eq!(wg.xadj[wg.n()] as usize, wg.adj.len());
    }

    #[test]
    fn prop_metis_valid_on_varied_graphs() {
        proptest::run_with(
            crate::util::proptest::Config { cases: 12, seed: 0xBEEF },
            "metis_like_valid",
            |rng, _| {
                let preset = [Preset::Mag, Preset::Mag240m][rng.below(2)];
                let g = generate(
                    preset,
                    4e-5,
                    &GenParams { seed: rng.next_u64(), ..Default::default() },
                );
                let k = 2 + rng.below(3);
                let p = metis_like(&g, k, rng.next_u64());
                crate::prop_assert!(
                    p.part_sizes().iter().sum::<usize>() == g.num_nodes(),
                    "node count mismatch"
                );
                let sizes = p.part_sizes();
                crate::prop_assert!(
                    sizes.iter().all(|&s| s > 0),
                    "empty partition: {sizes:?}"
                );
                Ok(())
            },
        );
    }
}
